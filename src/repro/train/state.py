"""Train state container."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jnp.ndarray          # () int32 — completed optimizer steps


def init_train_state(model, optimizer: AdamW, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(model, optimizer: AdamW) -> TrainState:
    """ShapeDtypeStruct train state — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda k: init_train_state(model, optimizer, k),
        jax.random.PRNGKey(0))
