from repro.train.state import (TrainState, abstract_train_state,
                               init_train_state)
from repro.train.step import (accumulate, finalize_step, make_grad_fn,
                              make_loss_fn, make_train_step)

__all__ = ["TrainState", "init_train_state", "abstract_train_state",
           "make_train_step", "make_grad_fn", "make_loss_fn", "accumulate",
           "finalize_step"]
