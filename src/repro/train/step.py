"""Training steps.

Two execution paths with *identical semantics* (tested):

* **Fused** (`make_train_step`): one jitted function scanning over
  micro-batches, accumulating gradients, then applying the optimizer.
  This is the production pjit-lowered step used by the dry-run.

* **Resumable** (`make_grad_fn` + `finalize_step`): per-micro-batch
  gradient calls with an explicit accumulator the caller owns.  Unicron's
  micro-batch scheduler (core/resumption.py) drives this path so that a
  mid-iteration failure can resume from partial results (§6.2, Eq. 7).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.optim import AdamW, global_norm
from repro.train.state import TrainState


def make_loss_fn(model, kernel: str = "jnp", remat: bool = False):
    def loss_fn(params, batch):
        return model.loss(params, batch, kernel=kernel, remat=remat)
    return loss_fn


def make_grad_fn(model, kernel: str = "jnp", remat: bool = False):
    """Per-micro-batch gradient: (params, micro_batch) -> (grads, metrics).

    Gradients are returned as *sums-compatible* means over the micro-batch
    (mean over tokens inside, so accumulation across micro-batches is a
    plain sum divided by the count — Eq. 6/7 algebra).
    """
    loss_fn = make_loss_fn(model, kernel, remat)

    @jax.jit
    def grad_fn(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics
    return grad_fn


def accumulate(acc, grads):
    """Add grads into the accumulator pytree (fp32)."""
    if acc is None:
        return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    return jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)


@partial(jax.jit, static_argnums=(0,))
def _finalize(optimizer: AdamW, state: TrainState, grad_sum, count):
    grads = jax.tree.map(lambda g: g / count, grad_sum)
    params, opt = optimizer.update(grads, state.opt, state.params)
    return TrainState(params, opt, state.step + 1), global_norm(grads)


def finalize_step(optimizer: AdamW, state: TrainState, grad_sum,
                  count: int) -> Tuple[TrainState, jnp.ndarray]:
    """Apply the accumulated (summed) gradients of ``count`` micro-batches."""
    return _finalize(optimizer, state, grad_sum,
                     jnp.asarray(count, jnp.float32))


def make_train_step(model, optimizer: AdamW, n_micro: int,
                    kernel: str = "jnp", remat: bool = False) -> Callable:
    """Fused production step.

    ``batch`` must be stacked for scan: every leaf has leading dims
    (n_micro, micro_batch, ...) — see data.stack_microbatches.
    Returns (state, metrics) with metrics averaged over micro-batches.
    """
    loss_fn = make_loss_fn(model, kernel, remat)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        def mb_step(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        if n_micro == 1:
            acc, metrics = mb_step(zeros, jax.tree.map(
                lambda a: a[0], batch))
            metrics = jax.tree.map(lambda m: m[None], metrics)
        else:
            acc, metrics = lax.scan(mb_step, zeros, batch)
        grads = jax.tree.map(lambda g: g / n_micro, acc)
        params, opt = optimizer.update(grads, state.opt, state.params)
        out_metrics = jax.tree.map(jnp.mean, metrics)
        out_metrics["grad_norm"] = global_norm(grads)
        return TrainState(params, opt, state.step + 1), out_metrics

    return train_step
