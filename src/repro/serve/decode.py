"""Serving: prefill + autoregressive decode on top of model.decode_step.

``make_serve_step`` builds the one-token decode function the decode-shape
dry-runs lower: given a KV cache of capacity ``seq_len``, produce ONE new
token.  ``prefill``/``generate`` drive real decoding for the examples.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax


def make_serve_step(model):
    """serve_step(params, caches, tokens, pos) -> (next_tokens, caches).

    Greedy sampling; ``pos`` is the absolute position of ``tokens``.
    """
    def serve_step(params, caches, tokens, pos):
        logits, caches = model.decode_step(params, caches, tokens, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches
    return serve_step


def prefill(model, params, caches, prompt: jnp.ndarray,
            start_pos: int = 0):
    """Feed ``prompt`` (B, S) through decode steps via scan.

    Returns (caches, last_logits).
    """
    S = prompt.shape[1]

    def step(carry, t):
        caches = carry
        logits, caches = model.decode_step(params, caches, prompt[:, t],
                                           start_pos + t)
        return caches, logits

    caches, logits_seq = lax.scan(step, caches, jnp.arange(S))
    return caches, logits_seq[-1]


def generate(model, params, prompt: jnp.ndarray, n_new: int,
             capacity: Optional[int] = None,
             cache_dtype=None) -> jnp.ndarray:
    """Greedy generation: returns (B, n_new) new tokens."""
    B, S = prompt.shape
    cap = capacity or (S + n_new)
    caches = model.init_cache(B, cap, cache_dtype)
    caches, last_logits = prefill(model, params, caches, prompt)
    tok0 = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)

    def step(carry, i):
        tok, caches = carry
        logits, caches = model.decode_step(params, caches, tok, S + i)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, caches), tok

    (_, _), toks = lax.scan(step, (tok0, caches), jnp.arange(n_new))
    return toks.T                                   # (B, n_new)


class RequestBatcher:
    """Minimal static-batch server: pads requests to a fixed batch and
    decodes them together (the serving example's front-end)."""

    def __init__(self, model, params, batch_size: int, capacity: int):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.capacity = capacity

    def serve(self, prompts, n_new: int):
        """prompts: list of 1-D int arrays (same length for simplicity)."""
        assert len(prompts) <= self.batch_size
        S = len(prompts[0])
        pad = self.batch_size - len(prompts)
        batch = jnp.stack(list(prompts)
                          + [jnp.zeros((S,), jnp.int32)] * pad)
        out = generate(self.model, self.params, batch, n_new,
                       capacity=self.capacity)
        return [out[i] for i in range(len(prompts))]
