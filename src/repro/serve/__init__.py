from repro.serve.decode import (RequestBatcher, generate, make_serve_step,
                                prefill)

__all__ = ["RequestBatcher", "generate", "make_serve_step", "prefill"]
