"""Continuous-batching request scheduler over the decode path.

vLLM-style token-level scheduling at laptop scale: a fixed pool of batch
lanes, each independently holding one request's progress against the
shared KV/state cache.  Every tick is ONE fused ``decode_step`` in which
each lane consumes its own next token at its own position — prompt
tokens while prefilling, generated tokens afterwards (the model's decode
path supports per-lane positions for exactly this).  New requests join
free lanes between ticks; finished requests free their lane immediately
— no head-of-line blocking on the longest request in the batch.

This is the serving-side counterpart of Unicron's elasticity story: the
scheduler tolerates lane-level failure (a poisoned request is evicted
and its lane recycled) without touching the other lanes.  Lane outcomes
are counted (``slo_stats``) and feed the planner's serving objective:
``waf.ServingSLO.calibrated`` derates per-worker capacity by the
observed lane-failure fraction, closing the loop between decode-path
health and cluster-level worker assignment.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp


@dataclass
class Request:
    req_id: int
    prompt: jnp.ndarray                 # (S,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False
    eos: Optional[int] = None


@dataclass
class _Lane:
    req: Optional[Request] = None
    pos: int = 0                        # position of the NEXT token to feed
    pending: int = 0                    # that token's id

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatcher:
    """Schedules requests over ``batch_size`` decode lanes."""

    def __init__(self, model, params, batch_size: int, capacity: int):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.capacity = capacity
        self.lanes = [_Lane() for _ in range(batch_size)]
        self.caches = model.init_cache(batch_size, capacity)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._decode = jax.jit(model.decode_step)
        self.steps = 0
        self.lane_failures = 0          # evicted (poisoned) requests
        self.completed = 0              # naturally finished requests

    # ---- client API --------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 100_000) -> List[Request]:
        while (self.queue or any(not ln.free for ln in self.lanes)) \
                and self.steps < max_steps:
            self.step()
        return self.finished

    # ---- scheduler core ----------------------------------------------------

    def _admit(self) -> None:
        for i, lane in enumerate(self.lanes):
            if not lane.free or not self.queue:
                continue
            req = self.queue.pop(0)
            self._reset_lane(i)
            lane.req = req
            lane.pos = 0
            lane.pending = int(req.prompt[0])

    def _reset_lane(self, i: int) -> None:
        """Zero lane i of every cache leaf (the leaf dim whose size is
        the batch size is the lane dim)."""
        def zero_lane(leaf):
            for axis, n in enumerate(leaf.shape):
                if n == self.batch_size:
                    return leaf.at[(slice(None),) * axis + (i,)].set(0)
            return leaf
        self.caches = jax.tree.map(zero_lane, self.caches)

    def step(self) -> None:
        self._admit()
        if all(ln.free for ln in self.lanes):
            return
        toks = jnp.asarray([ln.pending for ln in self.lanes], jnp.int32)
        poss = jnp.asarray([ln.pos for ln in self.lanes], jnp.int32)
        logits, self.caches = self._decode(self.params, self.caches,
                                           toks, poss)
        nxt = jnp.argmax(logits, axis=-1)
        for i, lane in enumerate(self.lanes):
            if lane.free:
                continue
            req = lane.req
            fed = lane.pos
            lane.pos += 1
            if fed < req.prompt.shape[0] - 1:
                lane.pending = int(req.prompt[fed + 1])   # still prefilling
                continue
            tok = int(nxt[i])                             # generated token
            req.out.append(tok)
            lane.pending = tok
            if len(req.out) >= req.max_new \
                    or (req.eos is not None and tok == req.eos) \
                    or lane.pos >= self.capacity - 1:
                req.done = True
                self.finished.append(req)
                self.completed += 1
                lane.req = None
        self.steps += 1

    # ---- failure handling ----------------------------------------------------

    def evict(self, req_id: int) -> bool:
        """Lane-level recovery: drop a poisoned request, recycle the
        lane; other lanes are untouched.  Counts toward
        ``lane_failures`` in :meth:`slo_stats`."""
        for lane in self.lanes:
            if lane.req is not None and lane.req.req_id == req_id:
                lane.req.done = True
                self.finished.append(lane.req)
                lane.req = None
                self.lane_failures += 1
                return True
        return False

    def slo_stats(self) -> dict:
        """Lane-outcome counters for objective calibration — the dict
        ``waf.ServingSLO.calibrated`` consumes.  ``lane_failures`` are
        evictions (poisoned/failed requests), ``completed`` natural
        finishes; the remaining keys are load diagnostics."""
        return {
            "lane_failures": self.lane_failures,
            "completed": self.completed,
            "steps": self.steps,
            "queue_depth": len(self.queue),
            "in_flight": sum(not ln.free for ln in self.lanes),
        }
