"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                       min_ratio: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)
