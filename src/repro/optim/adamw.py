"""AdamW in pure JAX with fp32 master weights for low-precision params.

Optimizer state per parameter: fp32 first/second moments, plus an fp32
master copy when the parameter itself is stored in bf16 — the standard
mixed-precision layout (2 + 4 + 4 + 4 bytes/param), which is what the
dry-run memory analysis should reflect.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray            # ()
    mu: Any                      # fp32 pytree
    nu: Any                      # fp32 pytree
    master: Any                  # fp32 pytree or None (params already fp32)


@dataclass(frozen=True)
class AdamW:
    lr: Callable                 # step -> lr  (or float)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        needs_master = any(p.dtype != jnp.float32
                           for p in jax.tree.leaves(params))
        master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
                  if needs_master else None)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros), master=master)

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state).  Grads may be any float dtype."""
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip and self.grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip /
                                jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, grads)
        ref = state.master if state.master is not None else params

        def upd(p32, m, v):
            mhat = m / b1c
            vhat = v / b2c
            return p32 - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                               + self.weight_decay * p32)

        new_ref = jax.tree.map(
            lambda p, m, v: upd(p.astype(jnp.float32), m, v), ref, mu, nu)
        new_params = jax.tree.map(
            lambda nr, p: nr.astype(p.dtype), new_ref, params)
        new_master = new_ref if state.master is not None else None
        return new_params, AdamWState(step, mu, nu, new_master)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))
