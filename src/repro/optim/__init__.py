from repro.optim.adamw import AdamW, AdamWState, global_norm
from repro.optim.schedules import constant, cosine_with_warmup

__all__ = ["AdamW", "AdamWState", "global_norm", "constant",
           "cosine_with_warmup"]
