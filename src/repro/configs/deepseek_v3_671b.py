"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437]"""
from repro.configs.base import (ArchConfig, AttnConfig, MLAConfig, MoEConfig,
                                register)

ARCH = register(ArchConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    source="arXiv:2412.19437",
    n_layers=61,
    d_model=7168,
    d_ff=18432,                       # dense-prefix layers' FFN width
    vocab=129280,
    attn=AttnConfig(n_heads=128, n_kv_heads=128, head_dim=128),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1),
    n_dense_prefix=3,
    mtp=True,
    mlp_act="silu",
    norm="rmsnorm",
))
