"""gemma3-12b [dense] — 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ArchConfig, AttnConfig, register

ARCH = register(ArchConfig(
    name="gemma3-12b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=48,
    d_model=3840,
    d_ff=15360,
    vocab=262144,
    attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=256,
                    qk_norm=True, window=1024, local_ratio=(5, 1),
                    rope_theta=1_000_000.0),
    mlp_act="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
    embed_scale=True,
))
