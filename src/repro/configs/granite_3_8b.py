"""granite-3-8b [dense] — GQA.  [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import ArchConfig, AttnConfig, register

ARCH = register(ArchConfig(
    name="granite-3-8b",
    arch_type="dense",
    source="hf:ibm-granite/granite-3.0-2b-base",
    n_layers=40,
    d_model=4096,
    d_ff=12800,
    vocab=49155,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128),
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
))
