"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242]

Zamba2 interleaves Mamba2 layers with a *shared* (weight-tied) attention
block invoked periodically; we apply the shared attention+MLP block every
``shared_period`` mamba layers, matching the 1.2B model's 6-layer period.
"""
from repro.configs.base import ArchConfig, AttnConfig, SSMConfig, register

ARCH = register(ArchConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    n_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=64),
    ssm=SSMConfig(d_state=64, head_dim=64),
    shared_period=6,
    mlp_act="gelu",
    norm="rmsnorm",
))
