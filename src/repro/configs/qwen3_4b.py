"""qwen3-4b [dense] — qk_norm, GQA.  [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ArchConfig, AttnConfig, register

ARCH = register(ArchConfig(
    name="qwen3-4b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=36,
    d_model=2560,
    d_ff=9728,
    vocab=151936,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                    qk_norm=True, rope_theta=1_000_000.0),
    mlp_act="silu",
    norm="rmsnorm",
))
