"""internvl2-2b [vlm] — InternViT (stub frontend) + InternLM2 backbone.
[arXiv:2404.16821]

Per the assignment carve-out, the ViT vision encoder + projector is a STUB:
``input_specs()`` supplies precomputed patch embeddings of shape
(batch, n_prefix_embeds, d_model) which are prepended to the token stream.
"""
from repro.configs.base import ArchConfig, AttnConfig, register

ARCH = register(ArchConfig(
    name="internvl2-2b",
    arch_type="vlm",
    source="arXiv:2404.16821",
    n_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab=92553,
    attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=128),
    modality="vision_stub",
    n_prefix_embeds=256,              # one 448x448 tile -> 256 patch tokens
    mlp_act="silu",
    norm="rmsnorm",
))
