"""Configuration system for the Unicron reproduction framework.

Every architecture assigned to this paper is expressed as an
:class:`ArchConfig`.  Configs are plain frozen dataclasses so they can be
hashed, used as jit static args, and copied into reduced "smoke" variants
(``reduced()``) that run one forward/train step on CPU.

The four canonical input shapes (train_4k / prefill_32k / decode_32k /
long_500k) are :class:`ShapeConfig` instances in ``SHAPES``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (GShard/DeepSeek style)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0          # DeepSeek shared experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01    # load-balance loss weight
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""

    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128                   # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention settings."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class AttnConfig:
    """Plain / GQA / MQA attention settings."""

    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False              # qwen3-style per-head RMSNorm on q,k
    causal: bool = True                # False for encoder-only (hubert)
    # Sliding-window pattern: window > 0 means local attention with the
    # given window; ``local_ratio`` of (local, global) layers per period,
    # e.g. gemma3 uses (5, 1) -> 5 local layers then 1 global layer.
    window: int = 0
    local_ratio: Tuple[int, int] = (0, 1)
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

# block kinds used by the model builder
BLOCK_ATTN_DENSE = "attn_dense"        # attention + dense MLP
BLOCK_ATTN_MOE = "attn_moe"            # attention + MoE FFN
BLOCK_MLA_DENSE = "mla_dense"          # MLA attention + dense MLP
BLOCK_MLA_MOE = "mla_moe"              # MLA attention + MoE FFN
BLOCK_MAMBA = "mamba"                  # Mamba2 SSD block
BLOCK_HYBRID_SHARED = "hybrid_shared"  # zamba2: mamba layers + shared attn


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    source: str                        # citation for the config numbers
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None

    # dense-layer prefix before MoE layers (deepseek: first 3 dense)
    n_dense_prefix: int = 0
    # zamba2: shared attention block applied every `shared_period` layers
    shared_period: int = 0

    mlp_act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    gated_mlp: bool = True             # False = classic 2-matrix MLP (GPT-3)
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    tie_embeddings: bool = False
    encoder_only: bool = False         # hubert: no decode step
    modality: str = "text"             # text | vision_stub | audio_stub
    n_prefix_embeds: int = 0           # VLM patch / audio frame positions
    mtp: bool = False                  # DeepSeek multi-token-prediction head
    embed_scale: bool = False          # gemma: scale embeddings by sqrt(d)
    param_dtype: str = "bfloat16"

    # ---- derived helpers ---------------------------------------------------

    @property
    def block_pattern(self) -> Tuple[Tuple[str, int], ...]:
        """Sequence of (block_kind, count) segments for the layer stack."""
        if self.arch_type == "ssm":
            return ((BLOCK_MAMBA, self.n_layers),)
        if self.arch_type == "hybrid":
            return ((BLOCK_HYBRID_SHARED, self.n_layers),)
        if self.moe is not None and self.mla is not None:
            return (
                (BLOCK_MLA_DENSE, self.n_dense_prefix),
                (BLOCK_MLA_MOE, self.n_layers - self.n_dense_prefix),
            )
        if self.moe is not None:
            return (
                (BLOCK_ATTN_DENSE, self.n_dense_prefix),
                (BLOCK_ATTN_MOE, self.n_layers - self.n_dense_prefix),
            )
        if self.mla is not None:
            return ((BLOCK_MLA_DENSE, self.n_layers),)
        return ((BLOCK_ATTN_DENSE, self.n_layers),)

    def param_count(self) -> int:
        """Approximate parameter count N (used for 6*N*D roofline check)."""
        d = self.d_model
        n = 0
        n += self.vocab * d                       # embedding
        if not self.tie_embeddings:
            n += self.vocab * d                   # lm head
        for kind, count in self.block_pattern:
            if count == 0:
                continue
            n += count * self._block_params(kind)
        if self.shared_period:                    # zamba2 shared attn+MLP block
            n += self._attn_params() + self._mlp_params(self.d_ff) + 2 * d
        n += d                                    # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        d = self.d_model
        n = self.vocab * d
        if not self.tie_embeddings:
            n += self.vocab * d
        for kind, count in self.block_pattern:
            if count == 0:
                continue
            n += count * self._block_params(kind, active_only=True)
        if self.shared_period:
            n += self._attn_params() + self._mlp_params(self.d_ff) + 2 * d
        n += d
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            h = self.attn.n_heads
            p = d * m.q_lora_rank
            p += m.q_lora_rank * h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
            p += h * m.v_head_dim * d
            return p
        a = self.attn
        p = d * a.n_heads * a.head_dim            # q
        p += 2 * d * a.n_kv_heads * a.head_dim    # k, v
        p += a.n_heads * a.head_dim * d           # o
        return p

    def _mlp_params(self, d_ff: int) -> int:
        k = 3 if self.gated_mlp else 2            # gated: w_in, w_gate, w_out
        return k * self.d_model * d_ff

    def _block_params(self, kind: str, active_only: bool = False) -> int:
        d = self.d_model
        norm_p = 2 * d
        if kind == BLOCK_MAMBA:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            # in_proj produces [z, x, B, C, dt]: di + di + 2*n_groups*d_state + nh
            n_groups = 1
            p = d * (2 * di + 2 * n_groups * s.d_state + nh)
            p += s.d_conv * (di + 2 * n_groups * s.d_state)   # conv1d
            p += nh * 2                                       # A_log, D
            p += di                                           # gate norm
            p += di * d                                       # out proj
            return p + d                                      # + pre-norm
        if kind == BLOCK_HYBRID_SHARED:
            # zamba2: per-layer params are the mamba block only; the shared
            # attention+MLP block is weight-tied (counted once, below).
            return self._block_params(BLOCK_MAMBA)
        p = self._attn_params() + norm_p
        if kind in (BLOCK_ATTN_MOE, BLOCK_MLA_MOE):
            m = self.moe
            per_expert = self._mlp_params(m.d_ff_expert)
            n_exp = m.top_k if active_only else m.n_experts
            p += n_exp * per_expert
            p += m.n_shared_experts * per_expert
            p += self.d_model * m.n_experts                   # router
        else:
            p += self._mlp_params(self.d_ff)
        return p

    # ---- reduced smoke variant ---------------------------------------------

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        attn = None
        if self.attn is not None:
            a = self.attn
            nh = min(a.n_heads, 4)
            nkv = max(1, min(a.n_kv_heads, nh))
            # keep the MQA/GQA character: preserve ratio where possible
            if a.n_kv_heads < a.n_heads:
                nkv = max(1, nh * a.n_kv_heads // a.n_heads)
            attn = dataclasses.replace(
                a, n_heads=nh, n_kv_heads=nkv, head_dim=min(a.head_dim, 64),
                window=min(a.window, 64) if a.window else 0)
        moe = None
        if self.moe is not None:
            m = self.moe
            # capacity_factor 4.0: no token dropping at smoke scale, so
            # decode-vs-forward consistency tests see exact semantics
            # (capacity overflow is a train-scale behavior).
            moe = dataclasses.replace(
                m, n_experts=min(m.n_experts, 4), top_k=min(m.top_k, 2),
                d_ff_expert=min(m.d_ff_expert, 128),
                n_shared_experts=min(m.n_shared_experts, 1),
                capacity_factor=4.0)
        ssm = None
        if self.ssm is not None:
            s = self.ssm
            ssm = dataclasses.replace(
                s, d_state=min(s.d_state, 16), head_dim=min(s.head_dim, 32),
                chunk=16)
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                            qk_nope_head_dim=32, qk_rope_head_dim=16,
                            v_head_dim=32)
        return dataclasses.replace(
            self, n_layers=2, d_model=d, d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 1024), attn=attn, moe=moe, ssm=ssm, mla=mla,
            n_dense_prefix=min(self.n_dense_prefix, 1),
            shared_period=2 if self.shared_period else 0,
            n_prefix_embeds=min(self.n_prefix_embeds, 8),
            param_dtype="float32")


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import all config modules lazily
        from repro.configs import ALL_ARCHS  # noqa: F401
    return _REGISTRY[name]


def list_archs() -> list:
    from repro.configs import ALL_ARCHS  # noqa: F401
    return sorted(_REGISTRY)


def supports_shape(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is runnable; returns (ok, reason-if-not).

    Encoder-only archs have no decode step.  ``long_500k`` decode requires
    sub-quadratic attention over the 524k context: SSM / hybrid always
    qualify; dense archs qualify only with a sliding-window variant
    (gemma3's native 5:1 local:global pattern).  See DESIGN.md.
    """
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only architecture has no autoregressive decode"
    if shape.name == "long_500k":
        subquadratic = (
            cfg.arch_type in ("ssm", "hybrid")
            or (cfg.attn is not None and cfg.attn.window > 0)
        )
        if not subquadratic:
            return False, ("full-attention architecture without sliding-window "
                           "variant; 524k KV cache rules it out (DESIGN.md)")
    return True, ""
