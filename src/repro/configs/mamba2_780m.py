"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, SSMConfig, register

ARCH = register(ArchConfig(
    name="mamba2-780m",
    arch_type="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=1536,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64),
    norm="rmsnorm",
    tie_embeddings=True,
))
