"""hubert-xlarge [audio] — encoder-only, wav2vec2 architecture.
[arXiv:2106.07447]

The conv feature extractor (waveform -> 50Hz frames) is a STUB per the
assignment carve-out: ``input_specs()`` supplies precomputed frame
embeddings (batch, seq, d_model).  Training objective is masked-unit
prediction over the 504 cluster-code vocabulary.  Encoder-only => no
decode shapes (noted in DESIGN.md).
"""
from repro.configs.base import ArchConfig, AttnConfig, register

ARCH = register(ArchConfig(
    name="hubert-xlarge",
    arch_type="audio",
    source="arXiv:2106.07447",
    n_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab=504,
    attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=80, causal=False),
    encoder_only=True,
    modality="audio_stub",
    mlp_act="gelu",
    norm="layernorm",
))
