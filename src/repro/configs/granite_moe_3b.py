"""granite-moe-3b-a800m [moe] — 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig, register

ARCH = register(ArchConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=32,
    d_model=1536,
    d_ff=512,
    vocab=49155,
    attn=AttnConfig(n_heads=24, n_kv_heads=8, head_dim=64),
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
    mlp_act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
))
