"""GPT-3 family — the paper's own workloads (Section 7.1).

Unicron's evaluation trains GPT-3 at 1.3B / 7B / 13B / 70B / 175B.  These
configs drive the WAF cost model calibration, the multi-task experiments
(Table 3) and the trace-driven overall-efficiency experiments (Figure 11).
Shapes follow Brown et al. 2020 table 2.1.
"""
from repro.configs.base import ArchConfig, AttnConfig, register


def _gpt3(name, n_layers, d_model, n_heads):
    return register(ArchConfig(
        name=name,
        arch_type="dense",
        source="arXiv:2005.14165",
        n_layers=n_layers,
        d_model=d_model,
        d_ff=4 * d_model,
        vocab=50257,
        attn=AttnConfig(n_heads=n_heads, n_kv_heads=n_heads,
                        head_dim=d_model // n_heads),
        mlp_act="gelu",
        gated_mlp=False,
        norm="layernorm",
        tie_embeddings=True,
    ))


GPT3_1P3B = _gpt3("gpt3-1.3b", 24, 2048, 16)
GPT3_7B = _gpt3("gpt3-7b", 32, 4096, 32)
GPT3_13B = _gpt3("gpt3-13b", 40, 5120, 40)
GPT3_70B = _gpt3("gpt3-70b", 80, 8192, 64)
GPT3_175B = _gpt3("gpt3-175b", 96, 12288, 96)

GPT3_SIZES = {
    "1.3B": GPT3_1P3B, "7B": GPT3_7B, "13B": GPT3_13B,
    "70B": GPT3_70B, "175B": GPT3_175B,
}
