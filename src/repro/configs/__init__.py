"""Config registry: the 10 assigned architectures + the paper's GPT-3 family."""
from repro.configs.base import (ArchConfig, AttnConfig, MLAConfig, MoEConfig,
                                SHAPES, SSMConfig, ShapeConfig, get_arch,
                                list_archs, register, supports_shape)

# Import order defines registry order.
from repro.configs.qwen3_4b import ARCH as QWEN3_4B
from repro.configs.zamba2_1p2b import ARCH as ZAMBA2_1P2B
from repro.configs.gemma3_12b import ARCH as GEMMA3_12B
from repro.configs.deepseek_v3_671b import ARCH as DEEPSEEK_V3_671B
from repro.configs.granite_moe_3b import ARCH as GRANITE_MOE_3B
from repro.configs.mamba2_780m import ARCH as MAMBA2_780M
from repro.configs.internvl2_2b import ARCH as INTERNVL2_2B
from repro.configs.gemma_2b import ARCH as GEMMA_2B
from repro.configs.hubert_xlarge import ARCH as HUBERT_XLARGE
from repro.configs.granite_3_8b import ARCH as GRANITE_3_8B
from repro.configs import gpt3  # noqa: F401  (registers GPT-3 family)

ASSIGNED_ARCHS = [
    "qwen3-4b", "zamba2-1.2b", "gemma3-12b", "deepseek-v3-671b",
    "granite-moe-3b-a800m", "mamba2-780m", "internvl2-2b", "gemma-2b",
    "hubert-xlarge", "granite-3-8b",
]

ALL_ARCHS = ASSIGNED_ARCHS + list(gpt3.GPT3_SIZES and [
    "gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b", "gpt3-175b"])

__all__ = [
    "ArchConfig", "AttnConfig", "MLAConfig", "MoEConfig", "SSMConfig",
    "ShapeConfig", "SHAPES", "get_arch", "list_archs", "register",
    "supports_shape", "ASSIGNED_ARCHS", "ALL_ARCHS",
    "QWEN3_4B", "ZAMBA2_1P2B", "GEMMA3_12B", "DEEPSEEK_V3_671B",
    "GRANITE_MOE_3B", "MAMBA2_780M", "INTERNVL2_2B", "GEMMA_2B",
    "HUBERT_XLARGE", "GRANITE_3_8B",
]
