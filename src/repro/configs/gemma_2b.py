"""gemma-2b [dense] — GeGLU, head_dim=256, MQA.  [arXiv:2403.08295]"""
from repro.configs.base import ArchConfig, AttnConfig, register

ARCH = register(ArchConfig(
    name="gemma-2b",
    arch_type="dense",
    source="arXiv:2403.08295",
    n_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab=256000,
    attn=AttnConfig(n_heads=8, n_kv_heads=1, head_dim=256),
    mlp_act="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
    embed_scale=True,
))
