"""Named-sharding rules for every architecture.

The rules are *path-driven*: each parameter leaf's dict path (``wq``,
``w_out``, ``moe/w_in``, ...) selects which logical dimension is sharded
over the ``model`` mesh axis, with divisibility fallbacks (GQA KV heads of
8 don't divide a 16-wide model axis, so ``wk``/``wv`` fall back to the
input d_model dim — Megatron-style KV replication expressed as GSPMD
input-dim sharding).  Leading stack dims (the ``lax.scan`` layer axis)
are always unsharded, so every rule indexes from the *end* of the shape.

Optimizer state (mu/nu/master) additionally gets ZeRO-1 style sharding of
its largest unsharded dim over the data axes, which is what makes the
0.7T-class configs' 12-byte/param optimizer state fit per chip.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P



# ---------------------------------------------------------------------------
# Path helpers
# ---------------------------------------------------------------------------


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for entry in path:
        if hasattr(entry, "key"):
            names.append(str(entry.key))
        elif hasattr(entry, "idx"):
            names.append(f"[{entry.idx}]")
        elif hasattr(entry, "name"):
            names.append(str(entry.name))
    return tuple(names)


def _dict_names(names: Sequence[str]) -> Tuple[str, ...]:
    return tuple(n for n in names if not n.startswith("["))


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# leaf name -> preferred negative dims to shard over the model axis,
# tried in order until one divides.
_PREFER_LAST = ("wq", "w_uq", "w_dq", "w_dkv", "w_uk", "w_uv",
                "w_in", "w_gate", "conv_w", "conv_b", "gate_norm")
_PREFER_SECOND = ("wo", "w_out")
_KV = ("wk", "wv")
_REPLICATED = ("router", "dt_bias", "A_log", "D", "scale", "bias",
               "q_norm", "k_norm", "kv_norm")


def param_spec(names: Tuple[str, ...], shape: Tuple[int, ...],
               model_size: int, model_axis: str = "model") -> P:
    """PartitionSpec for one parameter leaf."""
    dnames = _dict_names(names)
    last = dnames[-1] if dnames else ""
    parent = dnames[-2] if len(dnames) > 1 else ""
    nd = len(shape)
    spec: list = [None] * nd

    def try_dims(*negs: int) -> bool:
        for neg in negs:
            d = nd + neg
            if 0 <= d < nd and shape[d] % model_size == 0 and shape[d] > 1:
                spec[d] = model_axis
                return True
        return False

    if last == "w" and parent in ("embed", "head"):
        try_dims(-2, -1)                    # vocab, else d_model
    elif parent == "moe" and last in ("w_in", "w_gate", "w_out") and nd >= 3:
        # (E, d, f) / (E, f, d): expert-parallel when E divides, else d_ff
        if last == "w_out":
            try_dims(-3, -2)
        else:
            try_dims(-3, -1)
    elif last in _REPLICATED:
        pass
    elif last in _PREFER_LAST:
        try_dims(-1, -2)
    elif last in _PREFER_SECOND:
        try_dims(-2, -1)
    elif last in _KV:
        try_dims(-1, -2)
    # everything else stays replicated
    return P(*spec)


def param_specs(params_shape: Any, model_size: int,
                model_axis: str = "model") -> Any:
    """PartitionSpec pytree matching ``params_shape`` (ShapeDtypeStructs)."""
    def one(path, leaf):
        return param_spec(_path_names(path), leaf.shape, model_size,
                          model_axis)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def zero1_spec(spec: P, shape: Tuple[int, ...], data_axes: Tuple[str, ...],
               data_size: int) -> P:
    """Additionally shard the largest unsharded dim over the data axes
    (ZeRO-1 optimizer-state partitioning)."""
    if len(shape) < 2:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    cands = sorted((s, i) for i, s in enumerate(shape)
                   if parts[i] is None and s % data_size == 0 and s > 1)
    if not cands:
        return spec
    _, dim = cands[-1]
    parts[dim] = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(*parts)


def opt_specs(params_shape: Any, pspecs: Any, data_axes: Tuple[str, ...],
              data_size: int) -> Any:
    def one(leaf, spec):
        return zero1_spec(spec, leaf.shape, data_axes, data_size)
    return jax.tree.map(one, params_shape, pspecs)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------


def batch_specs(batch_shape: Any, data_axes: Tuple[str, ...],
                data_size: int, *, stacked: bool) -> Any:
    """Shard the batch dim over the data axes.  ``stacked``: leaves carry a
    leading (n_micro,) scan dim before the batch dim."""
    bdim = 1 if stacked else 0
    da = data_axes if len(data_axes) > 1 else data_axes[0]

    def one(leaf):
        nd = len(leaf.shape)
        parts = [None] * nd
        if nd > bdim and leaf.shape[bdim] % data_size == 0 \
                and leaf.shape[bdim] > 1:
            parts[bdim] = da
        return P(*parts)
    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape: Any, data_axes: Tuple[str, ...],
                data_size: int, model_size: int, *,
                shard_seq: bool = False, kv_model: bool = False) -> Any:
    """Decode-cache sharding.

    Default: batch dim (axis -4 for k/v, first post-stack dim generally)
    over data.  ``shard_seq``: long-context mode — batch is 1, so the
    attention caches' capacity dim is sharded over data instead
    (flash-decoding style), and SSM state heads go over model.
    """
    da = data_axes if len(data_axes) > 1 else data_axes[0]

    def one(path, leaf):
        names = _dict_names(_path_names(path))
        last = names[-1] if names else ""
        nd = len(leaf.shape)
        parts: list = [None] * nd

        def set_neg(neg, axis, size):
            d = nd + neg
            if 0 <= d < nd and parts[d] is None \
                    and leaf.shape[d] % size == 0 and leaf.shape[d] > 1:
                parts[d] = axis
                return True
            return False

        if last in ("k", "v"):                    # (..., B, C, KV, D)
            if not set_neg(-4, da, data_size) and shard_seq:
                pass
            if shard_seq and parts[nd - 3] is None:
                set_neg(-3, da, data_size)
            if not set_neg(-2, "model", model_size) and kv_model:
                # kv heads don't divide: shard capacity over model
                # (flash-decoding style residency fix)
                set_neg(-3, "model", model_size)
        elif last in ("ckv", "k_rope"):           # (..., B, C, r)
            if not set_neg(-3, da, data_size) and shard_seq:
                pass
            if shard_seq and parts[nd - 2] is None:
                set_neg(-2, da, data_size)
            if kv_model and parts[nd - 2] is None:
                set_neg(-2, "model", model_size)
        elif last == "ssm":                       # (..., B, H, P, N)
            set_neg(-4, da, data_size)
            set_neg(-3, "model", model_size)
        elif last == "conv":                      # (..., B, K, C)
            set_neg(-3, da, data_size)
            set_neg(-1, "model", model_size)
        return P(*parts)
    return jax.tree_util.tree_map_with_path(one, cache_shape)


# ---------------------------------------------------------------------------
# Assembled sharding bundles
# ---------------------------------------------------------------------------


def train_state_specs(state_shape, mesh: Mesh, *,
                      fsdp: bool = False) -> Any:
    """Sharding spec tree for a TrainState (params + AdamW state).

    ``fsdp``: additionally shard the PARAMETERS over the data axes
    (ZeRO-3 style; XLA inserts the per-layer all-gathers).  Required for
    0.5T+ models whose bf16 weights alone exceed per-chip HBM under
    model-axis-only sharding.
    """
    axes = mesh.axis_names
    model_size = mesh.shape["model"]
    data_axes = tuple(a for a in axes if a != "model")
    data_size = 1
    for a in data_axes:
        data_size *= mesh.shape[a]

    pspecs = param_specs(state_shape.params, model_size)
    ospecs = opt_specs(state_shape.params, pspecs, data_axes, data_size)
    if fsdp:
        pspecs = ospecs
    mu = ospecs
    nu = ospecs
    master = None if state_shape.opt.master is None else ospecs
    opt = type(state_shape.opt)(step=P(), mu=mu, nu=nu, master=master)
    return type(state_shape)(params=pspecs, opt=opt, step=P())


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def data_axes_of(mesh: Mesh) -> Tuple[Tuple[str, ...], int]:
    axes = tuple(a for a in mesh.axis_names if a != "model")
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return axes, size
