from repro.sharding.rules import (batch_specs, cache_specs, data_axes_of,
                                  opt_specs, param_spec, param_specs,
                                  to_named, train_state_specs, zero1_spec)

__all__ = ["batch_specs", "cache_specs", "data_axes_of", "opt_specs",
           "param_spec", "param_specs", "to_named", "train_state_specs",
           "zero1_spec"]
