"""Transition strategy (§6) — tier-aware duration model + state migration.

``TransitionCost`` estimates the seconds a task spends transitioning under
each recovery policy; the components mirror Figure 2 / §7.3:

  detect -> (plan lookup) -> process respawn -> state migration
        -> partial-iteration recompute -> resume

Checkpoint-tier realism.  Restores follow the nearest principle (§6.3),
the same preference order ``checkpoint/manager.py`` implements for real
state: a healthy DP replica over the fast interconnect, else the GEMINI
in-memory ring checkpoint in a neighbor's host DRAM, else the remote
persistent store.  ``restore_tier`` picks the tier that would actually
satisfy the restore — including *replica-loss* bursts where a correlated
failure takes out both a node and its in-memory ring neighbor
(``replica_lost=True``), which demotes a dp==1 restore all the way to the
persistent tier — and ``lost_work_seconds`` charges the recompute that
tier implies: sub-iteration partial-result recovery from a replica, one
snapshot interval for the in-memory ring, half the persistent checkpoint
interval (``CKPT_INTERVAL_S``) when only the cloud FS survives.

Policies.  The paper's five (§7.3: unicron; megatron/varuna checkpoint
restart; oobleck/bamboo dynamic reconfiguration) are joined by three
modern recovery techniques as first-class peers:

* ``fftrainer`` — hot-spare failover (FFTrainer, PAPERS.md): a reserved
  spare substitutes for the failed node in ``FFTRAINER_FAILOVER_S``
  (near-zero), state arrives from the DP replica, and recompute is half
  an iteration.  The spares themselves are capacity the planner can
  never assign — the WAF cost lives in the engines, not this model.
* ``hierarchical_ckpt`` — tiered restore with per-tier bandwidth: the
  in-memory ring normally (``BW_INMEMORY``), demoted to the persistent
  tier on replica loss, with the lost-work charge following the tier.
* ``redundant`` — redundant computation that continues through failures:
  the transition cost is identically zero and the price is a standing
  throughput tax (the engines' EFFICIENCY table), like replication-based
  systems that degrade instead of stopping.

``migrate_state`` performs the real migration via CheckpointManager;
``estimate_*`` provides the simulator's timing, and ``estimate_batch``
reproduces every scalar cell bitwise on a stacked policy axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.manager import CheckpointManager

# ---------------------------------------------------------------------------
# Timing constants (paper §1, §7 and GEMINI-reported bandwidths)
# ---------------------------------------------------------------------------

RESPAWN_UNICRON_S = 30.0            # warm process respawn inside agent
RESPAWN_BASELINE_S = (9 + 14) * 60.0  # resubmit (9min) + env/CUDA (14min)
PLAN_LOOKUP_S = 0.1                 # O(1) lookup-table dispatch
PLAN_SOLVE_S = 2.0                  # fresh O(mn^2) solve

BW_DP_REPLICA = 150e9               # bytes/s — fast interconnect replicate
BW_INMEMORY = 25e9                  # bytes/s — host RAM / neighbor fetch
BW_PERSISTENT = 20e9                # bytes/s — cloud FS (paper: 20 GB/s)

CKPT_INTERVAL_S = 30 * 60.0         # baseline checkpoint interval
MEAN_RECOMPUTE_BASELINE_S = 15 * 60.0  # paper footnote 2

FFTRAINER_FAILOVER_S = 2.0          # hot-spare substitution (FFTrainer)
RESPAWN_HIERARCHICAL_S = 60.0       # tiered-ckpt runtime reinit
INMEMORY_SNAPSHOT_ITERS = 1.0       # GEMINI ring snapshots every iteration


@dataclass(frozen=True)
class TransitionCost:
    detect_s: float
    plan_s: float
    respawn_s: float
    migrate_s: float
    recompute_s: float

    @property
    def total(self) -> float:
        return (self.detect_s + self.plan_s + self.respawn_s
                + self.migrate_s + self.recompute_s)


def restore_tier(dp_degree: int, inmemory_available: bool = True,
                 replica_lost: bool = False) -> str:
    """Nearest principle (§6.3): healthy DP replica -> GEMINI in-memory
    ring -> persistent store.

    ``replica_lost`` models a correlated burst that took out the failed
    node's in-memory ring neighbor too — the in-memory tier cannot
    satisfy the restore, so a dp==1 task falls through to persistent."""
    if dp_degree > 1:
        return "dp_replica"
    if inmemory_available and not replica_lost:
        return "inmemory"
    return "persistent"


def migration_source(dp_degree: int, inmemory_available: bool) -> str:
    """Back-compat alias for :func:`restore_tier` (no replica loss)."""
    return restore_tier(dp_degree, inmemory_available)


def migrate_seconds(state_bytes: float, source: str) -> float:
    bw = {"dp_replica": BW_DP_REPLICA, "inmemory": BW_INMEMORY,
          "persistent": BW_PERSISTENT}[source]
    return state_bytes / bw


def lost_work_seconds(tier: str, avg_iter_s: float,
                      dp_degree: int = 1) -> float:
    """Recompute seconds implied by the tier that satisfies the restore.

    * ``dp_replica`` — partial-result reuse: survivors redo an expected
      half of the in-flight iteration, amortized across the replicas.
    * ``inmemory`` — the GEMINI ring snapshots every
      ``INMEMORY_SNAPSHOT_ITERS`` iterations, so the expected loss is
      half a snapshot interval plus the in-flight iteration.
    * ``persistent`` — half the checkpoint interval on average.
    """
    if tier == "dp_replica":
        return 0.5 * avg_iter_s * (1.0 + 1.0 / max(dp_degree - 1, 1))
    if tier == "inmemory":
        return 0.5 * avg_iter_s * (INMEMORY_SNAPSHOT_ITERS + 1.0)
    return 0.5 * CKPT_INTERVAL_S


def estimate_unicron(state_bytes: float, avg_iter_s: float,
                     dp_degree: int, detect_s: float,
                     inmemory_available: bool = True,
                     lookup_hit: bool = True,
                     replica_lost: bool = False) -> TransitionCost:
    """Unicron: restore from the nearest surviving tier; partial-results
    reuse bounds recompute by roughly one iteration when a DP replica
    survives, and the tier's snapshot cadence bounds it otherwise."""
    tier = restore_tier(dp_degree, inmemory_available, replica_lost)
    return TransitionCost(
        detect_s=detect_s,
        plan_s=PLAN_LOOKUP_S if lookup_hit else PLAN_SOLVE_S,
        respawn_s=RESPAWN_UNICRON_S,
        migrate_s=migrate_seconds(state_bytes, tier),
        recompute_s=lost_work_seconds(tier, avg_iter_s, dp_degree))


def estimate_baseline(state_bytes: float, detect_s: float, *,
                      dynamic_reconfig: bool,
                      ckpt_restart: bool) -> TransitionCost:
    """Baselines (§7.3):
    * Megatron / Varuna: full restart from the persistent checkpoint +
      mean 15 min recompute.
    * Oobleck / Bamboo: dynamic reconfiguration — no checkpoint reload,
      but they restart the iteration (lose in-flight work) and pay a
      coordination respawn.
    """
    if ckpt_restart:
        return TransitionCost(
            detect_s=detect_s, plan_s=0.0,
            respawn_s=RESPAWN_BASELINE_S,
            migrate_s=migrate_seconds(state_bytes, "persistent"),
            recompute_s=MEAN_RECOMPUTE_BASELINE_S)
    # dynamic reconfiguration without Unicron's partial-result reuse
    return TransitionCost(
        detect_s=detect_s, plan_s=PLAN_SOLVE_S,
        respawn_s=90.0 if dynamic_reconfig else RESPAWN_BASELINE_S,
        migrate_s=migrate_seconds(state_bytes, "dp_replica"),
        recompute_s=60.0)


def estimate_fftrainer(state_bytes: float, avg_iter_s: float,
                       detect_s: float) -> TransitionCost:
    """FFTrainer hot-spare failover: a reserved spare takes the failed
    node's place in seconds, state streams from the DP replica, and the
    survivors redo half an iteration.  No plan step — the substitution
    preserves the parallelization configuration."""
    return TransitionCost(
        detect_s=detect_s, plan_s=0.0,
        respawn_s=FFTRAINER_FAILOVER_S,
        migrate_s=migrate_seconds(state_bytes, "dp_replica"),
        recompute_s=0.5 * avg_iter_s)


def estimate_hierarchical(state_bytes: float, avg_iter_s: float,
                          detect_s: float, *,
                          replica_lost: bool = False) -> TransitionCost:
    """Tiered-checkpoint restore: the GEMINI in-memory ring normally,
    demoted to the persistent tier when a correlated burst also took the
    ring neighbor; lost work follows the tier's snapshot cadence."""
    tier = "persistent" if replica_lost else "inmemory"
    return TransitionCost(
        detect_s=detect_s, plan_s=0.0,
        respawn_s=RESPAWN_HIERARCHICAL_S,
        migrate_s=migrate_seconds(state_bytes, tier),
        recompute_s=lost_work_seconds(tier, avg_iter_s))


def estimate_redundant() -> TransitionCost:
    """Redundancy-based continuation: surviving replicas absorb the work
    with zero stoppage — the price is the standing EFFICIENCY tax, not a
    transition."""
    return TransitionCost(0.0, 0.0, 0.0, 0.0, 0.0)


# ---------------------------------------------------------------------------
# Array-native transition model: per-policy cost matrices for the batched
# simulator.  Rows reproduce the scalar ``estimate_*`` components exactly.
# ---------------------------------------------------------------------------

COMPONENTS = ("detect", "plan", "respawn", "migrate", "recompute")

# which scalar estimate a recovery policy maps to (the §7.3 behaviours the
# simulator encodes): unicron -> estimate_unicron; megatron/varuna ->
# checkpoint restart; oobleck/bamboo -> dynamic reconfiguration; the
# modern-recovery peers map to their dedicated estimators
CKPT_RESTART_POLICIES = frozenset({"megatron", "varuna"})
DYNAMIC_POLICIES = frozenset({"oobleck", "bamboo"})
FFTRAINER_POLICIES = frozenset({"fftrainer"})
HIERARCHICAL_POLICIES = frozenset({"hierarchical_ckpt"})
REDUNDANT_POLICIES = frozenset({"redundant"})


def estimate_batch(policies: Sequence[str], state_bytes, avg_iter_s,
                   dp_degree, detect_s, *, lookup_hit: bool = True,
                   inmemory_available: bool = True,
                   replica_lost=False) -> np.ndarray:
    """Transition costs for every policy as one
    (len(policies), len(COMPONENTS)) matrix.

    Each argument is a scalar or a (len(policies),) vector — owners (and
    so state sizes, iteration times, DP degrees, detection latencies and
    replica-loss flags) differ per policy once trajectories diverge.
    Row p equals the ``TransitionCost`` the scalar path computes for
    that policy: ``estimate_unicron`` for ``"unicron"``,
    checkpoint-restart ``estimate_baseline`` for megatron/varuna,
    dynamic-reconfiguration ``estimate_baseline`` for oobleck/bamboo,
    ``estimate_fftrainer`` / ``estimate_hierarchical`` /
    ``estimate_redundant`` for the modern-recovery peers — same formulas
    applied elementwise, so every cell is bitwise-identical to the
    scalar call.  (Bamboo's ride-through of SEV2/3 failures, fftrainer's
    spare-pool bookkeeping and redundant's capacity degradation are
    engine-level rules on top of this matrix, as in the scalar
    simulator.)"""
    P = len(policies)
    shape = (P,)
    sb = np.broadcast_to(np.asarray(state_bytes, dtype=float), shape)
    avg = np.broadcast_to(np.asarray(avg_iter_s, dtype=float), shape)
    dp = np.broadcast_to(np.asarray(dp_degree, dtype=np.int64), shape)
    det = np.broadcast_to(np.asarray(detect_s, dtype=float), shape)
    rl = np.broadcast_to(np.asarray(replica_lost, dtype=bool), shape)
    is_uni = np.array([p == "unicron" for p in policies])
    is_ckpt = np.array([p in CKPT_RESTART_POLICIES for p in policies])
    is_dyn = np.array([p in DYNAMIC_POLICIES for p in policies])
    is_fft = np.array([p in FFTRAINER_POLICIES for p in policies])
    is_hier = np.array([p in HIERARCHICAL_POLICIES for p in policies])
    is_red = np.array([p in REDUNDANT_POLICIES for p in policies])
    unknown = ~(is_uni | is_ckpt | is_dyn | is_fft | is_hier | is_red)
    if unknown.any():
        bad = [p for p, u in zip(policies, unknown) if u]
        raise ValueError(f"unknown recovery policies {bad}")
    out = np.empty((P, len(COMPONENTS)))
    out[:, 0] = det
    # plan: O(1) lookup (or fresh solve) for unicron, a solve for dynamic
    # reconfigurators, nothing for checkpoint restarts / modern peers
    out[:, 1] = np.where(is_uni,
                         PLAN_LOOKUP_S if lookup_hit else PLAN_SOLVE_S,
                         np.where(is_dyn, PLAN_SOLVE_S, 0.0))
    out[:, 2] = np.where(
        is_uni, RESPAWN_UNICRON_S,
        np.where(is_dyn, 90.0,
                 np.where(is_fft, FFTRAINER_FAILOVER_S,
                          np.where(is_hier, RESPAWN_HIERARCHICAL_S,
                                   RESPAWN_BASELINE_S))))
    # migrate: nearest surviving tier for unicron (replica loss demotes a
    # dp==1 restore to persistent), persistent for ckpt restart, dp
    # replica for dynamic reconfiguration and fftrainer failover, the
    # in-memory ring (or persistent on replica loss) for tiered restore
    uni_pers = ~(dp > 1) & (rl | (not inmemory_available))
    uni_bw = np.where(dp > 1, BW_DP_REPLICA,
                      np.where(uni_pers, BW_PERSISTENT, BW_INMEMORY))
    hier_bw = np.where(rl, BW_PERSISTENT, BW_INMEMORY)
    out[:, 3] = sb / np.where(
        is_uni, uni_bw,
        np.where(is_dyn | is_fft, BW_DP_REPLICA,
                 np.where(is_hier, hier_bw, BW_PERSISTENT)))
    # recompute: lost_work_seconds per tier, elementwise
    uni_rec = np.where(
        dp > 1, 0.5 * avg * (1.0 + 1.0 / np.maximum(dp - 1, 1)),
        np.where(uni_pers, 0.5 * CKPT_INTERVAL_S,
                 0.5 * avg * (INMEMORY_SNAPSHOT_ITERS + 1.0)))
    hier_rec = np.where(rl, 0.5 * CKPT_INTERVAL_S,
                        0.5 * avg * (INMEMORY_SNAPSHOT_ITERS + 1.0))
    out[:, 4] = np.where(
        is_uni, uni_rec,
        np.where(is_dyn, 60.0,
                 np.where(is_fft, 0.5 * avg,
                          np.where(is_hier, hier_rec,
                                   MEAN_RECOMPUTE_BASELINE_S))))
    # redundant continuation: every component is zero (the cost is the
    # engines' standing EFFICIENCY tax)
    out[is_red] = 0.0
    return out


def batch_total(costs: np.ndarray) -> np.ndarray:
    """Per-policy totals of an ``estimate_batch`` matrix, summed in the
    scalar ``TransitionCost.total`` component order (left to right) so
    the floats match the scalar property exactly."""
    total = costs[..., 0]
    for c in range(1, costs.shape[-1]):
        total = total + costs[..., c]
    return total


# ---------------------------------------------------------------------------
# Real state migration (examples / integration tests)
# ---------------------------------------------------------------------------


def migrate_state(manager: CheckpointManager, rank: int, like,
                  dp_peer_state=None, peer_step: Optional[int] = None
                  ) -> Tuple[object, int, str]:
    """Fetch recovery state through the hierarchy; returns
    (state, step, source)."""
    return manager.restore(rank, like, dp_peer_state=dp_peer_state,
                           peer_step=peer_step)
