"""Transition strategy (§6) — duration model + state migration.

``TransitionCost`` estimates the seconds a task spends transitioning under
each policy; the components mirror Figure 2 / §7.3:

  detect -> (plan lookup) -> process respawn -> state migration
        -> partial-iteration recompute -> resume

State migration follows the nearest principle (§6.3): DP replica over the
fast interconnect, else GEMINI in-memory checkpoint over host DRAM/network,
else the remote persistent store.  ``migrate_state`` performs the real
migration via CheckpointManager; ``estimate_*`` provides the simulator's
timing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.manager import CheckpointManager

# ---------------------------------------------------------------------------
# Timing constants (paper §1, §7 and GEMINI-reported bandwidths)
# ---------------------------------------------------------------------------

RESPAWN_UNICRON_S = 30.0            # warm process respawn inside agent
RESPAWN_BASELINE_S = (9 + 14) * 60.0  # resubmit (9min) + env/CUDA (14min)
PLAN_LOOKUP_S = 0.1                 # O(1) lookup-table dispatch
PLAN_SOLVE_S = 2.0                  # fresh O(mn^2) solve

BW_DP_REPLICA = 150e9               # bytes/s — fast interconnect replicate
BW_INMEMORY = 25e9                  # bytes/s — host RAM / neighbor fetch
BW_PERSISTENT = 20e9                # bytes/s — cloud FS (paper: 20 GB/s)

CKPT_INTERVAL_S = 30 * 60.0         # baseline checkpoint interval
MEAN_RECOMPUTE_BASELINE_S = 15 * 60.0  # paper footnote 2


@dataclass(frozen=True)
class TransitionCost:
    detect_s: float
    plan_s: float
    respawn_s: float
    migrate_s: float
    recompute_s: float

    @property
    def total(self) -> float:
        return (self.detect_s + self.plan_s + self.respawn_s
                + self.migrate_s + self.recompute_s)


def migration_source(dp_degree: int, inmemory_available: bool) -> str:
    """Nearest principle: healthy DP replica -> in-memory ckpt ->
    persistent ckpt."""
    if dp_degree > 1:
        return "dp_replica"
    if inmemory_available:
        return "inmemory"
    return "persistent"


def migrate_seconds(state_bytes: float, source: str) -> float:
    bw = {"dp_replica": BW_DP_REPLICA, "inmemory": BW_INMEMORY,
          "persistent": BW_PERSISTENT}[source]
    return state_bytes / bw


def estimate_unicron(state_bytes: float, avg_iter_s: float,
                     dp_degree: int, detect_s: float,
                     inmemory_available: bool = True,
                     lookup_hit: bool = True) -> TransitionCost:
    """Unicron: partial-results reuse means recompute <= one iteration
    (expected half of the in-flight iteration's work is redone by
    survivors, amortized across them)."""
    src = migration_source(dp_degree, inmemory_available)
    recompute = 0.5 * avg_iter_s * (1.0 + 1.0 / max(dp_degree - 1, 1))
    return TransitionCost(
        detect_s=detect_s,
        plan_s=PLAN_LOOKUP_S if lookup_hit else PLAN_SOLVE_S,
        respawn_s=RESPAWN_UNICRON_S,
        migrate_s=migrate_seconds(state_bytes, src),
        recompute_s=recompute)


def estimate_baseline(state_bytes: float, detect_s: float, *,
                      dynamic_reconfig: bool,
                      ckpt_restart: bool) -> TransitionCost:
    """Baselines (§7.3):
    * Megatron / Varuna: full restart from the persistent checkpoint +
      mean 15 min recompute.
    * Oobleck / Bamboo: dynamic reconfiguration — no checkpoint reload,
      but they restart the iteration (lose in-flight work) and pay a
      coordination respawn.
    """
    if ckpt_restart:
        return TransitionCost(
            detect_s=detect_s, plan_s=0.0,
            respawn_s=RESPAWN_BASELINE_S,
            migrate_s=migrate_seconds(state_bytes, "persistent"),
            recompute_s=MEAN_RECOMPUTE_BASELINE_S)
    # dynamic reconfiguration without Unicron's partial-result reuse
    return TransitionCost(
        detect_s=detect_s, plan_s=PLAN_SOLVE_S,
        respawn_s=90.0 if dynamic_reconfig else RESPAWN_BASELINE_S,
        migrate_s=migrate_seconds(state_bytes, "dp_replica"),
        recompute_s=60.0)


# ---------------------------------------------------------------------------
# Array-native transition model: per-policy cost matrices for the batched
# simulator.  Rows reproduce the scalar ``estimate_*`` components exactly.
# ---------------------------------------------------------------------------

COMPONENTS = ("detect", "plan", "respawn", "migrate", "recompute")

# which scalar estimate a recovery policy maps to (the §7.3 behaviours the
# simulator encodes): unicron -> estimate_unicron; megatron/varuna ->
# checkpoint restart; oobleck/bamboo -> dynamic reconfiguration
CKPT_RESTART_POLICIES = frozenset({"megatron", "varuna"})
DYNAMIC_POLICIES = frozenset({"oobleck", "bamboo"})


def estimate_batch(policies: Sequence[str], state_bytes, avg_iter_s,
                   dp_degree, detect_s, *, lookup_hit: bool = True,
                   inmemory_available: bool = True) -> np.ndarray:
    """Transition costs for every policy as one
    (len(policies), len(COMPONENTS)) matrix.

    Each argument is a scalar or a (len(policies),) vector — owners (and
    so state sizes, iteration times, DP degrees and detection latencies)
    differ per policy once trajectories diverge.  Row p equals the
    ``TransitionCost`` the scalar path computes for that policy:
    ``estimate_unicron`` for ``"unicron"``, checkpoint-restart
    ``estimate_baseline`` for megatron/varuna, dynamic-reconfiguration
    ``estimate_baseline`` for oobleck/bamboo — same formulas applied
    elementwise, so every cell is bitwise-identical to the scalar call.
    (Bamboo's ride-through of SEV2/3 failures is an engine-level rule on
    top of this matrix, as it is in the scalar simulator.)"""
    P = len(policies)
    shape = (P,)
    sb = np.broadcast_to(np.asarray(state_bytes, dtype=float), shape)
    avg = np.broadcast_to(np.asarray(avg_iter_s, dtype=float), shape)
    dp = np.broadcast_to(np.asarray(dp_degree, dtype=np.int64), shape)
    det = np.broadcast_to(np.asarray(detect_s, dtype=float), shape)
    is_uni = np.array([p == "unicron" for p in policies])
    is_ckpt = np.array([p in CKPT_RESTART_POLICIES for p in policies])
    is_dyn = np.array([p in DYNAMIC_POLICIES for p in policies])
    unknown = ~(is_uni | is_ckpt | is_dyn)
    if unknown.any():
        bad = [p for p, u in zip(policies, unknown) if u]
        raise ValueError(f"unknown recovery policies {bad}")
    out = np.empty((P, len(COMPONENTS)))
    out[:, 0] = det
    # plan: O(1) lookup (or fresh solve) for unicron, a solve for dynamic
    # reconfigurators, nothing for checkpoint restarts
    out[:, 1] = np.where(is_uni,
                         PLAN_LOOKUP_S if lookup_hit else PLAN_SOLVE_S,
                         np.where(is_dyn, PLAN_SOLVE_S, 0.0))
    out[:, 2] = np.where(is_uni, RESPAWN_UNICRON_S,
                         np.where(is_dyn, 90.0, RESPAWN_BASELINE_S))
    # migrate: nearest source for unicron, persistent for ckpt restart,
    # dp replica for dynamic reconfiguration (the scalar branch table)
    uni_src_dp = dp > 1
    uni_bw = np.where(uni_src_dp, BW_DP_REPLICA,
                      BW_INMEMORY if inmemory_available else BW_PERSISTENT)
    out[:, 3] = sb / np.where(is_uni, uni_bw,
                              np.where(is_dyn, BW_DP_REPLICA,
                                       BW_PERSISTENT))
    out[:, 4] = np.where(
        is_uni, 0.5 * avg * (1.0 + 1.0 / np.maximum(dp - 1, 1)),
        np.where(is_dyn, 60.0, MEAN_RECOMPUTE_BASELINE_S))
    return out


def batch_total(costs: np.ndarray) -> np.ndarray:
    """Per-policy totals of an ``estimate_batch`` matrix, summed in the
    scalar ``TransitionCost.total`` component order (left to right) so
    the floats match the scalar property exactly."""
    total = costs[..., 0]
    for c in range(1, costs.shape[-1]):
        total = total + costs[..., c]
    return total


# ---------------------------------------------------------------------------
# Real state migration (examples / integration tests)
# ---------------------------------------------------------------------------


def migrate_state(manager: CheckpointManager, rank: int, like,
                  dp_peer_state=None, peer_step: Optional[int] = None
                  ) -> Tuple[object, int, str]:
    """Fetch recovery state through the hierarchy; returns
    (state, step, source)."""
    return manager.restore(rank, like, dp_peer_state=dp_peer_state,
                           peer_step=peer_step)
