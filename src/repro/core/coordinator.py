"""Unicron coordinator (§3.2) — cluster-level decisions.

Consumes agent status from the KV store, classifies failures, decides
actions (handling.py), and generates reconfiguration plans (planner.py)
over *all* tasks in the cluster.  The discrete-event simulator provides
time; every decision here is the real algorithm.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import planner, transition, waf as waf_mod
from repro.core.costmodel import Hardware
from repro.core.detection import ErrorKind, Severity, classify
from repro.core.handling import (Action, FailureCase, HandlingDecision,
                                 Trigger, decide)
from repro.core.kvstore import KVStore
from repro.core.planner import Plan, PlanInput, PlanTable
from repro.core.waf import Task


@dataclass
class TaskEntry:
    """Coordinator-side record of a running task (the 'task set')."""
    task: Task
    n_workers: int
    status: str = "running"            # running | transitioning | waiting
    avg_iter_s: float = 30.0
    state_bytes: float = 0.0


@dataclass
class PlanStats:
    """Planner-engine accounting: how long plan generation takes and how
    often failure-time dispatch was an O(1) table hit (the §5.2 claim the
    vectorized engine has to uphold at scale)."""
    table_rebuilds: int = 0
    table_rebuild_s: float = 0.0       # cumulative
    last_rebuild_s: float = 0.0
    lookup_hits: int = 0
    fresh_solves: int = 0
    fresh_solve_s: float = 0.0         # cumulative
    last_dispatch_s: float = 0.0       # latency of the last plan_for()


class UnicronCoordinator:
    def __init__(self, tasks: List[Task], assignment: List[int],
                 hw: Hardware, kv: Optional[KVStore] = None,
                 mtbf_per_worker_s: float = 30 * 86400.0,
                 d_transition_s: float = 120.0):
        self.hw = hw
        self.kv = kv or KVStore()
        self.entries: List[TaskEntry] = [
            TaskEntry(task=t, n_workers=x,
                      state_bytes=16.0 * t.model.n_params)
            for t, x in zip(tasks, assignment)]
        self.mtbf = mtbf_per_worker_s
        self.d_transition = d_transition_s
        self.open_cases: Dict[str, FailureCase] = {}
        self._table: Optional[PlanTable] = None
        self.plan_stats = PlanStats()
        self.refresh_plan_table()

    # ---- plan generation -------------------------------------------------

    def _plan_input(self, n_workers: int,
                    faulted_task: Optional[int]) -> PlanInput:
        tasks = tuple(e.task for e in self.entries)
        assignment = tuple(e.n_workers for e in self.entries)
        d_run = waf_mod.expected_run_duration(n_workers, self.mtbf)
        return PlanInput(tasks, assignment, n_workers, d_run,
                         self.d_transition,
                         tuple(i == faulted_task
                               for i in range(len(tasks))))

    def refresh_plan_table(self) -> None:
        """Precompute one-step lookahead plans (§5.2) for O(1) dispatch,
        via the incremental vectorized build (shared reward rows +
        prefix/suffix DPs)."""
        assignment = [e.n_workers for e in self.entries]
        d_run = waf_mod.expected_run_duration(sum(assignment), self.mtbf)
        t0 = time.perf_counter()
        self._table = PlanTable([e.task for e in self.entries], assignment,
                                self.hw, d_run, self.d_transition)
        dt = time.perf_counter() - t0
        self.plan_stats.table_rebuilds += 1
        self.plan_stats.table_rebuild_s += dt
        self.plan_stats.last_rebuild_s = dt

    def plan_for(self, n_workers: int, faulted_task: Optional[int],
                 lookup_key: Optional[str] = None) -> Tuple[Plan, bool]:
        """Returns (plan, was_lookup_hit)."""
        t0 = time.perf_counter()
        if lookup_key and self._table:
            hit = self._table.lookup(lookup_key)
            if hit is not None:
                self.plan_stats.lookup_hits += 1
                self.plan_stats.last_dispatch_s = time.perf_counter() - t0
                return hit, True
        plan = planner.solve(self._plan_input(n_workers, faulted_task),
                             self.hw)
        dt = time.perf_counter() - t0
        self.plan_stats.fresh_solves += 1
        self.plan_stats.fresh_solve_s += dt
        self.plan_stats.last_dispatch_s = dt
        return plan, False

    # ---- error handling ----------------------------------------------------

    def on_error(self, case_id: str, kind: ErrorKind) -> HandlingDecision:
        case = self.open_cases.get(case_id)
        if case is None:
            case = FailureCase.from_kind(kind)
            self.open_cases[case_id] = case
        return decide(case)

    def on_action_failed(self, case_id: str) -> HandlingDecision:
        """Escalate SEV3 -> SEV2 -> SEV1 (Figure 7)."""
        case = self.open_cases[case_id]
        case.record_failure()
        return decide(case)

    def close_case(self, case_id: str) -> None:
        self.open_cases.pop(case_id, None)

    # ---- reconfiguration entry points (Figure 7 triggers 3..6) -----------

    def reconfigure(self, n_workers_now: int,
                    faulted_task: Optional[int] = None,
                    trigger: Trigger = Trigger.ERROR) -> Plan:
        key = None
        if trigger is Trigger.ERROR and faulted_task is not None:
            key = f"fault:{faulted_task}"
        elif trigger is Trigger.NODE_JOIN:
            key = "join:1"
        t0 = time.perf_counter()
        plan, hit = self.plan_for(n_workers_now, faulted_task, key)
        if hit and sum(plan.assignment) > n_workers_now:
            # precomputed scenario does not match reality: fresh solve.
            # The discarded hit was not a usable dispatch — uncount it and
            # charge the whole lookup-plus-solve to this dispatch.
            self.plan_stats.lookup_hits -= 1
            plan, _ = self.plan_for(n_workers_now, faulted_task, None)
            self.plan_stats.last_dispatch_s = time.perf_counter() - t0
        for e, x in zip(self.entries, plan.assignment):
            e.n_workers = x
        self.refresh_plan_table()
        return plan

    # ---- accounting --------------------------------------------------------

    def cluster_waf(self) -> float:
        return sum(waf_mod.waf(e.task, e.n_workers, self.hw)
                   for e in self.entries if e.status == "running")
