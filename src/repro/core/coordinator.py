"""Unicron coordinator (§3.2) — cluster-level decisions.

Consumes agent status from the KV store, classifies failures, decides
actions (handling.py), and generates reconfiguration plans (planner.py)
over *all* tasks in the cluster.  The discrete-event simulator provides
time; every decision here is the real algorithm.

Crash-recovery: the coordinator journals its durable state — task set,
per-task assignment/status, plan epoch, and open failure cases — to
``/coord/journal/*`` in the status monitor on every mutation, and
``UnicronCoordinator.recover(kv, hw, ...)`` rebuilds an equivalent
coordinator (entries, epoch, cases, and a refreshed ``PlanTable``) from
that journal after a crash.  Each instance claims an incarnation epoch
under ``/coord/incarnation`` at construction; journal and plan-epoch
writes are fenced on it, so a deposed predecessor that wakes up after a
recovery raises ``StaleCoordinatorError`` instead of shadowing its
successor's state.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core import planner, waf as waf_mod
from repro.core.costmodel import Hardware
from repro.core.detection import ErrorKind, Severity
from repro.core.handling import FailureCase, HandlingDecision, Trigger, decide
from repro.core.kvstore import KVStore, PLAN_EPOCH_KEY
from repro.core.planner import Plan, PlanInput, PlanTable
from repro.core.waf import Task

# Coordinator journal: rewritten in full on every mutation (task churn,
# reconfiguration, case open/close).  Small — O(tasks + open cases) —
# so full rewrite beats a log that would need compaction.
JOURNAL_TASKS_KEY = "/coord/journal/tasks"
JOURNAL_EPOCH_KEY = "/coord/journal/epoch"
JOURNAL_CASES_KEY = "/coord/journal/cases"
INCARNATION_KEY = "/coord/incarnation"


class StaleCoordinatorError(RuntimeError):
    """A deposed coordinator incarnation tried to write journaled state
    after a successor claimed the incarnation key (fencing, §3.2)."""


@dataclass
class TaskEntry:
    """Coordinator-side record of a running task (the 'task set')."""
    task: Task
    n_workers: int
    status: str = "running"            # running | transitioning | waiting
    avg_iter_s: float = 30.0
    state_bytes: float = 0.0


@dataclass
class PlanStats:
    """Planner-engine accounting: how long plan generation takes and how
    often failure-time dispatch was an O(1) table hit (the §5.2 claim the
    vectorized engine has to uphold at scale).

    The ``batched_*``/``lazy_tracebacks`` counters mirror the batched
    PlanTable engine's ``batch_stats``: tree/complement levels merged,
    stacked max-plus kernel launches issued, and plans materialized by
    on-demand argmax traceback.  They accumulate the deltas observed
    through THIS coordinator's table handle — under a cache-shared table
    another coordinator's work lands on whichever handle reads it first,
    so sums over all coordinators remain exact."""
    table_rebuilds: int = 0
    table_rebuild_s: float = 0.0       # cumulative
    last_rebuild_s: float = 0.0
    lookup_hits: int = 0
    fresh_solves: int = 0
    fresh_solve_s: float = 0.0         # cumulative
    last_dispatch_s: float = 0.0       # latency of the last plan_for()
    task_launches: int = 0
    task_finishes: int = 0
    batched_levels: int = 0            # level-synchronous merge sweeps
    batched_launches: int = 0          # stacked max-plus kernel launches
    lazy_tracebacks: int = 0           # plans materialized by traceback
    device_dispatches: int = 0         # fused-engine compiled programs run


class UnicronCoordinator:
    def __init__(self, tasks: List[Task], assignment: List[int],
                 hw: Hardware, kv: Optional[KVStore] = None,
                 mtbf_per_worker_s: float = 30 * 86400.0,
                 d_transition_s: float = 120.0,
                 plan_cache: Optional[planner.PlannerCache] = None,
                 n_cluster_workers: Optional[int] = None,
                 workers_per_node: int = 8,
                 plan_engine: str = "batched",
                 prebuild_scenarios: bool = False,
                 journal: bool = True):
        """``plan_cache``: share a ``PlannerCache`` across coordinators —
        plan tables become lazy (scenarios assembled on first lookup) and
        rows/prefix-suffix DPs/solves are reused across rebuilds, with
        plans float-identical to the eager uncached build.

        ``n_cluster_workers``: total cluster capacity.  When given,
        D_running (Eq. 3) is the expected time to the next failure of the
        WHOLE cluster — failures arrive per node over the full fleet, not
        just the assigned workers — and the planner's DP arrays are sized
        once for that capacity, which keeps plan values comparable (and
        cache keys identical) across rebuilds at different totals.

        ``plan_engine``: incremental PlanTable engine — ``"batched"``
        (default: level-synchronous stacked merges, value-only assembly,
        lazy traceback), ``"fused"`` (the whole-table value rebuild
        compiled into ONE jitted ``lax.scan`` dispatch; same-signature
        churn reuses the cached program, ``device_dispatches`` counts
        the executions), ``"segtree"`` (dyadic segment tree, O(log m)
        churn invalidation, one kernel call per merge) or ``"chain"``
        (the PR-2 prefix/suffix chains).  ``prebuild_scenarios``
        composes with any of them.

        ``prebuild_scenarios``: run the whole-table value rebuild on
        every plan-table refresh (including the churn triggers, where the
        task set shifts and ANY scenario may fire next) — on the batched
        engine a constant number of stacked launches per tree level, so
        every subsequent dispatch is a memo read plus one lazy traceback.
        Off by default: the Monte-Carlo engines keep lazy tables (most
        intermediate states are never consulted).

        ``journal``: persist task set / epoch / open cases to
        ``/coord/journal/*`` on every mutation so ``recover`` can rebuild
        this coordinator after a crash.  On by default; benchmarks turn
        it off to measure the journaling overhead."""
        self.hw = hw
        # normalize through the registry so legacy spellings resolve (and
        # typos fail) at construction, not at the first reconfigure
        self.plan_engine = planner.resolve_engine(plan_engine)
        self.prebuild_scenarios = prebuild_scenarios
        self.kv = kv or KVStore()
        self.journal = journal
        # claim the incarnation: any still-running predecessor is deposed
        # and its next fenced write raises StaleCoordinatorError
        self.incarnation = int(self.kv.get(INCARNATION_KEY, 0)) + 1
        self.kv.put(INCARNATION_KEY, self.incarnation)
        self.entries: List[TaskEntry] = [
            TaskEntry(task=t, n_workers=x,
                      state_bytes=waf_mod.state_bytes(t))
            for t, x in zip(tasks, assignment)]
        self.mtbf = mtbf_per_worker_s
        self.d_transition = d_transition_s
        self.n_cluster = n_cluster_workers
        self.workers_per_node = workers_per_node
        self.open_cases: Dict[str, FailureCase] = {}
        self._table: Optional[PlanTable] = None
        self.plan_cache = plan_cache
        self._tids: Optional[Tuple[int, ...]] = None   # interned task ids
        self._intern_tasks()
        self.plan_stats = PlanStats()
        # batched-engine counter baseline: the table handle last synced
        # and its batch_stats snapshot at that point (cache-shared tables
        # may arrive pre-warmed; only deltas seen through this handle
        # count toward plan_stats)
        self._bstats_src: Optional[PlanTable] = None
        self._bstats_seen: Dict[str, int] = {}
        self.plan_epoch = 0
        self._fenced_put(PLAN_EPOCH_KEY, self.plan_epoch)
        self.refresh_plan_table()
        self._journal_tasks()
        self._journal_cases()

    def _intern_tasks(self) -> None:
        """Re-intern the task set in the shared plan cache (churn only):
        per-event table refreshes then reuse the tuple instead of hashing
        every task object again."""
        if self.plan_cache is not None:
            self._tids = tuple(self.plan_cache.task_id(e.task)
                               for e in self.entries)

    def _bump_epoch(self) -> None:
        """The task set changed: indices in in-flight churn reports are
        stale.  Publish the new epoch so agents stamp future reports."""
        self.plan_epoch += 1
        self._fenced_put(PLAN_EPOCH_KEY, self.plan_epoch)

    # ---- journaling + incarnation fence (crash-recovery) -------------------

    def _fenced_put(self, key: str, value) -> None:
        """Write-through guarded by the incarnation fence: a coordinator
        whose incarnation was superseded must not touch shared state."""
        if int(self.kv.get(INCARNATION_KEY, self.incarnation)) \
                != self.incarnation:
            raise StaleCoordinatorError(
                f"incarnation {self.incarnation} deposed; refusing {key}")
        self.kv.put(key, value)

    def _journal_tasks(self) -> None:
        """Persist the task set + assignment + plan epoch.  Called after
        every mutation, OUTSIDE the timed dispatch windows so
        ``last_dispatch_s`` measures planning, not persistence."""
        if not self.journal:
            return
        self._fenced_put(JOURNAL_TASKS_KEY, tuple(
            (e.task, e.n_workers, e.status, e.avg_iter_s, e.state_bytes)
            for e in self.entries))
        self._fenced_put(JOURNAL_EPOCH_KEY, self.plan_epoch)

    def _journal_cases(self) -> None:
        if not self.journal:
            return
        self._fenced_put(JOURNAL_CASES_KEY, {
            cid: (c.kind.value, int(c.severity), c.attempts)
            for cid, c in self.open_cases.items()})

    @classmethod
    def recover(cls, kv: KVStore, hw: Hardware,
                **kwargs) -> "UnicronCoordinator":
        """Rebuild a coordinator from the ``/coord/journal/*`` keys after
        a crash: task entries (with statuses and iteration stats), plan
        epoch, open failure cases, and a refreshed ``PlanTable``.  Claims
        a new incarnation, fencing out the crashed predecessor should it
        wake up again.  ``kwargs`` forward to the constructor (plan
        cache, cluster capacity, engine, ...)."""
        journaled = kv.get(JOURNAL_TASKS_KEY)
        if journaled is None:
            raise RuntimeError("no coordinator journal to recover from")
        # snapshot epoch + cases BEFORE constructing: __init__ journals
        # its own fresh state (epoch 0, no cases) and would clobber them
        epoch = int(kv.get(JOURNAL_EPOCH_KEY, 0))
        cases = dict(kv.get(JOURNAL_CASES_KEY) or {})
        tasks = [t for t, *_ in journaled]
        assignment = [int(x) for _, x, *_ in journaled]
        coord = cls(tasks, assignment, hw, kv=kv, **kwargs)
        for e, (_, _, status, avg_iter_s, state_bytes) in zip(coord.entries,
                                                              journaled):
            e.status = status
            e.avg_iter_s = avg_iter_s
            e.state_bytes = state_bytes
        coord.plan_epoch = epoch
        coord._fenced_put(PLAN_EPOCH_KEY, coord.plan_epoch)
        for cid, (kind, sev, attempts) in cases.items():
            coord.open_cases[cid] = FailureCase(kind=ErrorKind(kind),
                                                severity=Severity(sev),
                                                attempts=attempts)
        coord._journal_tasks()
        coord._journal_cases()
        return coord

    def restore_assignment(self, assignment) -> None:
        """Re-apply an exact previously-dispatched assignment (the control
        loop's false-positive-drain rollback).  Not a planner decision —
        no epoch bump (the task set is unchanged) and no dispatch stats;
        the plan table is refreshed for the restored state."""
        for e, x in zip(self.entries, assignment):
            e.n_workers = int(x)
        self.refresh_plan_table()
        self._journal_tasks()

    def _d_running(self, n_workers: int) -> float:
        return waf_mod.expected_run_duration(self.n_cluster or n_workers,
                                             self.mtbf)

    def _adopt_table(self, table: Optional[PlanTable],
                     fresh: bool) -> None:
        """Set the batched-counter baseline for a newly acquired table
        handle: zeros when this coordinator just built it (all its work
        is ours), the current snapshot when it came warm out of a shared
        cache (prior work belongs to whoever did it)."""
        stats = getattr(table, "batch_stats", None)
        if stats is None or self._bstats_src is table:
            return
        self._bstats_src = table
        self._bstats_seen = ({k: 0 for k in stats} if fresh
                             else dict(stats))

    def _sync_batch_stats(self) -> None:
        """Fold the table's batched-engine counters into ``plan_stats``
        (delta since this coordinator last read this table handle)."""
        table = self._table
        stats = getattr(table, "batch_stats", None)
        if stats is None or self._bstats_src is not table:
            return
        seen = self._bstats_seen
        self.plan_stats.batched_levels += stats["levels"] - seen["levels"]
        self.plan_stats.batched_launches += (stats["launches"]
                                             - seen["launches"])
        self.plan_stats.lazy_tracebacks += (stats["tracebacks"]
                                            - seen["tracebacks"])
        self.plan_stats.device_dispatches += (
            stats.get("device_dispatches", 0)
            - seen.get("device_dispatches", 0))
        self._bstats_seen = dict(stats)

    # ---- plan generation -------------------------------------------------

    def _plan_input(self, n_workers: int,
                    faulted_task: Optional[int]) -> PlanInput:
        tasks = tuple(e.task for e in self.entries)
        assignment = tuple(e.n_workers for e in self.entries)
        return PlanInput(tasks, assignment, n_workers,
                         self._d_running(n_workers), self.d_transition,
                         tuple(i == faulted_task
                               for i in range(len(tasks))))

    def refresh_plan_table(self) -> None:
        """Precompute one-step lookahead plans (§5.2) for O(1) dispatch,
        via the incremental vectorized build (shared reward rows +
        prefix/suffix DPs).  With a ``plan_cache`` the table is lazy and
        chain-cached across rebuilds: a recurring cluster state costs a
        dict hit, a near state only the chains past the change."""
        assignment = [e.n_workers for e in self.entries]
        d_run = self._d_running(sum(assignment))
        w = self.workers_per_node
        n_budget = (self.n_cluster + w) if self.n_cluster else None
        t0 = time.perf_counter()
        tasks = [e.task for e in self.entries]
        if self.plan_cache is not None:
            self._table = self.plan_cache.table(tasks, assignment, self.hw,
                                                d_run, self.d_transition,
                                                workers_per_fault=w,
                                                n_budget=n_budget,
                                                engine=self.plan_engine,
                                                task_ids=self._tids)
            self._adopt_table(self._table, fresh=False)
        else:
            self._table = PlanTable(tasks, assignment, self.hw, d_run,
                                    self.d_transition,
                                    workers_per_fault=w,
                                    n_budget=n_budget,
                                    engine=self.plan_engine)
            self._adopt_table(self._table, fresh=True)
        if self.prebuild_scenarios:
            self._table.rebuild_values()
        self._sync_batch_stats()
        dt = time.perf_counter() - t0
        self.plan_stats.table_rebuilds += 1
        self.plan_stats.table_rebuild_s += dt
        self.plan_stats.last_rebuild_s = dt

    def plan_for(self, n_workers: int, faulted_task: Optional[int],
                 lookup_key: Optional[str] = None) -> Tuple[Plan, bool]:
        """Returns (plan, was_lookup_hit)."""
        t0 = time.perf_counter()
        if lookup_key and self._table:
            hit = self._table.lookup(lookup_key)
            self._sync_batch_stats()
            if hit is not None:
                self.plan_stats.lookup_hits += 1
                self.plan_stats.last_dispatch_s = time.perf_counter() - t0
                return hit, True
        plan = self._fresh_plan(n_workers, faulted_task)
        self.plan_stats.last_dispatch_s = time.perf_counter() - t0
        return plan, False

    # ---- error handling ----------------------------------------------------

    def on_error(self, case_id: str, kind: ErrorKind) -> HandlingDecision:
        case = self.open_cases.get(case_id)
        if case is None:
            case = FailureCase.from_kind(kind)
            self.open_cases[case_id] = case
            self._journal_cases()
        return decide(case)

    def on_action_failed(self, case_id: str) -> HandlingDecision:
        """Escalate SEV3 -> SEV2 -> SEV1 (Figure 7)."""
        case = self.open_cases[case_id]
        case.record_failure()
        self._journal_cases()
        return decide(case)

    def close_case(self, case_id: str) -> None:
        if self.open_cases.pop(case_id, None) is not None:
            self._journal_cases()

    # ---- reconfiguration entry points (Figure 7 triggers 3..6) -----------

    def reconfigure(self, n_workers_now: int,
                    faulted_task: Optional[int] = None,
                    trigger: Trigger = Trigger.ERROR) -> Plan:
        key = None
        if trigger is Trigger.ERROR and faulted_task is not None:
            key = f"fault:{faulted_task}"
        elif trigger is Trigger.NODE_JOIN:
            key = "join:1"
        t0 = time.perf_counter()
        plan, hit = self.plan_for(n_workers_now, faulted_task, key)
        if hit and sum(plan.assignment) > n_workers_now:
            # precomputed scenario does not match reality: fresh solve.
            # The discarded hit was not a usable dispatch — uncount it and
            # charge the whole lookup-plus-solve to this dispatch.
            self.plan_stats.lookup_hits -= 1
            plan, _ = self.plan_for(n_workers_now, faulted_task, None)
            self.plan_stats.last_dispatch_s = time.perf_counter() - t0
        for e, x in zip(self.entries, plan.assignment):
            e.n_workers = x
        self.refresh_plan_table()
        self._journal_tasks()
        return plan

    # ---- task churn (Figure 7 triggers 5 and 6) ---------------------------

    def _fresh_plan(self, n_workers_now: int,
                    faulted_task: Optional[int] = None) -> Plan:
        """Single fresh-dispatch path: memoized ``solve_fast`` under a
        plan cache, plain ``solve`` otherwise, with solve-time stats."""
        t0 = time.perf_counter()
        inp = self._plan_input(n_workers_now, faulted_task)
        if self.plan_cache is not None:
            plan = self.plan_cache.solve(inp, self.hw)
        else:
            plan = planner.solve(inp, self.hw)
        self.plan_stats.fresh_solves += 1
        self.plan_stats.fresh_solve_s += time.perf_counter() - t0
        return plan

    def task_finished(self, task_index: int, n_workers_now: int) -> Plan:
        """Trigger (5): the finished task's workers return to the pool and
        the remaining tasks are replanned — lookup table first (the
        ``finish:i`` scenario), fresh solve on a scenario mismatch."""
        t0 = time.perf_counter()
        plan = None
        if self._table is not None:
            cand = self._table.lookup(f"finish:{task_index}")
            self._sync_batch_stats()
            if cand is not None and sum(cand.assignment) <= n_workers_now:
                plan = cand
                self.plan_stats.lookup_hits += 1
        self.entries.pop(task_index)
        self._intern_tasks()
        self._bump_epoch()
        if plan is None:
            plan = self._fresh_plan(n_workers_now)
        for e, x in zip(self.entries, plan.assignment):
            e.n_workers = x
        self.plan_stats.task_finishes += 1
        self.plan_stats.last_dispatch_s = time.perf_counter() - t0
        self.refresh_plan_table()
        self._journal_tasks()
        return plan

    def task_updated(self, task_index: int, task: Task) -> None:
        """Reward-only task swap (a serving task's offered load stepped —
        ``scenarios.RateChangeEvent``): workers stay put, nothing is
        dispatched and no epoch bump (slot indices are unchanged, so
        in-flight churn reports stay valid).  The entry's task and
        transition payload are replaced and the lookahead table refreshed
        so the NEXT trigger plans against the updated reward rows."""
        e = self.entries[task_index]
        e.task = task
        e.state_bytes = waf_mod.state_bytes(task)
        self._intern_tasks()
        self.refresh_plan_table()
        self._journal_tasks()

    def task_launched(self, task: Task, n_workers_now: int,
                      avg_iter_s: float = 30.0) -> Plan:
        """Trigger (6): admit a task (x_old = 0) and replan the whole
        cluster.  There is no precomputed scenario for launches, so this
        is always a fresh solve (memoized under a plan cache)."""
        self.entries.append(TaskEntry(task=task, n_workers=0,
                                      avg_iter_s=avg_iter_s,
                                      state_bytes=waf_mod.state_bytes(task)))
        self._intern_tasks()
        self._bump_epoch()
        t0 = time.perf_counter()
        plan = self._fresh_plan(n_workers_now)
        for e, x in zip(self.entries, plan.assignment):
            e.n_workers = x
        self.plan_stats.task_launches += 1
        self.plan_stats.last_dispatch_s = time.perf_counter() - t0
        self.refresh_plan_table()
        self._journal_tasks()
        return plan

    # ---- accounting --------------------------------------------------------

    def cluster_waf(self) -> float:
        return sum(waf_mod.waf(e.task, e.n_workers, self.hw)
                   for e in self.entries if e.status == "running")
