"""Analytic throughput model T(t, x) — achieved aggregate FLOP/s of a task
on x workers (§5.1).

The paper calibrates T(t,x) by profiling tasks on the cluster and using
automatic execution-plan search (Alpa [55]) for the optimal parallelism
settings.  We reproduce that with a Megatron-style analytic model: for a
given worker count we enumerate (dp, tp, pp) configurations, check memory
feasibility, estimate iteration time from compute + TP/PP/DP communication
terms, and take the best.  This exhibits the paper's Figure-4 phenomena:
non-linear and occasionally *non-monotonic* aggregate FLOP/s in x (awkward
worker counts force worse configurations or idle workers).

Two hardware presets: A800 (the paper's testbed) and TPU v5e (our target);
all experiments record which preset they used.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Tuple

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # per worker, FLOP/s (bf16)
    hbm_bytes: float           # per worker
    hbm_bw: float              # bytes/s
    intra_bw: float            # bytes/s per worker, fast domain (NVLink/ICI)
    inter_bw: float            # bytes/s per worker, slow domain (RoCE/DCN)
    intra_size: int            # workers per fast domain (node / ICI pod)
    compute_eff: float         # achievable fraction of peak on matmuls


A800 = Hardware(name="A800", peak_flops=312e12, hbm_bytes=80e9,
                hbm_bw=2.0e12, intra_bw=200e9, inter_bw=12.5e9,
                intra_size=8, compute_eff=0.62)

# TPU v5e chip; ICI is the fast domain (full pod), DCN the slow one.
TPU_V5E = Hardware(name="TPUv5e", peak_flops=197e12, hbm_bytes=16e9,
                   hbm_bw=819e9, intra_bw=50e9, inter_bw=6.25e9,
                   intra_size=256, compute_eff=0.60)


@dataclass(frozen=True)
class TaskModel:
    """Static description of a training task for the cost model."""
    name: str
    n_params: float            # N
    n_layers: int
    d_model: int
    seq_len: int = 2048
    global_batch: int = 512

    @classmethod
    def from_arch(cls, cfg: ArchConfig, seq_len: int = 2048,
                  global_batch: int = 512) -> "TaskModel":
        return cls(name=cfg.name, n_params=float(cfg.param_count()),
                   n_layers=cfg.n_layers, d_model=cfg.d_model,
                   seq_len=seq_len, global_batch=global_batch)


@dataclass(frozen=True)
class PlanPoint:
    """One feasible (dp, tp, pp) evaluation."""
    dp: int
    tp: int
    pp: int
    t_iter: float              # seconds
    agg_flops: float           # achieved aggregate FLOP/s
    mem_per_worker: float      # bytes


def _mem_per_worker(task: TaskModel, tp: int, pp: int, micro_b: int,
                    hw: Hardware) -> float:
    shard = task.n_params / (tp * pp)
    static = 16.0 * shard                       # bf16 w+g, fp32 m/v/master
    # activations with selective recompute, one in-flight micro-batch per
    # stage plus pipeline depth amplification
    act = (22.0 * task.seq_len * micro_b * task.d_model
           * (task.n_layers / pp) / tp) * min(pp, 4)
    return static + act


def _iter_time(task: TaskModel, dp: int, tp: int, pp: int, micro_b: int,
               hw: Hardware) -> float:
    B, S, N, L, d = (task.global_batch, task.seq_len, task.n_params,
                     task.n_layers, task.d_model)
    m = max(1, math.ceil(B / (dp * micro_b)))   # micro-batches per DP rank
    tokens = B * S
    flops = 6.0 * N * tokens
    t_comp = flops / (dp * tp * pp * hw.peak_flops * hw.compute_eff)
    # pipeline bubble
    t_comp *= (m + pp - 1) / m
    # TP collectives: 4 all-reduces per layer of (S*micro_b*d) bf16 acts,
    # ring factor 2(tp-1)/tp, over the fast domain
    if tp > 1:
        bw = hw.intra_bw if tp <= hw.intra_size else hw.inter_bw
        tp_bytes = 4 * L / pp * (2.0 * S * micro_b * d) * m
        t_tp = tp_bytes * 2 * (tp - 1) / tp / bw
    else:
        t_tp = 0.0
    # DP gradient all-reduce of the shard, slow domain (overlapped ~50%)
    if dp > 1:
        g_bytes = 2.0 * N / (tp * pp)
        workers_per_node = hw.intra_size
        bw = hw.intra_bw if dp * tp * pp <= workers_per_node else hw.inter_bw
        t_dp = 0.5 * g_bytes * 2 * (dp - 1) / dp / bw
    else:
        t_dp = 0.0
    # imbalance when dp does not divide B
    imbalance = math.ceil(B / dp) / (B / dp)
    return (t_comp + t_tp + t_dp) * imbalance


@lru_cache(maxsize=65536)
def _best_plan(task: TaskModel, x: int, hw: Hardware) -> Optional[PlanPoint]:
    if x <= 0:
        return None
    best: Optional[PlanPoint] = None
    tps = [t for t in (1, 2, 4, 8, 16) if t <= min(x, hw.intra_size)]
    for tp in tps:
        pp = 1
        while tp * pp <= x and pp <= task.n_layers:
            if task.n_layers % pp == 0:
                dp = x // (tp * pp)
                if dp >= 1 and dp <= task.global_batch:
                    for micro_b in (1, 2, 4):
                        if micro_b * dp > task.global_batch:
                            continue
                        mem = _mem_per_worker(task, tp, pp, micro_b, hw)
                        if mem > hw.hbm_bytes:
                            continue
                        t = _iter_time(task, dp, tp, pp, micro_b, hw)
                        used_flops = (6.0 * task.n_params * task.global_batch
                                      * task.seq_len) / t
                        pt = PlanPoint(dp, tp, pp, t, used_flops, mem)
                        if best is None or pt.agg_flops > best.agg_flops:
                            best = pt
            pp *= 2
    return best


def achieved_flops(task: TaskModel, x: int,
                   hw: Hardware = A800) -> float:
    """T(t, x): achieved aggregate FLOP/s with the best feasible plan,
    0.0 if no configuration fits."""
    p = _best_plan(task, x, hw)
    return 0.0 if p is None else p.agg_flops


def best_plan(task: TaskModel, x: int, hw: Hardware = A800):
    return _best_plan(task, x, hw)


def min_feasible_workers(task: TaskModel, hw: Hardware = A800,
                         upper: int = 4096) -> int:
    """Smallest x with a feasible plan (T_necessary floor)."""
    x = 1
    while x <= upper:
        if _best_plan(task, x, hw) is not None:
            return x
        x += 1
    return upper


def flops_ratio(task: TaskModel, x: int, hw: Hardware = A800) -> float:
    """Achieved fraction of the x workers' theoretical peak (Fig. 4)."""
    t = achieved_flops(task, x, hw)
    return t / (x * hw.peak_flops) if x else 0.0
