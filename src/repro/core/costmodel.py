"""Analytic throughput model T(t, x) — achieved aggregate FLOP/s of a task
on x workers (§5.1).

The paper calibrates T(t,x) by profiling tasks on the cluster and using
automatic execution-plan search (Alpa [55]) for the optimal parallelism
settings.  We reproduce that with a Megatron-style analytic model: for a
given worker count we enumerate (dp, tp, pp) configurations, check memory
feasibility, estimate iteration time from compute + TP/PP/DP communication
terms, and take the best.  This exhibits the paper's Figure-4 phenomena:
non-linear and occasionally *non-monotonic* aggregate FLOP/s in x (awkward
worker counts force worse configurations or idle workers).

Two hardware presets: A800 (the paper's testbed) and TPU v5e (our target);
all experiments record which preset they used.

Two evaluation paths share the same formulas:

* the **scalar reference** (``_best_plan`` / ``achieved_flops``), one worker
  count at a time, kept for property tests and as the ground truth;
* the **vectorized engine** (``throughput_curve``), which evaluates the whole
  feasible (dp, tp, pp, micro_b) grid for *all* worker counts ``1..n`` in one
  NumPy sweep and is memoized per ``(task, hw)`` — this is what the planner's
  reward-row construction and ``min_feasible_workers`` run on, so a plan-table
  rebuild touches the analytic model once per task instead of once per cell.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # per worker, FLOP/s (bf16)
    hbm_bytes: float           # per worker
    hbm_bw: float              # bytes/s
    intra_bw: float            # bytes/s per worker, fast domain (NVLink/ICI)
    inter_bw: float            # bytes/s per worker, slow domain (RoCE/DCN)
    intra_size: int            # workers per fast domain (node / ICI pod)
    compute_eff: float         # achievable fraction of peak on matmuls


A800 = Hardware(name="A800", peak_flops=312e12, hbm_bytes=80e9,
                hbm_bw=2.0e12, intra_bw=200e9, inter_bw=12.5e9,
                intra_size=8, compute_eff=0.62)

# TPU v5e chip; ICI is the fast domain (full pod), DCN the slow one.
TPU_V5E = Hardware(name="TPUv5e", peak_flops=197e12, hbm_bytes=16e9,
                   hbm_bw=819e9, intra_bw=50e9, inter_bw=6.25e9,
                   intra_size=256, compute_eff=0.60)


@dataclass(frozen=True)
class TaskModel:
    """Static description of a training task for the cost model."""
    name: str
    n_params: float            # N
    n_layers: int
    d_model: int
    seq_len: int = 2048
    global_batch: int = 512

    @classmethod
    def from_arch(cls, cfg: ArchConfig, seq_len: int = 2048,
                  global_batch: int = 512) -> "TaskModel":
        return cls(name=cfg.name, n_params=float(cfg.param_count()),
                   n_layers=cfg.n_layers, d_model=cfg.d_model,
                   seq_len=seq_len, global_batch=global_batch)


@dataclass(frozen=True)
class PlanPoint:
    """One feasible (dp, tp, pp) evaluation."""
    dp: int
    tp: int
    pp: int
    t_iter: float              # seconds
    agg_flops: float           # achieved aggregate FLOP/s
    mem_per_worker: float      # bytes


def _mem_per_worker(task: TaskModel, tp: int, pp: int, micro_b: int,
                    hw: Hardware) -> float:
    shard = task.n_params / (tp * pp)
    static = 16.0 * shard                       # bf16 w+g, fp32 m/v/master
    # activations with selective recompute, one in-flight micro-batch per
    # stage plus pipeline depth amplification
    act = (22.0 * task.seq_len * micro_b * task.d_model
           * (task.n_layers / pp) / tp) * min(pp, 4)
    return static + act


def _iter_time(task: TaskModel, dp: int, tp: int, pp: int, micro_b: int,
               hw: Hardware) -> float:
    B, S, N, L, d = (task.global_batch, task.seq_len, task.n_params,
                     task.n_layers, task.d_model)
    m = max(1, math.ceil(B / (dp * micro_b)))   # micro-batches per DP rank
    tokens = B * S
    flops = 6.0 * N * tokens
    t_comp = flops / (dp * tp * pp * hw.peak_flops * hw.compute_eff)
    # pipeline bubble
    t_comp *= (m + pp - 1) / m
    # TP collectives: 4 all-reduces per layer of (S*micro_b*d) bf16 acts,
    # ring factor 2(tp-1)/tp, over the fast domain
    if tp > 1:
        bw = hw.intra_bw if tp <= hw.intra_size else hw.inter_bw
        tp_bytes = 4 * L / pp * (2.0 * S * micro_b * d) * m
        t_tp = tp_bytes * 2 * (tp - 1) / tp / bw
    else:
        t_tp = 0.0
    # DP gradient all-reduce of the shard, slow domain (overlapped ~50%)
    if dp > 1:
        g_bytes = 2.0 * N / (tp * pp)
        workers_per_node = hw.intra_size
        bw = hw.intra_bw if dp * tp * pp <= workers_per_node else hw.inter_bw
        t_dp = 0.5 * g_bytes * 2 * (dp - 1) / dp / bw
    else:
        t_dp = 0.0
    # imbalance when dp does not divide B
    imbalance = math.ceil(B / dp) / (B / dp)
    return (t_comp + t_tp + t_dp) * imbalance


@lru_cache(maxsize=65536)
def _best_plan(task: TaskModel, x: int, hw: Hardware) -> Optional[PlanPoint]:
    if x <= 0:
        return None
    best: Optional[PlanPoint] = None
    tps = [t for t in (1, 2, 4, 8, 16) if t <= min(x, hw.intra_size)]
    for tp in tps:
        pp = 1
        while tp * pp <= x and pp <= task.n_layers:
            if task.n_layers % pp == 0:
                dp = x // (tp * pp)
                if dp >= 1 and dp <= task.global_batch:
                    for micro_b in (1, 2, 4):
                        if micro_b * dp > task.global_batch:
                            continue
                        mem = _mem_per_worker(task, tp, pp, micro_b, hw)
                        if mem > hw.hbm_bytes:
                            continue
                        t = _iter_time(task, dp, tp, pp, micro_b, hw)
                        used_flops = (6.0 * task.n_params * task.global_batch
                                      * task.seq_len) / t
                        pt = PlanPoint(dp, tp, pp, t, used_flops, mem)
                        if best is None or pt.agg_flops > best.agg_flops:
                            best = pt
            pp *= 2
    return best


def achieved_flops(task: TaskModel, x: int,
                   hw: Hardware = A800) -> float:
    """T(t, x): achieved aggregate FLOP/s with the best feasible plan,
    0.0 if no configuration fits."""
    p = _best_plan(task, x, hw)
    return 0.0 if p is None else p.agg_flops


def best_plan(task: TaskModel, x: int, hw: Hardware = A800):
    return _best_plan(task, x, hw)


def min_feasible_workers_reference(task: TaskModel, hw: Hardware = A800,
                                   upper: int = 4096) -> int:
    """Scalar reference: linear scan from x=1 (kept for property tests)."""
    x = 1
    while x <= upper:
        if _best_plan(task, x, hw) is not None:
            return x
        x += 1
    return upper


def flops_ratio(task: TaskModel, x: int, hw: Hardware = A800) -> float:
    """Achieved fraction of the x workers' theoretical peak (Fig. 4)."""
    t = achieved_flops(task, x, hw)
    return t / (x * hw.peak_flops) if x else 0.0


# ---------------------------------------------------------------------------
# Vectorized engine: T(t, ·) for all worker counts in one sweep
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ThroughputCurve:
    """T(t, x) for x = 0..n plus the argmax plan at every x.

    ``flops[x]`` is the achieved aggregate FLOP/s of the best feasible
    (dp, tp, pp, micro_b) configuration on x workers (0.0 when none fits);
    ``cfg[x]`` indexes into ``configs`` (-1 when infeasible).  Arrays are
    views into the memoized per-(task, hw) sweep, so slicing is free.
    """
    task: TaskModel
    hw: Hardware
    n: int
    flops: np.ndarray                  # (n+1,) float64
    cfg: np.ndarray                    # (n+1,) int64, -1 = infeasible
    dp: np.ndarray                     # (n+1,) int64
    t_iter: np.ndarray                 # (n+1,) float64
    mem: np.ndarray                    # (n+1,) float64
    configs: Tuple[Tuple[int, int, int], ...]   # (tp, pp, micro_b)

    def plan(self, x: int) -> Optional[PlanPoint]:
        """PlanPoint at worker count x (None if infeasible)."""
        if x <= 0 or x > self.n or self.cfg[x] < 0:
            return None
        tp, pp, _ = self.configs[int(self.cfg[x])]
        return PlanPoint(int(self.dp[x]), tp, pp, float(self.t_iter[x]),
                         float(self.flops[x]), float(self.mem[x]))

    def min_feasible(self) -> Optional[int]:
        """Smallest x with a feasible plan, or None if none up to n."""
        nz = np.nonzero(self.cfg[1:] >= 0)[0]
        return int(nz[0]) + 1 if nz.size else None


def _feasible_configs(task: TaskModel, n: int,
                      hw: Hardware) -> List[Tuple[int, int, int]]:
    """All (tp, pp, micro_b) memory-feasible on <= n workers, enumerated in
    the same order as the scalar reference so argmax tie-breaks agree."""
    out: List[Tuple[int, int, int]] = []
    tps = [t for t in (1, 2, 4, 8, 16) if t <= min(n, hw.intra_size)]
    for tp in tps:
        pp = 1
        while tp * pp <= n and pp <= task.n_layers:
            if task.n_layers % pp == 0:
                for micro_b in (1, 2, 4):
                    if _mem_per_worker(task, tp, pp, micro_b,
                                       hw) <= hw.hbm_bytes:
                        out.append((tp, pp, micro_b))
            pp *= 2
    return out


def _sweep(task: TaskModel, n: int, hw: Hardware) -> ThroughputCurve:
    """Evaluate every feasible config on every worker count 1..n at once.

    Mirrors ``_iter_time``'s arithmetic (same operation order) so the curve
    is float-identical to the scalar reference at every x.
    """
    B, S, N, L, d = (task.global_batch, task.seq_len, task.n_params,
                     task.n_layers, task.d_model)
    configs = _feasible_configs(task, n, hw)
    X = np.arange(n + 1, dtype=np.int64)
    if not configs:
        z = np.zeros(n + 1)
        return ThroughputCurve(task, hw, n, z,
                               np.full(n + 1, -1, dtype=np.int64),
                               np.zeros(n + 1, dtype=np.int64), z.copy(),
                               z.copy(), ())
    agg = np.zeros((len(configs), n + 1))          # achieved FLOP/s, 0 = infeasible
    dps = np.zeros((len(configs), n + 1), dtype=np.int64)
    its = np.zeros((len(configs), n + 1))
    tokens = B * S
    flops = 6.0 * N * tokens
    for ci, (tp, pp, micro_b) in enumerate(configs):
        dp = X // (tp * pp)
        ok = (dp >= 1) & (dp <= B) & (micro_b * dp <= B)
        dp_s = np.where(ok, dp, 1)                 # safe divisor
        m = np.maximum(1, np.ceil(B / (dp_s * micro_b)))
        t_comp = flops / (dp_s * tp * pp * hw.peak_flops * hw.compute_eff)
        t_comp = t_comp * ((m + pp - 1) / m)
        if tp > 1:
            bw = hw.intra_bw if tp <= hw.intra_size else hw.inter_bw
            tp_bytes = 4 * L / pp * (2.0 * S * micro_b * d) * m
            t_tp = tp_bytes * 2 * (tp - 1) / tp / bw
        else:
            t_tp = np.zeros(n + 1)
        g_bytes = 2.0 * N / (tp * pp)
        bw_dp = np.where(dp_s * tp * pp <= hw.intra_size,
                         hw.intra_bw, hw.inter_bw)
        t_dp = np.where(dp_s > 1,
                        0.5 * g_bytes * 2 * (dp_s - 1) / dp_s / bw_dp, 0.0)
        imbalance = np.ceil(B / dp_s) / (B / dp_s)
        t = (t_comp + t_tp + t_dp) * imbalance
        used = (6.0 * task.n_params * task.global_batch * task.seq_len) / t
        agg[ci] = np.where(ok, used, 0.0)
        dps[ci] = np.where(ok, dp, 0)
        its[ci] = np.where(ok, t, 0.0)
    best = np.argmax(agg, axis=0)                  # first max, like reference
    rows = np.arange(n + 1)
    best_agg = agg[best, rows]
    cfg = np.where(best_agg > 0.0, best, -1).astype(np.int64)
    mems = np.array([_mem_per_worker(task, tp, pp, mb, hw)
                     for tp, pp, mb in configs])
    mem = np.where(cfg >= 0, mems[np.maximum(cfg, 0)], 0.0)
    return ThroughputCurve(task, hw, n, best_agg, cfg, dps[best, rows],
                           its[best, rows], mem, tuple(configs))


_CURVE_CACHE: Dict[Tuple[TaskModel, Hardware], ThroughputCurve] = {}
_CURVE_CACHE_MAX = 1024                # curves are O(n) arrays; bound the set


def throughput_curve(task: TaskModel, n: int,
                     hw: Hardware = A800,
                     cap: Optional[int] = None) -> ThroughputCurve:
    """T(t, ·) vector for worker counts 0..n plus argmax plans, memoized per
    (task, hw); a larger-n request grows the cached sweep, a smaller one
    returns views into it.

    ``cap``: per-task worker ceiling (``Task.max_workers``).  Past the cap
    the curve is *flat* — extra workers idle, so T(t, x > cap) = T(t, cap)
    and ``plan(x)`` returns the cap-worker plan.  The flat tail is what
    lets the planner's banded max-plus kernels shrink the convolution
    band from n to cap+1 without changing any optimum."""
    cached = _CURVE_CACHE.pop((task, hw), None)
    if cached is None or cached.n < n:
        cached = _sweep(task, max(n, 1), hw)
    while len(_CURVE_CACHE) >= _CURVE_CACHE_MAX:      # LRU: dicts keep
        _CURVE_CACHE.pop(next(iter(_CURVE_CACHE)))    # insertion order
    _CURVE_CACHE[(task, hw)] = cached
    if cap is not None and cap < n:
        idx = np.minimum(np.arange(n + 1), max(cap, 0))
        return ThroughputCurve(task, hw, n, cached.flops[idx],
                               cached.cfg[idx], cached.dp[idx],
                               cached.t_iter[idx], cached.mem[idx],
                               cached.configs)
    if cached.n == n:
        return cached
    s = slice(0, n + 1)
    return ThroughputCurve(task, hw, n, cached.flops[s], cached.cfg[s],
                           cached.dp[s], cached.t_iter[s], cached.mem[s],
                           cached.configs)


def throughput_matrix(tasks, n: int, hw: Hardware = A800) -> np.ndarray:
    """T(t_i, x) for every task as one (m, n+1) matrix, assembled from the
    memoized per-task sweeps — the vectorized cluster simulator gathers
    whole worker-count columns out of this instead of calling the analytic
    model per (task, x)."""
    out = np.empty((len(tasks), n + 1))
    for i, t in enumerate(tasks):
        out[i] = throughput_curve(t, n, hw).flops[:n + 1]
    return out


def min_feasible_workers(task: TaskModel, hw: Hardware = A800,
                         upper: int = 4096) -> int:
    """Smallest x with a feasible plan (T_necessary floor).

    Exponential search over the vectorized curve: double the sweep range
    until a feasible count appears, then read the first nonzero entry
    directly off the curve (the curve gives the whole feasibility vector,
    subsuming the binary-search refinement step)."""
    n = 64
    while True:
        n = min(n, upper)
        found = throughput_curve(task, n, hw).min_feasible()
        if found is not None:
            return found
        if n >= upper:
            return upper
        n *= 2
