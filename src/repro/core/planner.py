"""Optimal reconfiguration plan generation (§5.2).

Knapsack-style dynamic program over (tasks x workers):

    S(i, j) = max_k { S(i-1, j-k) + G(t_i, k) }           (Eq. 5)

Reward rows G(t_i, ·) are produced by each task's *objective*
(``waf.Objective`` — training WAF by default, serving goodput/SLO for
inference tasks): the planner consumes ``waf.reward`` scalars and
``waf.reward_curve`` vectors without knowing which objective built
them.  The only row property the engines rely on is the **band
contract** (see ``core.waf``): rows are flat past each task's
``max_workers`` cap.  Rows need not be monotone — the *DP value
vectors* the banded kernels take as ``prev`` are made monotone
non-decreasing at the leaves (running maxima) and stay monotone under
max-plus merging, and that is what the band proof requires.

Two solver paths share the recurrence:

* ``solve`` — the vectorized engine: reward rows come out of the
  objective's vectorized curve as whole vectors (``waf.reward_curve``),
  and the DP inner loop is a max-plus convolution evaluated as one NumPy
  windowed matrix per task (O(n^2) cells but a single vector op), with
  argmax traceback.
* ``solve_reference`` — the original pure-Python scalar DP over the
  objective's scalar ``value``, kept as the ground truth for property
  tests and the speedup baseline.

Engine registry
---------------
``engines()`` is the single discovery point for the planner's engine and
backend axes.  ``engine=`` (values from ``engines()["engine"]``:
``"batched"``/``"fused"``/``"segtree"``/``"chain"``/``"reference"``) is
the one canonical spelling, accepted by ``PlanTable``/``PlannerCache.table``
directly and as the value of the simulators'/coordinator's
``plan_engine=`` kwarg (named to coexist with ``run_monte_carlo``'s
*simulator*-axis ``engine=``).  The historical ``solver=`` /
``incremental=False`` kwargs are deprecated shims for
``engine="reference"`` and are normalized by ``resolve_engine``.

Max-plus kernel family
----------------------
The DP inner loop is a max-plus (tropical) convolution; four evaluations
share the candidate set (``prev[j-k] + g[k]``), so their maxima agree:

* ``_maxplus_vals`` — plain windowed matrix (PR-1 baseline kernel);
* ``_maxplus_vals_fast`` — row-blocked (PR-2 chain-engine kernel);
* ``_maxplus_vals_fused`` — tiled fused add+max: candidate tiles are added
  and max-reduced block-by-block so the (n x n) candidate matrix is never
  materialized, and an optional **band** restricts the convolution to
  ``k <= band``.  The band is sound whenever ``prev`` is monotone
  non-decreasing (every DP value vector is) and ``g`` is flat past the
  band (reward rows of tasks with ``Task.max_workers`` caps are; so are
  span value vectors past the sum of their tasks' caps) — the banded
  output is then bitwise-identical to the dense one.
* ``_maxplus_vals_fused_batched`` — stacked (B, n+1) variant of the
  fused kernel with a *per-row* band: one call evaluates B independent
  convolutions, each row bitwise-identical to the 2-D fused kernel on
  its own (prev, g, band) slice.  The batched engine's workhorse.
* ``kernels.maxplus.maxplus_conv`` / ``maxplus_conv_batched`` — Pallas
  TPU kernels (interpret on CPU/GPU, compiled via Mosaic on TPU),
  float32; the batched variant puts the stack axis on the Pallas grid.
  Selected with the backend switch: ``set_maxplus_backend("pallas")`` or
  ``REPRO_PLANNER_BACKEND=pallas``; default stays ``numpy`` (float64).
* ``kernels.maxplus.maxplus_scan_chunk`` — the scan-compatible Pallas
  chunk step the fused engine runs inside its one-program ``lax.scan``
  when the pallas backend is selected (pre-gathered static-width
  operands, so one trace serves every scan step).

Incremental engine matrix (chain -> segtree -> batched -> fused)
----------------------------------------------------------------
``PlanTable`` precomputes the one-step lookahead lookup table the paper
uses for O(1) dispatch at failure time.  Four incremental engines build
it (mirroring the scalar -> vector -> batched simulator matrix):

* ``engine="chain"`` — the PR-2 prefix/suffix DP chains: P[i]/T[i] value
  vectors, each scenario assembled from <= 2 extra convolutions, a churn
  step invalidates the O(m) chain tail past the change.  Kept unchanged
  as the measured churn-rebuild baseline (``bench_planner_scale``).
* ``engine="segtree"`` — a dyadic segment tree over task positions
  (PR 3).  Each node stores the max-plus merge V[lo, hi) of its span's
  reward rows (leaves are running maxima, internal nodes one banded
  convolution of their children), and every scenario assembles from
  O(log m) cached node merges: ``join`` reads the root, ``finish:i`` the
  complement chain C(i) = merge of i's root-path siblings, ``fault:i``
  one extra banded convolution of C(i) with the fault row.  A churn step
  that changes one task's reward row invalidates only the O(log m) nodes
  on its root path (plus the complements crossing it) — but every node
  merge and every chain link is still its own Python-dispatched kernel
  call, and every ``lookup`` pays an O(m) argmax traceback.
* ``engine="batched"`` (default) — the level-synchronous batched engine
  on the same dyadic tree, three upgrades over ``segtree``:

  1. *Level-stacked merges*: tree nodes are grouped by depth and each
     level's merges run as ONE stacked banded max-plus call
     (``_maxplus_vals_fused_batched``), so a whole-tree build is
     O(log m) kernel launches instead of O(m) Python-driven calls.
  2. *Shared complement sweep*: the m ``fault:i``/``finish:i``
     complement chains overlap in O(m) distinct nodes — one top-down
     level-parallel sweep computes the complement vector of EVERY tree
     node (Comp(child) = Comp(parent) (+) V(sibling), all children of a
     level in one stacked call), then all m fault combines run as one
     more stacked call.  A whole-table value rebuild is therefore a
     constant number of batched launches per tree level.
  3. *Value-only assembly + lazy traceback*: ``rebuild_values()`` /
     ``scenario_total()`` materialize every scenario's value vector and
     total reward but NO assignments; the O(m) argmax traceback runs
     only for the scenario a ``lookup`` actually dispatches.

* ``engine="fused"`` — the one-program engine: the ENTIRE whole-table
  value rebuild (level-synchronous tree merges, top-down complement
  sweep, per-task fault combines, per-scenario argmaxes and totals) is
  ONE jitted device dispatch.  A host-side *schedule builder* decomposes
  every banded convolution of the batched engine's sweep — same
  operands, operand orders and bands — into fixed-width candidate-offset
  chunks that scatter-max into a slot buffer, groups the chunk rows by
  dependency level, and the compiled program runs ``lax.scan`` over the
  resulting step table with either a pure-``jnp`` float64 inner step
  (default; bitwise-identical totals to the numpy engines) or the Pallas
  ``maxplus_scan_chunk`` kernel under ``REPRO_PLANNER_BACKEND=pallas``.

  *Schedule padding contract*: every level's chunk rows are padded to a
  multiple of the scan group width with -inf dummy rows (band = -1
  masks the whole chunk, and a -inf row scatter-maxes to a no-op), and
  per-row ragged bands are masked to -inf inside the step — padding is
  value-neutral because a masked candidate never beats the always-finite
  k=0 candidate.  *Retrace keys*: compiled programs are cached per
  schedule signature (m, n_max, per-task unfaulted/faulted bands,
  backend) — reward-row *values* are runtime inputs, so churn that
  preserves caps and budgets re-dispatches the cached program with zero
  retraces; a capacity or cap change is a new signature (new trace), not
  an error.  ``batch_stats["device_dispatches"]`` counts exactly 1 per
  whole-table rebuild.  Lazy single-scenario lookups before a rebuild,
  and every argmax traceback, stay on the host-side batched machinery
  unchanged; node vectors are not written to the ``PlannerCache`` array
  store (the program cache replaces content-keyed reuse on this path).

  All four engines reduce identical candidate sets with exact
  order-free maxima, so their plans are float-identical.

With ``lazy=True`` scenarios (and the node merges feeding them) are
assembled on first ``lookup``; with a ``PlannerCache`` reward rows and
node/chain vectors are keyed by their span *contents* and reused across
rebuilds, and a recurring cluster state is a whole-table hit.  The
churn-heavy cluster simulators (``core.simulator.VectorSimulator`` /
``BatchSimulator``) are the main consumers; their cold Monte-Carlo walls
are planner-dispatch-bound, which is what the batched engine's
constant-launch rebuilds attack (``bench_planner_scale``'s whole-table
churn axis measures it directly).

``brute_force`` is an exponential reference used by the property tests.
Regenerate the committed benchmark baselines (``results/bench_*.json``)
with ``python benchmarks/run.py`` after any reward-model change here
(``python benchmarks/run.py --only planner_scale`` re-records a single
bench after a planner-only change).
"""
from __future__ import annotations

import itertools
import os
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import waf as waf_mod
from repro.core.costmodel import Hardware
from repro.core.waf import Task

NEG = float("-inf")


@dataclass(frozen=True)
class PlanInput:
    tasks: Tuple[Task, ...]
    assignment: Tuple[int, ...]        # current workers per task (x_i)
    n_workers: int                     # n' available after the event
    d_running: float
    d_transition: float
    faulted: Tuple[bool, ...]          # per task: did one of its workers fault


@dataclass(frozen=True)
class Plan:
    assignment: Tuple[int, ...]
    total_reward: float
    waf: float                         # cluster WAF under the new assignment


def _vector_capable(tasks: Sequence) -> bool:
    """Reward rows can be built from the objective's vectorized curve
    (real ``Task``s whose objective declares itself vector-capable — the
    default ``TrainingWAF`` requires an analytic ``TaskModel``).
    Duck-typed tasks — e.g. the tabulated tasks the property tests use
    with a monkeypatched ``waf`` — fall back to the scalar row builder
    so they keep their custom semantics."""
    return all(isinstance(t, Task) and t.objective.vector_capable(t)
               for t in tasks)


def _reward_row(inp: PlanInput, i: int, hw: Hardware) -> List[float]:
    """G(t_i, k) for k = 0..n_workers (scalar reference path)."""
    t = inp.tasks[i]
    return [waf_mod.reward(t, inp.assignment[i], k,
                           d_running=inp.d_running,
                           d_transition=inp.d_transition,
                           worker_faulted=inp.faulted[i], hw=hw)
            for k in range(inp.n_workers + 1)]


def _reward_matrix(inp: PlanInput, hw: Hardware) -> np.ndarray:
    """All m reward rows as an (m, n+1) matrix."""
    if _vector_capable(inp.tasks):
        return np.stack([
            waf_mod.reward_curve(t, inp.assignment[i], inp.n_workers,
                                 d_running=inp.d_running,
                                 d_transition=inp.d_transition,
                                 worker_faulted=inp.faulted[i], hw=hw)
            for i, t in enumerate(inp.tasks)])
    return np.array([_reward_row(inp, i, hw)
                     for i in range(len(inp.tasks))], dtype=float)


def _maxplus(prev: np.ndarray, g: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """One max-plus convolution step: out[j] = max_{0<=k<=j} prev[j-k] + g[k],
    plus the argmax k per j (first/lowest k on ties, matching the scalar
    DP's strict-improvement rule)."""
    n = prev.shape[0] - 1
    pad = np.concatenate([np.full(n, NEG), prev])
    win = np.lib.stride_tricks.sliding_window_view(pad, n + 1)
    vals = win[:, ::-1] + g[None, :]   # vals[j, k] = prev[j-k] + g[k]
    ch = vals.argmax(axis=1)           # one O(n^2) scan serves both outputs
    return vals[np.arange(n + 1), ch], ch


def _maxplus_vals(prev: np.ndarray, g: np.ndarray) -> np.ndarray:
    """Value vector of one max-plus step, without the per-cell argmax.

    Same candidate set per cell as ``_maxplus`` (so the maxima are
    float-identical), but evaluated without reversing the O(n^2) window
    matrix; tracebacks recover choices per *visited* cell via
    ``_argmax_at`` instead of materializing the whole argmax matrix."""
    n = prev.shape[0] - 1
    pad = np.concatenate([np.full(n, NEG), prev])
    win = np.lib.stride_tricks.sliding_window_view(pad, n + 1)
    return (win + g[::-1][None, :]).max(axis=1)


def _maxplus_vals_fast(prev: np.ndarray, g: np.ndarray) -> np.ndarray:
    """Bitwise-identical values to ``_maxplus_vals``, evaluated in row
    blocks that skip most of the -inf padding triangle (cell j only has
    j+1 real candidates; the rectangular kernel evaluates all n+1).
    Every real candidate is the same ``prev[j-k] + g[k]`` float and max
    is an exact, order-free reduction, so the output is unchanged.  This
    is the kernel of the cached/lazy engine path; the eager reference
    build keeps the plain kernels as the measured baseline."""
    n = prev.shape[0] - 1
    pad = np.concatenate([np.full(n, NEG), prev])
    win = np.lib.stride_tricks.sliding_window_view(pad, n + 1)
    gr = g[::-1]
    out = np.empty(n + 1)
    block = 128
    for j0 in range(0, n + 1, block):
        j1 = min(j0 + block, n + 1)
        t_lo = n - j1 + 1          # rows below j1 have no candidate before
        out[j0:j1] = (win[j0:j1, t_lo:] + gr[t_lo:]).max(axis=1)
    return out


def _maxplus_vals_fused(prev: np.ndarray, g: np.ndarray,
                        band: Optional[int] = None,
                        block: Optional[int] = None) -> np.ndarray:
    """Tiled fused add+max max-plus convolution.

    out[j] = max_{0 <= k <= min(j, band)} prev[j-k] + g[k]

    Candidate tiles of at most (block, band+1) cells are added and
    max-reduced immediately, so peak scratch is one tile — the (n x n)
    candidate matrix of the plain kernels is never materialized.  With
    ``band=None`` (dense) the candidate set per cell is exactly
    ``_maxplus_vals``'s, so the output is bitwise identical.  A finite
    band is sound — and still bitwise identical to dense — when ``prev``
    is monotone non-decreasing and ``g`` is flat past the band: every
    dropped candidate ``prev[j-k] + g[k]`` (k > band) is dominated by
    ``prev[j-band] + g[band]``, and first-max tie-breaking already picks
    the lowest k.

    Tile orientation adapts to the band: a narrow band (<= 1/4 of the
    width) lays k along the short outer axis and j along the long
    contiguous axis, so numpy's per-row loop overhead scales with the
    band instead of with n; wide/dense bands keep the j-blocked layout
    whose tiles bound peak scratch at one (block, band+1) slab.  Both
    orientations max-reduce the same candidate floats, so tiling never
    changes values."""
    n = prev.shape[0] - 1
    b = n if band is None else max(0, min(int(band), n))
    pad = np.concatenate([np.full(b, NEG), prev])
    if 4 * (b + 1) <= n + 1:           # narrow band: k-major tiles
        winT = np.lib.stride_tricks.sliding_window_view(pad, n + 1)
        gr = g[b::-1][:, None]         # gr[t] = g[b - t], i.e. k = b - t
        width = max(128, 131072 // (b + 1)) if block is None else block
        out = np.empty(n + 1)
        for j0 in range(0, n + 1, width):
            j1 = min(j0 + width, n + 1)
            out[j0:j1] = (winT[:, j0:j1] + gr).max(axis=0)
        return out
    if block is None:
        block = 128
    win = np.lib.stride_tricks.sliding_window_view(pad, b + 1)
    gr = g[b::-1]
    out = np.empty(n + 1)
    for j0 in range(0, n + 1, block):
        j1 = min(j0 + block, n + 1)
        t_lo = max(b - j1 + 1, 0)      # rows below j1 have no candidate before
        out[j0:j1] = (win[j0:j1, t_lo:] + gr[t_lo:]).max(axis=1)
    return out


def _maxplus_kloop_stack(prev: np.ndarray, g: np.ndarray,
                         bs: np.ndarray) -> np.ndarray:
    """Shift-slab evaluation of a stacked banded convolution: one
    iteration per candidate offset k, each a fused add + in-place max
    over the whole contiguous (B, n+1) slab.

    out[r, j] = max_{0 <= k <= min(j, bs[r])} prev[r, j-k] + g[r, k]

    Per-row bands are applied by masking g past each row's band to -inf
    (a masked candidate never beats the finite k=0 candidate); k > j
    candidates fall into the -inf pad.  Max is an exact order-free
    reduction over the same ``prev[r, j-k] + g[r, k]`` floats as the 2-D
    fused kernel, so rows are bitwise identical to per-slice calls."""
    B, n1 = prev.shape
    bmax = int(bs.max())
    pad = np.concatenate([np.full((B, bmax), NEG), prev], axis=1)
    gm = g
    if (bs < bmax).any():
        gm = np.where(np.arange(n1)[None, :] > bs[:, None], NEG, g)
    out = np.full((B, n1), NEG)
    tmp = np.empty((B, n1))
    for k in range(bmax + 1):
        np.add(pad[:, bmax - k: bmax - k + n1], gm[:, k:k + 1], out=tmp)
        np.maximum(out, tmp, out=out)
    return out


def _maxplus_vals_fused_batched(prev: np.ndarray, g: np.ndarray,
                                bands=None) -> np.ndarray:
    """Stacked banded max-plus convolution: B independent rows at once.

    ``prev`` and ``g`` are (B, n+1); ``bands`` is a per-row band sequence
    (``None`` entries = dense).  Row r of the output is **bitwise
    identical** to ``_maxplus_vals_fused(prev[r], g[r], band=bands[r])``:
    every path below reduces exactly row r's candidate set with exact
    order-free maxima.

    One call replaces a Python loop of B 2-D kernel calls — the
    per-level launch of the ``engine="batched"`` PlanTable.  Like the
    2-D kernel's orientation adaptivity, the evaluation strategy follows
    the shape: rows are bucketed by band (each bucket spans at most a 2x
    band spread, bounding masked-candidate waste), narrow buckets run as
    shift-slab stacks whose Python-loop count is the band instead of the
    batch (``_maxplus_kloop_stack``), and wide/dense buckets — where one
    row's candidate matrix already saturates the memory system and
    stacking only thrashes it — fall through to the tiled 2-D kernel per
    row."""
    prev = np.asarray(prev, dtype=float)
    g = np.asarray(g, dtype=float)
    B, n1 = prev.shape
    n = n1 - 1
    if bands is None:
        bs = np.full(B, n, dtype=np.int64)
    else:
        bs = np.array([n if b is None else max(0, min(int(b), n))
                       for b in bands], dtype=np.int64)
    out = np.empty((B, n1))
    order = np.argsort(bs, kind="stable")
    start = 0
    while start < B:
        stop = start + 1
        floor = bs[order[start]]
        while (stop < B
               and bs[order[stop]] + 1 <= 2 * (floor + 1)):
            stop += 1
        rows = order[start:stop]
        bmax = int(bs[rows[-1]])
        if bmax + 1 <= 4 * len(rows):      # narrow bucket: slab stack
            out[rows] = _maxplus_kloop_stack(prev[rows], g[rows],
                                             bs[rows])
        else:                              # wide/dense: per-row tiles
            for r in rows:
                out[r] = _maxplus_vals_fused(prev[r], g[r],
                                             band=int(bs[r]))
        start = stop
    return out


# ---------------------------------------------------------------------------
# Max-plus backend switch: numpy (float64, default) or the Pallas kernel
# (kernels.maxplus.maxplus_conv, float32; interpret off-TPU).
# ---------------------------------------------------------------------------

_BACKEND_ENV = "REPRO_PLANNER_BACKEND"
_BACKENDS = ("numpy", "pallas")
_backend_override: Optional[str] = None


def set_maxplus_backend(name: Optional[str]) -> None:
    """Select the max-plus convolution backend for the incremental engines:
    ``"numpy"`` / ``"pallas"``, or ``None`` to defer to the
    ``REPRO_PLANNER_BACKEND`` env var (default numpy)."""
    global _backend_override
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"unknown max-plus backend {name!r}; "
                         f"choose from {_BACKENDS}")
    _backend_override = name


def get_maxplus_backend() -> str:
    if _backend_override is not None:
        return _backend_override
    env = os.environ.get(_BACKEND_ENV, "").strip().lower()
    if env and env not in _BACKENDS:
        raise ValueError(f"{_BACKEND_ENV}={env!r} is not recognized; "
                         f"choose from {_BACKENDS}")
    return env or "numpy"


# ---------------------------------------------------------------------------
# Engine registry: the single discovery point for the planner's engine and
# backend axes (see the module docstring's "Engine registry" section).
# ---------------------------------------------------------------------------

ENGINES = ("batched", "fused", "segtree", "chain", "reference")

_ENGINE_DESCRIPTIONS = {
    "batched": "level-synchronous stacked dyadic tree; value-only "
               "rebuilds + lazy traceback (default)",
    "fused": "one-program engine: whole-table value rebuild compiled "
             "into a single jitted lax.scan dispatch (program cache "
             "keyed on the schedule signature)",
    "segtree": "per-node dyadic segment tree, O(log m) churn "
               "invalidation, one kernel call per merge",
    "chain": "prefix/suffix DP chains; the preserved churn-rebuild "
             "baseline",
    "reference": "non-incremental per-scenario solves (scalar "
                 "solve_reference by default); the ground-truth path",
}

_BACKEND_DESCRIPTIONS = {
    "numpy": "float64 fused numpy kernels (default)",
    "pallas": "float32 Pallas TPU kernels (interpret off-TPU); "
              "set_maxplus_backend('pallas') or "
              "REPRO_PLANNER_BACKEND=pallas",
}


def engines() -> Dict[str, Dict[str, str]]:
    """The planner's engine/backend registry.

    Returns ``{"engine": {name: description}, "backend": {...}}``.  The
    ``engine`` axis is spelled ``engine=`` on ``PlanTable`` /
    ``PlannerCache.table`` and ``plan_engine=`` on the simulators and
    ``UnicronCoordinator`` (same values; the kwarg differs only because
    ``run_monte_carlo``'s ``engine=`` already names the simulator axis).
    The ``backend`` axis is the process-wide max-plus kernel switch
    (``set_maxplus_backend`` / ``REPRO_PLANNER_BACKEND``)."""
    return {"engine": dict(_ENGINE_DESCRIPTIONS),
            "backend": dict(_BACKEND_DESCRIPTIONS)}


def resolve_engine(engine: Optional[str] = None, *,
                   solver=None, incremental: bool = True,
                   default: str = "batched") -> str:
    """Normalize the historical spellings of the engine axis to one
    canonical name from ``engines()["engine"]``.

    ``solver=`` (any non-None per-scenario solver) and
    ``incremental=False`` are deprecated shims for
    ``engine="reference"``; an explicit ``engine=`` name passes through
    unchanged otherwise.  Unknown names raise ``ValueError``."""
    if engine is not None and engine not in ENGINES:
        raise ValueError(f"unknown PlanTable engine {engine!r}; "
                         f"choose from {ENGINES}")
    if solver is not None or not incremental:
        return "reference"
    return engine if engine is not None else default


def _conv_vals(prev: np.ndarray, g: np.ndarray,
               band: Optional[int] = None) -> np.ndarray:
    """Backend-dispatched banded max-plus value kernel (segment-tree
    engine's convolution).  Traceback-time argmax recovery stays on
    numpy either way — only the value vectors go through the kernel."""
    if get_maxplus_backend() == "pallas":
        from repro.kernels.maxplus import maxplus_conv
        return np.asarray(maxplus_conv(prev, g, band=band), dtype=float)
    return _maxplus_vals_fused(prev, g, band)


def _conv_vals_batched(prev: np.ndarray, g: np.ndarray,
                       bands) -> np.ndarray:
    """Backend-dispatched stacked banded max-plus kernel (the batched
    engine's per-level launch): numpy float64 by default, the
    grid-batched Pallas kernel (float32) under the same
    ``REPRO_PLANNER_BACKEND=pallas`` switch as the 2-D path."""
    if get_maxplus_backend() == "pallas":
        from repro.kernels.maxplus import maxplus_conv_batched
        return np.asarray(maxplus_conv_batched(prev, g, bands), dtype=float)
    return _maxplus_vals_fused_batched(prev, g, bands)


def _argmax_at(prev: np.ndarray, g: np.ndarray, j: int) -> int:
    """Choice k at cell j of ``_maxplus(prev, g)``: first/lowest k on ties
    (all candidates with k > j are -inf, so restricting to k <= j is
    exactly the stored-argmax matrix's answer)."""
    return int(np.argmax(prev[j::-1] + g[:j + 1]))


def _cluster_waf(tasks: Sequence[Task], assign: Sequence[int],
                 hw: Hardware) -> float:
    return sum(waf_mod.waf(t, x, hw) for t, x in zip(tasks, assign))


def solve(inp: PlanInput, hw: Hardware) -> Plan:
    """Vectorized dynamic program (Eq. 5) with traceback."""
    m, n = len(inp.tasks), inp.n_workers
    if m == 0:
        return Plan((), 0.0, 0.0)
    rows = _reward_matrix(inp, hw)
    S = np.zeros(n + 1)
    choice = np.zeros((m, n + 1), dtype=np.int64)
    for i in range(m):
        S, choice[i] = _maxplus(S, rows[i])
    assign = [0] * m
    j = int(np.argmax(S))
    total = float(S[j])
    for i in range(m - 1, -1, -1):
        k = int(choice[i, j])
        assign[i] = k
        j -= k
    return Plan(tuple(assign), total, _cluster_waf(inp.tasks, assign, hw))


def solve_fast(inp: PlanInput, hw: Hardware) -> Plan:
    """Same Plan as ``solve`` (same candidate floats, same first-max
    tie-breaking) using the value-only row-blocked kernel and
    traceback-time argmax recovery instead of per-cell argmax matrices —
    the fresh-dispatch path of the cached engine."""
    m, n = len(inp.tasks), inp.n_workers
    if m == 0:
        return Plan((), 0.0, 0.0)
    rows = _reward_matrix(inp, hw)
    S = [np.zeros(n + 1)]
    for i in range(m):
        S.append(_maxplus_vals_fast(S[i], rows[i]))
    assign = [0] * m
    j = int(np.argmax(S[m]))
    total = float(S[m][j])
    for i in range(m - 1, -1, -1):
        k = _argmax_at(S[i], rows[i], j)
        assign[i] = k
        j -= k
    return Plan(tuple(assign), total, _cluster_waf(inp.tasks, assign, hw))


def solve_reference(inp: PlanInput, hw: Hardware) -> Plan:
    """Scalar reference DP (the original implementation): property-test
    ground truth and the speedup baseline for the benchmarks."""
    m, n = len(inp.tasks), inp.n_workers
    rows = [_reward_row(inp, i, hw) for i in range(m)]
    # S[i][j]: best reward of first i tasks using j workers
    S = [[0.0] + [0.0] * n]
    choice: List[List[int]] = []
    for i in range(1, m + 1):
        row = [NEG] * (n + 1)
        ch = [0] * (n + 1)
        g = rows[i - 1]
        for j in range(n + 1):
            best, bk = NEG, 0
            for k in range(j + 1):
                v = S[i - 1][j - k] + g[k]
                if v > best:
                    best, bk = v, k
            row[j], ch[j] = best, bk
        S.append(row)
        choice.append(ch)
    # traceback from S(m, n)
    assign = [0] * m
    j = max(range(n + 1), key=lambda jj: S[m][jj])
    total = S[m][j]
    for i in range(m, 0, -1):
        k = choice[i - 1][j]
        assign[i - 1] = k
        j -= k
    return Plan(tuple(assign), total, _cluster_waf(inp.tasks, assign, hw))


def brute_force(inp: PlanInput, hw: Hardware) -> Plan:
    """Exponential reference solver (tests only)."""
    m, n = len(inp.tasks), inp.n_workers
    rows = [_reward_row(inp, i, hw) for i in range(m)]
    best: Optional[Tuple[float, Tuple[int, ...]]] = None
    for assign in itertools.product(range(n + 1), repeat=m):
        if sum(assign) > n:
            continue
        v = sum(rows[i][assign[i]] for i in range(m))
        if best is None or v > best[0]:
            best = (v, assign)
    v, assign = best
    return Plan(tuple(assign), v, _cluster_waf(inp.tasks, assign, hw))


# ---------------------------------------------------------------------------
# Fused one-program engine: schedule builder + compiled program cache.
#
# The whole-table value rebuild of the batched engine — level-synchronous
# tree merges, top-down complement sweep, fault combines, scenario argmaxes
# — becomes ONE jitted device dispatch.  See the module docstring's
# ``engine="fused"`` entry for the padding contract and retrace keys.
# ---------------------------------------------------------------------------

_FUSED_GROUP = 32   # scan step width G: chunk rows per lax.scan step
_FUSED_ROW_COST = 4  # per-chunk-row overhead (gather/mask/scatter), in
#                      units of n1 cells — the adaptive-K cost model's
#                      only tunable


def _fused_chunk_width(bands: Sequence[int]) -> int:
    """Adaptive candidate-offset chunk width K for one schedule: minimize
    padded candidate slots + per-row overhead over the signature's actual
    band distribution.  K is static per compiled program (it sets every
    ``dynamic_slice`` width), so this is trace-time work — e.g. a fleet
    of cap-16 tasks picks K=17 (band-16 ops become one exact chunk)
    instead of padding every 17-candidate op to a power of two."""
    if not bands:
        return 16
    best_k, best_cost = 16, None
    for k in range(8, 65):
        cost = sum(-(-(b + 1) // k) * (k + _FUSED_ROW_COST)
                   for b in bands)
        if best_cost is None or cost < best_cost:
            best_k, best_cost = k, cost
    return best_k


class _FusedSchedule:
    """Static whole-table rebuild schedule for one signature
    (m, n_max, per-task bands).

    Every banded max-plus convolution of the batched sweep is decomposed
    into ``ceil((band+1)/K)`` chunk rows — chunk ``c`` covering candidate
    offsets ``[cK, cK+K)`` — which scatter-max into the op's output slot
    (exact: the candidate set partitions over offset chunks and max is
    order-free).  Chunk rows are grouped by dependency level (merges
    bottom-up by tree depth, then the complement sweep top-down, then the
    fault combines), each level padded to a multiple of the group width
    ``G`` with inert dummy rows (band = -1), and flattened into
    ``(steps, G)`` int32 step tables a single ``lax.scan`` consumes.

    All vectors live in one (n_slots, width) slot buffer with ``K``-aware
    -inf margins on both sides, so a chunk's shifted ``prev`` window and
    its ``g`` chunk are plain ``dynamic_slice`` gathers at trace-friendly
    static widths.  Operand orders and bands mirror ``_build_spans`` /
    ``_ensure_values`` exactly — outputs are bitwise-identical."""

    def __init__(self, m: int, n_max: int,
                 bands_unf: Tuple[int, ...], bands_f: Tuple[int, ...],
                 chunk: Optional[int] = None, group: int = _FUSED_GROUP):
        self.m, self.n_max = m, n_max
        self.group = group
        self.n1 = n_max + 1

        levels: List[List[Tuple[int, int]]] = []

        def walk(lo: int, hi: int, d: int) -> None:
            if len(levels) <= d:
                levels.append([])
            levels[d].append((lo, hi))
            if hi - lo > 1:
                mid = (lo + hi) // 2
                walk(lo, mid, d + 1)
                walk(mid, hi, d + 1)

        walk(0, m, 0)
        self.levels = levels
        nodes = [nd for lvl in levels for nd in lvl]
        self.v_slot = {nd: i for i, nd in enumerate(nodes)}
        base = len(nodes)
        self.c_slot = {nd: base + i for i, nd in enumerate(nodes)}
        base += len(nodes)
        self.fault_slot = {i: base + i for i in range(m)}
        base += m
        self.frow_slot = {i: base + i for i in range(m)}
        base += m
        self.scratch = base
        self.n_slots = base + 1

        sat_memo: Dict[Tuple[int, int], int] = {}

        def sat(lo: int, hi: int) -> int:
            got = sat_memo.get((lo, hi))
            if got is None:
                got = min(sum(bands_unf[lo:hi]), n_max)
                sat_memo[(lo, hi)] = got
            return got

        # op_steps: dependency-ordered groups of (prev, g, band, out).
        op_steps: List[List[Tuple[int, int, int, int]]] = []
        # V up-sweep: internal merges bottom-up, one step group per tree
        # depth (children are strictly deeper -> already reduced).
        for d in reversed(range(len(levels))):
            ops: List[Tuple[int, int, int, int]] = []
            for lo, hi in levels[d]:
                if hi - lo == 1:
                    continue
                mid = (lo + hi) // 2
                sl, sr = sat(lo, mid), sat(mid, hi)
                if sl < sr:               # band by the flatter operand
                    prev, g, band = (mid, hi), (lo, mid), sl
                else:
                    prev, g, band = (lo, mid), (mid, hi), sr
                ops.append((self.v_slot[prev], self.v_slot[g],
                            min(band, n_max), self.v_slot[(lo, hi)]))
            if ops:
                op_steps.append(ops)
        # Complement down-sweep: Comp(child) = Comp(parent) (+) V(sib).
        csat: Dict[Tuple[int, int], int] = {(0, m): 0}
        for d in range(len(levels) - 1):
            ops = []
            for lo, hi in levels[d]:
                if hi - lo == 1:
                    continue
                mid = (lo + hi) // 2
                for child, sib in (((lo, mid), (mid, hi)),
                                   ((mid, hi), (lo, mid))):
                    satc, sat_v = csat[(lo, hi)], sat(*sib)
                    csat[child] = min(satc + sat_v, n_max)
                    if satc < sat_v:      # band by the flatter operand
                        prev, g, band = (self.v_slot[sib],
                                         self.c_slot[(lo, hi)], satc)
                    else:
                        prev, g, band = (self.c_slot[(lo, hi)],
                                         self.v_slot[sib], sat_v)
                    ops.append((prev, g, min(band, n_max),
                                self.c_slot[child]))
            if ops:
                op_steps.append(ops)
        # Fault combines: Comp(leaf i) (+) faulted row i.
        ops = [(self.c_slot[(i, i + 1)], self.frow_slot[i],
                min(bands_f[i], n_max), self.fault_slot[i])
               for i in range(m)]
        if ops:
            op_steps.append(ops)

        # Static per-signature traceback metadata, bulk-copied into the
        # table's stores after a dispatch (saves the per-rebuild python
        # sweep the batched engine pays): span saturations, comp-tree
        # cumulative saturations and sibling paths.
        self.sat_map = dict(sat_memo)
        self.csat_map = csat
        csibs: Dict[Tuple[int, int], Tuple] = {(0, m): ()}
        for d in range(len(levels) - 1):
            for lo, hi in levels[d]:
                if hi - lo == 1:
                    continue
                mid = (lo + hi) // 2
                for child, sib in (((lo, mid), (mid, hi)),
                                   ((mid, hi), (lo, mid))):
                    csibs[child] = csibs[(lo, hi)] + (sib,)
        self.csibs_map = csibs

        all_bands = [op[2] for ops in op_steps for op in ops]
        self.chunk = chunk = (_fused_chunk_width(all_bands)
                              if chunk is None else chunk)
        steps: List[List[Tuple[int, int, int, int, int]]] = []
        for ops in op_steps:
            rows = [(prev, g, c, band, out)
                    for prev, g, band, out in ops
                    for c in range(0, band + 1, chunk)]
            steps.append(rows)
        # left margin sized to the widest chunk offset actually scheduled
        # (window start padl - off - (K-1) stays > 0, so dynamic_slice
        # never clamps); right margin keeps g-chunk reads past n_max in
        # -inf territory.  The scan carries the whole buffer, so every
        # saved column is saved once per step.
        max_off = max((r[2] for rows in steps for r in rows), default=0)
        self.padl = max_off + chunk
        self.width = self.padl + self.n1 + chunk

        dummy = (self.scratch, self.scratch, 0, -1, self.scratch)
        packed: List[Tuple[int, int, int, int, int]] = []
        self.real_rows = 0
        for rows in steps:
            self.real_rows += len(rows)
            rows = rows + [dummy] * (-len(rows) % group)
            packed.extend(rows)
        if not packed:
            packed = [dummy] * group
        table = np.asarray(packed, dtype=np.int32).reshape(-1, group, 5)
        self.n_steps = table.shape[0]
        self.xs = tuple(np.ascontiguousarray(table[:, :, i])
                        for i in range(5))
        self.leaf_slots = np.asarray(
            [self.v_slot[(i, i + 1)] for i in range(m)], dtype=np.int32)
        self.frow_slots = np.asarray(
            [self.frow_slot[i] for i in range(m)], dtype=np.int32)
        self.root_c_slot = self.c_slot[(0, m)]
        # scenario readout order: fault:0..m-1, finish:0..m-1, join:1
        self.scen_slots = np.asarray(
            [self.fault_slot[i] for i in range(m)]
            + [self.c_slot[(i, i + 1)] for i in range(m)]
            + [self.v_slot[(0, m)]], dtype=np.int32)


class _FusedProgram:
    """One compiled whole-table rebuild for a schedule signature.

    ``__call__(g_unf, g_f, limits)`` runs the single jitted dispatch:
    reward-row stacks (m, n+1) float64 and the (2m+1,) per-scenario
    argmax limits are the only runtime inputs; the step tables are
    trace-time constants.  Returns host arrays: the (n_slots, n+1) slot
    values, per-scenario argmax cells, and totals.  Traced and invoked
    under ``jax.experimental.enable_x64`` so the default backend stays
    float64 — totals are then bitwise-identical to the numpy engines
    (each candidate is a single IEEE add; max is order-free).  Under the
    pallas backend the inner step is ``maxplus_scan_chunk`` (float32
    kernel arithmetic, float64 buffer), matching the batched engine's
    pallas precision exactly."""

    def __init__(self, sched: _FusedSchedule, backend: str):
        import jax                        # deferred: numpy engines never
        self._jax = jax                   # pay the jax import
        self.sched = sched
        self.backend = backend
        self.calls = 0
        self._fn = jax.jit(self._program)

    def traces(self) -> int:
        """Compiled-trace count of the jitted program (the no-retrace
        assertion probe); -1 if this jax build has no cache probe."""
        try:
            return int(self._fn._cache_size())
        except AttributeError:
            return -1

    def _program(self, g_unf, g_f, limits):
        jax = self._jax
        jnp = jax.numpy
        sc = self.sched
        dt = g_unf.dtype
        K, n1, padl = sc.chunk, sc.n1, sc.padl
        buf = jnp.full((sc.n_slots, sc.width), NEG, dt)
        leaves = jax.lax.cummax(g_unf, axis=1)     # running maxima
        buf = buf.at[sc.leaf_slots, padl:padl + n1].set(leaves)
        buf = buf.at[sc.frow_slots, padl:padl + n1].set(g_f)
        buf = buf.at[sc.root_c_slot, padl:padl + n1].set(
            jnp.zeros((n1,), dt))

        def step(b, xs):
            src, gsl, off, band, out = xs
            wins = jax.vmap(
                lambda r, o: jax.lax.dynamic_slice(
                    r, (padl - o - (K - 1),), (n1 + K - 1,))
            )(b[src], off)
            gs = jax.vmap(
                lambda r, o: jax.lax.dynamic_slice(r, (padl + o,), (K,))
            )(b[gsl], off)
            ks = off[:, None] + jnp.arange(K, dtype=off.dtype)[None, :]
            gs = jnp.where(ks <= band[:, None], gs, NEG)
            if self.backend == "pallas":
                from repro.kernels.maxplus import maxplus_scan_chunk
                acc = maxplus_scan_chunk(wins, gs).astype(dt)
            else:
                acc = jnp.full((wins.shape[0], n1), NEG, dt)
                for k in range(K):        # static unroll: fused add+max
                    acc = jnp.maximum(
                        acc, wins[:, K - 1 - k:K - 1 - k + n1]
                        + gs[:, k:k + 1])
            return b.at[out, padl:padl + n1].max(acc), None

        buf, _ = jax.lax.scan(step, buf, sc.xs)
        vals = buf[:, padl:padl + n1]
        scen = vals[sc.scen_slots]
        mask = jnp.arange(n1)[None, :] <= limits[:, None]
        js = jnp.argmax(jnp.where(mask, scen, NEG), axis=1)
        totals = jnp.take_along_axis(scen, js[:, None], axis=1)[:, 0]
        return vals, js, totals

    def __call__(self, g_unf: np.ndarray, g_f: np.ndarray,
                 limits: np.ndarray):
        from jax.experimental import enable_x64
        with enable_x64():                # trace AND dispatch in f64
            vals, js, totals = self._fn(g_unf, g_f, limits)
            out = (np.asarray(vals), np.asarray(js), np.asarray(totals))
        self.calls += 1
        return out


_FUSED_PROGRAMS: OrderedDict = OrderedDict()
_FUSED_PROGRAM_CAP = 32
_fused_lock = threading.Lock()


def _fused_program(m: int, n_max: int, bands_unf: Tuple[int, ...],
                   bands_f: Tuple[int, ...], backend: str) -> _FusedProgram:
    """Process-wide LRU of compiled fused programs, keyed on the schedule
    signature — same-signature churn rebuilds re-dispatch without
    retracing (reward values are runtime inputs)."""
    key = (m, n_max, bands_unf, bands_f, backend)
    with _fused_lock:
        prog = _FUSED_PROGRAMS.get(key)
        if prog is not None:
            _FUSED_PROGRAMS.move_to_end(key)
            return prog
    prog = _FusedProgram(_FusedSchedule(m, n_max, bands_unf, bands_f),
                         backend)
    with _fused_lock:
        got = _FUSED_PROGRAMS.setdefault(key, prog)
        _FUSED_PROGRAMS.move_to_end(key)
        while len(_FUSED_PROGRAMS) > _FUSED_PROGRAM_CAP:
            _FUSED_PROGRAMS.popitem(last=False)
        return got


class PlanTable:
    """Precomputed lookup table (§5.2 'Complexity'): one-step lookahead
    plans for every single-event scenario from the current configuration —
    any task losing one worker, a worker joining, a task finishing —
    giving O(1) dispatch when the event actually happens.

    Incremental build: base reward rows G(t_i, ·) at the largest scenario
    budget are computed once from the memoized cost-model curves, prefix
    DPs P[i] (tasks 0..i-1) and suffix DPs T[i] (tasks i..m-1) are each one
    max-plus pass, and every scenario is then assembled from them:

      fault:i   combine(P[i], fault-row_i, T[i+1])   (2 convolutions)
      join:1    combine(P[m//2], T[m//2])             (1 convolution)
      finish:i  combine(P[i], T[i+1])                 (1 convolution)

    ``lazy=True`` defers scenario assembly (and the node merges / chains
    feeding it) to the first ``lookup`` of each key: a table consulted for
    one scenario before the cluster state changes again only pays for that
    scenario.  A ``PlannerCache`` shares rows and node/chain vectors
    *across* rebuilds.  The batched engine additionally separates values
    from assignments: ``rebuild_values()`` materializes every scenario's
    total in a constant number of stacked kernel launches per tree level,
    and the O(m) argmax traceback runs only for keys ``lookup`` actually
    dispatches.

    ``incremental=False`` retains the original scenario-by-scenario full
    solves (the reference path the tests and benchmarks compare against).
    """

    #: canonical engine names — aliases the module-level registry tuple
    ENGINES = ENGINES

    def __init__(self, tasks: Sequence[Task], assignment: Sequence[int],
                 hw: Hardware, d_running: float, d_transition: float,
                 workers_per_fault: int = 8, incremental: bool = True,
                 solver=None, lazy: bool = False,
                 cache: Optional["PlannerCache"] = None,
                 n_budget: Optional[int] = None,
                 engine: Optional[str] = None):
        """``engine`` (canonical axis, values from
        ``engines()["engine"]``): ``"batched"`` (default;
        level-synchronous stacked merges, shared complement sweep,
        value-only assembly with lazy traceback), ``"fused"`` (the
        one-program engine: the whole-table value rebuild is a single
        jitted ``lax.scan`` dispatch, cached per schedule signature;
        lazy single lookups and tracebacks share the batched host
        machinery), ``"segtree"`` (the
        PR-3 per-node dyadic tree, O(log m) invalidation per churn step,
        one kernel call per merge), ``"chain"`` (the PR-2 prefix/suffix
        DP chains, kept as the churn-rebuild baseline) or
        ``"reference"`` (non-incremental: one full ``solve_reference``
        solve per scenario — the all-scalar ground truth).

        Deprecated shims, normalized by ``resolve_engine``:
        ``incremental=False`` falls back to one full solve per scenario
        (historical default solver: vectorized ``solve``), and a
        non-None ``solver=`` picks the per-scenario solver; both resolve
        to the ``"reference"`` engine.

        ``n_budget``: size the DP value arrays for this many workers (>=
        the largest scenario budget).  Plans are unchanged — every
        scenario argmax is sliced to its own budget — but a *fixed*
        budget (e.g. cluster capacity + one node) keeps chain-cache keys
        and array shapes identical across rebuilds at different totals."""
        requested = engine
        engine = resolve_engine(engine, solver=solver,
                                incremental=incremental)
        self.tasks = tuple(tasks)
        self.assignment = tuple(assignment)
        self.hw = hw
        self.d_running = d_running
        self.d_transition = d_transition
        self.workers_per_fault = workers_per_fault  # a node drain = 8 GPUs
        self.n_budget = n_budget
        self.engine = engine
        if engine == "reference" and requested == "reference":
            # the canonical spelling defaults to the scalar ground truth;
            # the incremental=False shim keeps its historical vectorized
            # per-scenario default
            self._solver = solver or solve_reference
        else:
            self._solver = solver or solve
        self._cache = cache
        self.table: Dict[str, Plan] = {}
        # batched/fused-engine accounting (zeros for the other engines):
        # tree/complement levels merged, stacked kernel launches issued,
        # plans materialized by on-demand traceback, and compiled fused
        # programs executed (exactly 1 per whole-table fused rebuild).
        self.batch_stats: Dict[str, int] = {"levels": 0, "launches": 0,
                                            "tracebacks": 0,
                                            "device_dispatches": 0}
        self._incremental = (engine != "reference"
                             and len(self.tasks) > 0
                             and _vector_capable(self.tasks))
        if self._incremental:
            self._init_incremental()
            if not lazy:
                if engine in ("batched", "fused"):
                    self._ensure_values()
                for key in self.scenario_keys():
                    self.lookup(key)
        else:
            self._precompute_reference()

    def scenario_keys(self) -> List[str]:
        m = len(self.tasks)
        return ([f"fault:{i}" for i in range(m)] + ["join:1"]
                + [f"finish:{i}" for i in range(m)])

    def _scenario_input(self, n_workers: int,
                        faulted_task: Optional[int]) -> PlanInput:
        faulted = tuple(i == faulted_task for i in range(len(self.tasks)))
        return PlanInput(self.tasks, self.assignment, n_workers,
                         self.d_running, self.d_transition, faulted)

    # ---- reference build: one full solve per scenario ---------------------

    def _precompute_reference(self) -> None:
        n_now = sum(self.assignment)
        w = self.workers_per_fault
        for ti in range(len(self.tasks)):
            key = f"fault:{ti}"
            self.table[key] = self._solver(
                self._scenario_input(max(n_now - w, 0), ti), self.hw)
        self.table["join:1"] = self._solver(
            self._scenario_input(n_now + w, None), self.hw)
        for ti in range(len(self.tasks)):
            # task ti finished: its workers return to the pool
            rem_tasks = self.tasks[:ti] + self.tasks[ti + 1:]
            rem_assign = self.assignment[:ti] + self.assignment[ti + 1:]
            inp = PlanInput(rem_tasks, rem_assign, n_now,
                            self.d_running, self.d_transition,
                            (False,) * len(rem_tasks))
            self.table[f"finish:{ti}"] = self._solver(inp, self.hw)

    # ---- incremental build: shared rows + prefix/suffix DP chains ---------

    def _init_incremental(self) -> None:
        m = len(self.tasks)
        n_now = sum(self.assignment)
        w = self.workers_per_fault
        self._n_now = n_now
        self._n_join = n_now + w                # join is the largest budget
        self._n_max = max(self._n_join, self.n_budget or 0)
        self._n_fault = max(n_now - w, 0)
        self._rows: List[Optional[np.ndarray]] = [None] * m
        self._frows: Dict[int, np.ndarray] = {}
        self._P: List[Optional[np.ndarray]] = [None] * (m + 1)
        self._T: List[Optional[np.ndarray]] = [None] * (m + 1)
        self._P[0] = np.zeros(self._n_max + 1)
        self._T[m] = np.zeros(self._n_max + 1)
        # The chain engine keeps the PR-1/PR-2 kernels on purpose: that
        # path IS the preserved churn-rebuild baseline whose wall-clock
        # the bench speedup floors are measured against.  The segment
        # tree runs on the fused banded kernel (backend-dispatched);
        # outputs of all kernels are bitwise identical on the same
        # candidate sets.
        self._conv = _maxplus_vals_fast if self._cache else _maxplus_vals
        self._V: Dict[Tuple[int, int], np.ndarray] = {}
        self._sat_memo: Dict[Tuple[int, int], int] = {}
        # batched engine: complement vectors per tree node (Comp(X) =
        # merge of X's root-path siblings), their cumulative saturations
        # and sibling paths, plus value-only scenario results
        # (vector, argmax cell, total) pending lazy traceback.
        self._Comp: Dict[Tuple[int, int], np.ndarray] = {}
        self._csat: Dict[Tuple[int, int], int] = {}
        self._csibs: Dict[Tuple[int, int], Tuple] = {}
        self._scen: Dict[str, Tuple[np.ndarray, int, float]] = {}
        self._level_nodes: Optional[List[List[Tuple[int, int]]]] = None
        self._tree_built = False
        self._values_built = False
        cache = self._cache
        if cache is not None:
            self._pairs = tuple((cache.task_id(t), x)
                                for t, x in zip(self.tasks,
                                                self.assignment))
            self._sig = (self.hw, self._n_max, self.d_running,
                         self.d_transition)

    def _pkey(self, i: int):
        return ("P", self._sig, self._pairs[:i])

    def _skey(self, i: int):
        return ("T", self._sig, self._pairs[i:])

    def _rkey(self, i: int, faulted: bool):
        return ("G", self._sig, self._pairs[i], faulted)

    def _row(self, i: int, faulted: bool = False) -> np.ndarray:
        store = self._frows if faulted else self._rows
        row = store.get(i) if faulted else store[i]
        if row is not None:
            return row

        def build() -> np.ndarray:
            return waf_mod.reward_curve(
                self.tasks[i], self.assignment[i], self._n_max,
                d_running=self.d_running, d_transition=self.d_transition,
                worker_faulted=faulted, hw=self.hw)

        if self._cache is not None:
            row = self._cache.array(self._rkey(i, faulted), build)
        else:
            row = build()
        store[i] = row
        return row

    def _prefix(self, i: int) -> np.ndarray:
        """P[i]: DP value vector over tasks 0..i-1 (cache-chained)."""
        start = i
        while self._P[start] is None:
            if self._cache is not None:
                hit = self._cache.array(self._pkey(start))
                if hit is not None:
                    self._P[start] = hit
                    break
            start -= 1
        for t in range(start + 1, i + 1):
            if self._P[t] is None:
                arr = self._conv(self._P[t - 1], self._row(t - 1))
                if self._cache is not None:
                    self._cache.array(self._pkey(t), lambda: arr)
                self._P[t] = arr
        return self._P[i]

    def _suffix(self, i: int) -> np.ndarray:
        """T[i]: DP value vector over tasks i..m-1 (cache-chained)."""
        start = i
        while self._T[start] is None:
            if self._cache is not None:
                hit = self._cache.array(self._skey(start))
                if hit is not None:
                    self._T[start] = hit
                    break
            start += 1
        for t in range(start - 1, i - 1, -1):
            if self._T[t] is None:
                arr = self._conv(self._T[t + 1], self._row(t))
                if self._cache is not None:
                    self._cache.array(self._skey(t), lambda: arr)
                self._T[t] = arr
        return self._T[i]

    def _cwaf(self, tasks: Sequence[Task], assign: Sequence[int]) -> float:
        """Cluster WAF of an assembled plan.  With a cache, reads F(t, ·)
        vectors (same floats as the scalar ``waf`` — the sweep mirrors the
        scalar arithmetic) instead of per-(task, x) model evaluations."""
        if self._cache is None:
            return _cluster_waf(tasks, assign, self.hw)
        total = 0.0
        for t, x in zip(tasks, assign):
            F = self._cache.array(
                ("F", self.hw, self._cache.task_id(t)),
                lambda t=t: waf_mod.waf_curve(t, self._n_max, self.hw))
            x = int(x)
            if x < F.shape[0]:
                total += float(F[x])
            else:
                total += waf_mod.waf(t, x, self.hw)
        return total

    def _walk_prefix(self, last: int, budget: int,
                     assign: List[int]) -> None:
        for t in range(last, -1, -1):
            k = _argmax_at(self._prefix(t), self._row(t), budget)
            assign[t] = k
            budget -= k

    def _walk_suffix(self, first: int, budget: int, assign: List[int],
                     offset: int = 0) -> None:
        for t in range(first, len(self.tasks)):
            k = _argmax_at(self._suffix(t + 1), self._row(t), budget)
            assign[t - offset] = k
            budget -= k

    def _assemble_chain(self, key: str) -> Optional[Plan]:
        """Build one scenario plan from the shared rows and P/T chains
        (same combine order and tie-breaking as the eager build)."""
        m = len(self.tasks)
        if key == "join:1":
            # combine at the mid split so both chain halves stay reusable
            # across rebuilds (a change at position i only invalidates the
            # half containing i)
            s = m // 2
            combined = self._conv(self._prefix(s), self._suffix(s))
            j = int(np.argmax(combined[:self._n_join + 1]))
            assign = [0] * m
            b = _argmax_at(self._prefix(s), self._suffix(s), j)
            self._walk_prefix(s - 1, j - b, assign)
            self._walk_suffix(s, b, assign)
            return Plan(tuple(assign), float(combined[j]),
                        self._cwaf(self.tasks, assign))
        kind, _, idx = key.partition(":")
        if not idx.isdigit():
            return None
        ti = int(idx)
        if not 0 <= ti < m:
            return None
        if kind == "fault":
            frow = self._row(ti, faulted=True)
            mid = None
            if self._cache is not None:    # P[ti] (+) fault-row, by prefix
                mid = self._cache.array(("M", self._sig,
                                         self._pairs[:ti + 1]))
            if mid is None:
                mid = self._conv(self._prefix(ti), frow)
                if self._cache is not None:
                    self._cache.array(("M", self._sig,
                                       self._pairs[:ti + 1]), lambda: mid)
            combined = self._conv(mid, self._suffix(ti + 1))
            j = int(np.argmax(combined[:self._n_fault + 1]))
            total = float(combined[j])
            assign = [0] * m
            b = _argmax_at(mid, self._suffix(ti + 1), j)   # suffix budget
            k = _argmax_at(self._prefix(ti), frow, j - b)  # faulted task
            assign[ti] = k
            self._walk_prefix(ti - 1, j - b - k, assign)
            self._walk_suffix(ti + 1, b, assign)
            return Plan(tuple(assign), total,
                        self._cwaf(self.tasks, assign))
        if kind == "finish":
            combined = self._conv(self._prefix(ti), self._suffix(ti + 1))
            j = int(np.argmax(combined[:self._n_now + 1]))
            total = float(combined[j])
            assign = [0] * (m - 1)
            b = _argmax_at(self._prefix(ti), self._suffix(ti + 1), j)
            self._walk_prefix(ti - 1, j - b, assign)
            self._walk_suffix(ti + 1, b, assign, offset=1)
            rem = self.tasks[:ti] + self.tasks[ti + 1:]
            return Plan(tuple(assign), total, self._cwaf(rem, assign))
        return None

    # ---- segment-tree engine: dyadic span merges + complement chains ------

    def _band(self, i: int, faulted: bool = False) -> Optional[int]:
        """Band of task i's reward row: the row is flat past it (worker
        cap; plus the unfaulted row's no-transition spike at x_old), so
        banded convolutions with it are exact.  None = uncapped/dense."""
        cap = self.tasks[i].max_workers
        if cap is None:
            return None
        b = min(max(cap, 0), self._n_max)
        if not faulted:                    # g[x_old] spike breaks flatness
            b = min(max(b, self.assignment[i]), self._n_max)
        return b

    def _sat(self, lo: int, hi: int) -> int:
        """Saturation of span [lo, hi): V[lo, hi) is flat past the sum of
        its tasks' bands (more workers than every cap combined are idle).
        Memoized per table — the level sweeps consult every node's
        saturation repeatedly."""
        got = self._sat_memo.get((lo, hi))
        if got is not None:
            return got
        s = 0
        for i in range(lo, hi):
            b = self._band(i)
            s += self._n_max if b is None else b
            if s >= self._n_max:
                s = self._n_max
                break
        self._sat_memo[(lo, hi)] = s
        return s

    def _vkey(self, lo: int, hi: int):
        return ("V", self._sig, self._pairs[lo:hi])

    def _vvec(self, lo: int, hi: int) -> np.ndarray:
        """V[lo, hi): max-plus merge of the span's reward rows (best span
        reward using at most j workers), built by dyadic midpoint split
        and cached by span *contents* — a churn step at task u only
        invalidates the O(log m) spans containing u."""
        got = self._V.get((lo, hi))
        if got is not None:
            return got
        arr = None
        if self._cache is not None:
            arr = self._cache.array(self._vkey(lo, hi))
        if arr is None:
            if hi - lo == 1:
                arr = np.maximum.accumulate(self._row(lo))
            else:
                mid = (lo + hi) // 2
                left, right = self._vvec(lo, mid), self._vvec(mid, hi)
                sl, sr = self._sat(lo, mid), self._sat(mid, hi)
                if sl < sr:               # band by the flatter operand
                    arr = _conv_vals(right, left,
                                     sl if sl < self._n_max else None)
                else:
                    arr = _conv_vals(left, right,
                                     sr if sr < self._n_max else None)
            if self._cache is not None:
                self._cache.array(self._vkey(lo, hi), lambda: arr)
        self._V[(lo, hi)] = arr
        return arr

    def _path_sibs(self, ti: int) -> List[Tuple[int, int]]:
        """Siblings along the root -> leaf(ti) path, top-down: their
        union is every task except ti."""
        sibs: List[Tuple[int, int]] = []
        lo, hi = 0, len(self.tasks)
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if ti < mid:
                sibs.append((mid, hi))
                hi = mid
            else:
                sibs.append((lo, mid))
                lo = mid
        return sibs

    def _ckey(self, sibs: Sequence[Tuple[int, int]]):
        return ("C", self._sig, tuple(self._pairs[a:b] for a, b in sibs))

    def _compl_chain(self, ti: int):
        """Complement chain of leaf ti: Cs[i] merges the first i root-path
        siblings, so Cs[-1] is the DP value vector over every task except
        ti (the ``finish:ti`` vector, and the ``fault:ti`` base)."""
        sibs = self._path_sibs(ti)
        Cs = [np.zeros(self._n_max + 1)]
        satc = 0
        for i, (a, b) in enumerate(sibs):
            C = None
            if self._cache is not None:
                C = self._cache.array(self._ckey(sibs[: i + 1]))
            if C is None:
                sat_v = self._sat(a, b)
                if satc < sat_v:          # band by the flatter operand
                    C = _conv_vals(self._vvec(a, b), Cs[i],
                                   satc if satc < self._n_max else None)
                else:
                    C = _conv_vals(Cs[i], self._vvec(a, b),
                                   sat_v if sat_v < self._n_max else None)
                if self._cache is not None:
                    self._cache.array(self._ckey(sibs[: i + 1]), lambda: C)
            satc = min(satc + self._sat(a, b), self._n_max)
            Cs.append(C)
        return sibs, Cs

    def _walk_span(self, lo: int, hi: int, budget: int,
                   assign: List[int]) -> None:
        """Traceback inside span [lo, hi): recover the per-task workers
        achieving V[lo, hi)[budget] by descending the tree (first-max
        splits, like the chain walks)."""
        if hi - lo == 1:
            assign[lo] = int(np.argmax(self._row(lo)[:budget + 1]))
            return
        mid = (lo + hi) // 2
        b = _argmax_at(self._vvec(lo, mid), self._vvec(mid, hi), budget)
        self._walk_span(mid, hi, b, assign)
        self._walk_span(lo, mid, budget - b, assign)

    def _walk_compl(self, sibs, Cs, budget: int,
                    assign: List[int]) -> None:
        for i in range(len(sibs) - 1, -1, -1):
            a, b_hi = sibs[i]
            b = _argmax_at(Cs[i], self._vvec(a, b_hi), budget)
            self._walk_span(a, b_hi, b, assign)
            budget -= b

    def _assemble_segtree(self, key: str) -> Optional[Plan]:
        """Build one scenario plan from O(log m) cached node merges."""
        m = len(self.tasks)
        if key == "join:1":
            root = self._vvec(0, m)
            j = int(np.argmax(root[:self._n_join + 1]))
            assign = [0] * m
            self._walk_span(0, m, j, assign)
            return Plan(tuple(assign), float(root[j]),
                        self._cwaf(self.tasks, assign))
        kind, _, idx = key.partition(":")
        if not idx.isdigit():
            return None
        ti = int(idx)
        if not 0 <= ti < m:
            return None
        if kind not in ("fault", "finish"):
            return None
        sibs, Cs = self._compl_chain(ti)
        C = Cs[-1]
        if kind == "fault":
            frow = self._row(ti, faulted=True)
            combined = None
            fkey = None
            if self._cache is not None:
                fkey = self._fm_key(ti)
                combined = self._cache.array(fkey)
            if combined is None:
                combined = _conv_vals(C, frow, self._band(ti, faulted=True))
                if self._cache is not None:
                    self._cache.array(fkey, lambda: combined)
            j = int(np.argmax(combined[:self._n_fault + 1]))
            total = float(combined[j])
            assign = [0] * m
            k = _argmax_at(C, frow, j)
            assign[ti] = k
            self._walk_compl(sibs, Cs, j - k, assign)
            return Plan(tuple(assign), total,
                        self._cwaf(self.tasks, assign))
        j = int(np.argmax(C[:self._n_now + 1]))
        total = float(C[j])
        assign = [0] * m
        self._walk_compl(sibs, Cs, j, assign)
        del assign[ti]
        rem = self.tasks[:ti] + self.tasks[ti + 1:]
        return Plan(tuple(assign), total, self._cwaf(rem, assign))

    # ---- batched engine: level-synchronous stacked sweeps + lazy traceback -

    def _fm_key(self, ti: int):
        """Cache key of the ``fault:ti`` combined vector (cache only)."""
        return ("FM", self._sig,
                (self._pairs[:ti], self._pairs[ti + 1:]), self._pairs[ti])

    def _levels(self) -> List[List[Tuple[int, int]]]:
        """Dyadic tree nodes grouped by depth (root first), memoized."""
        if self._level_nodes is None:
            out: List[List[Tuple[int, int]]] = []

            def walk(lo: int, hi: int, d: int) -> None:
                if len(out) <= d:
                    out.append([])
                out[d].append((lo, hi))
                if hi - lo > 1:
                    mid = (lo + hi) // 2
                    walk(lo, mid, d + 1)
                    walk(mid, hi, d + 1)

            walk(0, len(self.tasks), 0)
            self._level_nodes = out
        return self._level_nodes

    def _launch(self, rows: List[Tuple[np.ndarray, np.ndarray,
                                       Optional[int]]]) -> np.ndarray:
        """One stacked kernel launch over ``rows`` of (prev, g, band).
        A single-row level skips the stacking machinery — the 2-D kernel
        is the identical computation (and tiny tables are all single-row
        levels)."""
        self.batch_stats["launches"] += 1
        if len(rows) == 1:
            prev, g, band = rows[0]
            return _conv_vals(prev, g, band)[None, :]
        prev = np.stack([r[0] for r in rows])
        g = np.stack([r[1] for r in rows])
        return _conv_vals_batched(prev, g, [r[2] for r in rows])

    def _node_hit(self, lo: int, hi: int) -> Optional[np.ndarray]:
        got = self._V.get((lo, hi))
        if got is None and self._cache is not None:
            got = self._cache.array(self._vkey(lo, hi))
            if got is not None:
                self._V[(lo, hi)] = got
        return got

    def _store_node(self, lo: int, hi: int, arr: np.ndarray) -> None:
        self._V[(lo, hi)] = arr
        if self._cache is not None:
            self._cache.array(self._vkey(lo, hi), lambda: arr)

    def _build_spans(self, roots: List[Tuple[int, int, int]]) -> None:
        """Level-synchronous V build of the given (lo, hi, depth)
        subtrees: descend pruning spans the cache already holds, build
        every missing leaf as one vectorized running-max pass, then merge
        each level's internal nodes with ONE stacked banded launch,
        bottom-up.  Same merges, operand orders and bands as ``_vvec`` —
        floats are identical.  Depths are global tree depths, so nodes of
        different subtrees land in shared level launches."""
        roots = [r for r in roots if (r[0], r[1]) not in self._V]
        if not roots:
            return
        need: List[List[Tuple[int, int]]] = [[] for _ in self._levels()]

        def visit(lo: int, hi: int, d: int) -> None:
            if self._node_hit(lo, hi) is not None:
                return
            need[d].append((lo, hi))
            if hi - lo > 1:
                mid = (lo + hi) // 2
                visit(lo, mid, d + 1)
                visit(mid, hi, d + 1)

        for lo, hi, d in roots:
            visit(lo, hi, d)
        leaves = [nd for lvl in need for nd in lvl if nd[1] - nd[0] == 1]
        if leaves:
            rows = np.stack([self._row(lo) for lo, _ in leaves])
            acc = np.maximum.accumulate(rows, axis=1)
            for r, (lo, hi) in enumerate(leaves):
                self._store_node(lo, hi, acc[r])
        for d in range(len(need) - 1, -1, -1):
            todo = [nd for nd in need[d] if nd[1] - nd[0] > 1]
            if not todo:
                continue
            stack = []
            for lo, hi in todo:
                mid = (lo + hi) // 2
                left, right = self._V[(lo, mid)], self._V[(mid, hi)]
                sl, sr = self._sat(lo, mid), self._sat(mid, hi)
                if sl < sr:               # band by the flatter operand
                    stack.append((right, left,
                                  sl if sl < self._n_max else None))
                else:
                    stack.append((left, right,
                                  sr if sr < self._n_max else None))
            out = self._launch(stack)
            self.batch_stats["levels"] += 1
            for r, (lo, hi) in enumerate(todo):
                self._store_node(lo, hi, out[r])

    def _ensure_tree(self) -> None:
        """Whole-tree V sweep (the join scenario and the whole-table
        value rebuild consume every node)."""
        if self._tree_built:
            return
        self._build_spans([(0, len(self.tasks), 0)])
        self._tree_built = True

    def _ensure_chain_spans(self, ti: int) -> None:
        """Build exactly the sibling subtrees leaf ti's complement chain
        merges — the same node set the segtree engine's recursive
        ``_vvec`` calls would touch for this scenario, but launched per
        level instead of per node.  Single cold dispatches therefore
        never pay for the root-path merges only ``join`` needs."""
        missing = [(a, b, i + 1)
                   for i, (a, b) in enumerate(self._path_sibs(ti))
                   if (a, b) not in self._V]
        if missing:
            self._build_spans(missing)

    def _comp_meta(self, child: Tuple[int, int], parent: Tuple[int, int],
                   sib: Tuple[int, int]) -> None:
        """Sibling path and cumulative saturation of a comp-tree child."""
        self._csibs[child] = self._csibs[parent] + (sib,)
        self._csat[child] = min(self._csat[parent] + self._sat(*sib),
                                self._n_max)

    def _comp_root(self) -> Tuple[int, int]:
        root = (0, len(self.tasks))
        if root not in self._Comp:
            self._Comp[root] = np.zeros(self._n_max + 1)
        self._csat.setdefault(root, 0)
        self._csibs.setdefault(root, ())
        return root

    def _total_entry(self, vec: np.ndarray,
                     limit: int) -> Tuple[np.ndarray, int, float]:
        j = int(np.argmax(vec[:limit + 1]))
        return vec, j, float(vec[j])

    def _ensure_values(self) -> None:
        """Whole-table value rebuild: the complement vector of EVERY tree
        node via one top-down level-parallel sweep (all children of a
        level in one stacked launch — the m per-leaf chains overlap in
        exactly these O(m) distinct nodes, so nothing is recomputed per
        scenario), then all m fault combines in one more launch, then
        every scenario's total.  NO argmax tracebacks — ``lookup`` runs
        those lazily for the scenario actually dispatched.

        On the fused engine the identical sweep (same operands, orders
        and bands) runs as ONE compiled device dispatch instead."""
        if self._values_built:
            return
        if self.engine == "fused":
            self._ensure_values_fused()
            return
        self._ensure_tree()
        m = len(self.tasks)
        self._comp_root()
        levels = self._levels()
        for d in range(len(levels) - 1):
            todo, stack = [], []
            for lo, hi in levels[d]:
                if hi - lo == 1:
                    continue
                mid = (lo + hi) // 2
                for child, sib in (((lo, mid), (mid, hi)),
                                   ((mid, hi), (lo, mid))):
                    self._comp_meta(child, (lo, hi), sib)
                    if child in self._Comp:
                        continue
                    C = None
                    if self._cache is not None:
                        C = self._cache.array(
                            self._ckey(self._csibs[child]))
                    if C is not None:
                        self._Comp[child] = C
                        continue
                    satc = self._csat[(lo, hi)]
                    sat_v = self._sat(*sib)
                    if satc < sat_v:      # band by the flatter operand
                        stack.append((self._vvec(*sib), self._Comp[(lo, hi)],
                                      satc if satc < self._n_max else None))
                    else:
                        stack.append((self._Comp[(lo, hi)], self._vvec(*sib),
                                      sat_v if sat_v < self._n_max else None))
                    todo.append(child)
            if todo:
                out = self._launch(stack)
                self.batch_stats["levels"] += 1
                for r, child in enumerate(todo):
                    arr = out[r]
                    self._Comp[child] = arr
                    if self._cache is not None:
                        self._cache.array(self._ckey(self._csibs[child]),
                                          lambda a=arr: a)
        todo, stack = [], []
        for ti in range(m):
            key = f"fault:{ti}"
            if key in self._scen:
                continue
            combined = None
            if self._cache is not None:
                combined = self._cache.array(self._fm_key(ti))
            if combined is not None:
                self._scen[key] = self._total_entry(combined, self._n_fault)
                continue
            stack.append((self._Comp[(ti, ti + 1)],
                          self._row(ti, faulted=True),
                          self._band(ti, faulted=True)))
            todo.append(ti)
        if todo:
            out = self._launch(stack)
            for r, ti in enumerate(todo):
                arr = out[r]
                if self._cache is not None:
                    self._cache.array(self._fm_key(ti), lambda a=arr: a)
                self._scen[f"fault:{ti}"] = self._total_entry(
                    arr, self._n_fault)
        for ti in range(m):
            self._scen.setdefault(f"finish:{ti}", self._total_entry(
                self._Comp[(ti, ti + 1)], self._n_now))
        self._scen.setdefault("join:1", self._total_entry(
            self._vvec(0, m), self._n_join))
        self._values_built = True

    def _fused_signature(self) -> Tuple:
        """Schedule signature of this table: the static inputs the
        compiled fused program is keyed (and retraced) on.  Bands are
        normalized to ``n_max`` for uncapped/dense rows."""
        m = len(self.tasks)
        bu = tuple(self._n_max if b is None else b
                   for b in (self._band(i) for i in range(m)))
        bf = tuple(self._n_max if b is None else b
                   for b in (self._band(i, faulted=True)
                             for i in range(m)))
        return (m, self._n_max, bu, bf, get_maxplus_backend())

    def _ensure_values_fused(self) -> None:
        """Whole-table value rebuild as ONE compiled device dispatch:
        fetch (or build) the signature-keyed fused program, hand it the
        reward-row stacks and per-scenario argmax limits, and unpack the
        returned slot buffer into the batched engine's stores — the
        host-side lazy traceback machinery then works unchanged.  Node
        vectors are deliberately NOT written to the ``PlannerCache``
        array store: on this path the program cache is the reuse
        mechanism, and a recurring cluster state is already a whole-table
        hit at the ``PlannerCache.table`` level."""
        m = len(self.tasks)
        prog = _fused_program(*self._fused_signature())
        g_unf = np.stack([np.asarray(self._row(i), dtype=float)
                          for i in range(m)])
        g_f = np.stack([np.asarray(self._row(i, faulted=True),
                                   dtype=float) for i in range(m)])
        limits = np.asarray([self._n_fault] * m + [self._n_now] * m
                            + [self._n_join], dtype=np.int32)
        vals, js, totals = prog(g_unf, g_f, limits)
        self.batch_stats["device_dispatches"] += 1
        sched = prog.sched
        for node, si in sched.v_slot.items():
            self._V[node] = vals[si]
        self._comp_root()
        for node, si in sched.c_slot.items():
            self._Comp.setdefault(node, vals[si])
        self._sat_memo.update(sched.sat_map)
        self._csat.update(sched.csat_map)
        self._csibs.update(sched.csibs_map)
        for ti in range(m):
            self._scen.setdefault(
                f"fault:{ti}", (vals[sched.fault_slot[ti]],
                                int(js[ti]), float(totals[ti])))
            self._scen.setdefault(
                f"finish:{ti}", (self._Comp[(ti, ti + 1)],
                                 int(js[m + ti]), float(totals[m + ti])))
        self._scen.setdefault("join:1", (self._V[(0, m)], int(js[2 * m]),
                                         float(totals[2 * m])))
        self._tree_built = True
        self._values_built = True

    def _chain_batched(self, ti: int):
        """(sibs, Cs) complement chain of leaf ti, reading the level-sweep
        store and computing (and storing) only missing links — the
        single-dispatch path shares every vector with the whole-table
        sweep (same operands, orders and bands: identical floats).

        Like the segtree engine's chain, a cached link costs nothing:
        the sibling V subtrees are only built — one stacked level launch
        per level, restricted to the missing siblings — past the longest
        already-known chain prefix."""
        sibs = self._path_sibs(ti)
        path = [self._comp_root()]
        for a, b in sibs:
            lo, hi = path[-1]
            mid = (lo + hi) // 2
            path.append((lo, mid) if (a, b) == (mid, hi) else (mid, hi))
        Cs = [self._Comp[path[0]]]
        known = 0
        for i, (sib, child) in enumerate(zip(sibs, path[1:])):
            self._comp_meta(child, path[i], sib)
            C = self._Comp.get(child)
            if C is None and self._cache is not None:
                C = self._cache.array(self._ckey(self._csibs[child]))
                if C is not None:
                    self._Comp[child] = C
            if C is None:
                break
            Cs.append(C)
            known = i + 1
        if known == len(sibs):
            return sibs, Cs
        self._build_spans([(a, b, i + 1)
                           for i, (a, b) in enumerate(sibs)
                           if i >= known and (a, b) not in self._V])
        for i in range(known, len(sibs)):
            a, b = sibs[i]
            child = path[i + 1]
            self._comp_meta(child, path[i], (a, b))
            C = self._Comp.get(child)
            if C is None and self._cache is not None:
                C = self._cache.array(self._ckey(self._csibs[child]))
            if C is None:
                satc = self._csat[path[i]]
                sat_v = self._sat(a, b)
                if satc < sat_v:          # band by the flatter operand
                    C = _conv_vals(self._vvec(a, b), Cs[-1],
                                   satc if satc < self._n_max else None)
                else:
                    C = _conv_vals(Cs[-1], self._vvec(a, b),
                                   sat_v if sat_v < self._n_max else None)
                if self._cache is not None:
                    self._cache.array(self._ckey(self._csibs[child]),
                                      lambda: C)
            self._Comp[child] = C
            Cs.append(C)
        return sibs, Cs

    def _fault_combined(self, ti: int, C: np.ndarray) -> np.ndarray:
        """``fault:ti`` combined vector: C(leaf ti) (+) fault-row, cache
        -shared with the whole-table sweep."""
        combined = None
        if self._cache is not None:
            combined = self._cache.array(self._fm_key(ti))
        if combined is None:
            combined = _conv_vals(C, self._row(ti, faulted=True),
                                  self._band(ti, faulted=True))
            if self._cache is not None:
                self._cache.array(self._fm_key(ti), lambda: combined)
        return combined

    def _parse_leaf_key(self, key: str) -> Optional[Tuple[str, int]]:
        kind, _, idx = key.partition(":")
        if kind not in ("fault", "finish") or not idx.isdigit():
            return None
        ti = int(idx)
        if not 0 <= ti < len(self.tasks):
            return None
        return kind, ti

    def _scen_entry(self, key: str
                    ) -> Optional[Tuple[np.ndarray, int, float]]:
        """Value-only scenario result (vector, argmax cell, total): from
        the whole-table sweep when built, else assembled for this key
        alone (single dispatches stay O(chain), not O(table))."""
        got = self._scen.get(key)
        if got is not None:
            return got
        if key == "join:1":
            self._ensure_tree()
            entry = self._total_entry(self._vvec(0, len(self.tasks)),
                                      self._n_join)
        else:
            parsed = self._parse_leaf_key(key)
            if parsed is None:
                return None
            kind, ti = parsed
            _, Cs = self._chain_batched(ti)
            if kind == "finish":
                entry = self._total_entry(Cs[-1], self._n_now)
            else:
                entry = self._total_entry(self._fault_combined(ti, Cs[-1]),
                                          self._n_fault)
        self._scen[key] = entry
        return entry

    def _assemble_batched(self, key: str) -> Optional[Plan]:
        """Materialize one scenario's Plan: value vectors from the batched
        store, then the lazy argmax traceback for just this key."""
        m = len(self.tasks)
        if key == "join:1":
            entry = self._scen_entry(key)
            vec, j, total = entry
            self.batch_stats["tracebacks"] += 1
            assign = [0] * m
            self._walk_span(0, m, j, assign)
            return Plan(tuple(assign), total,
                        self._cwaf(self.tasks, assign))
        parsed = self._parse_leaf_key(key)
        if parsed is None:
            return None
        kind, ti = parsed
        sibs, Cs = self._chain_batched(ti)
        entry = self._scen.get(key)
        if entry is None:
            if kind == "finish":
                entry = self._total_entry(Cs[-1], self._n_now)
            else:
                entry = self._total_entry(self._fault_combined(ti, Cs[-1]),
                                          self._n_fault)
            self._scen[key] = entry
        vec, j, total = entry
        self.batch_stats["tracebacks"] += 1
        # the argmax walks descend every sibling subtree, so build them
        # (level-launched; usually warm) even when the chain was cached
        self._ensure_chain_spans(ti)
        assign = [0] * m
        if kind == "fault":
            k = _argmax_at(Cs[-1], self._row(ti, faulted=True), j)
            assign[ti] = k
            self._walk_compl(sibs, Cs, j - k, assign)
            return Plan(tuple(assign), total,
                        self._cwaf(self.tasks, assign))
        self._walk_compl(sibs, Cs, j, assign)
        del assign[ti]
        rem = self.tasks[:ti] + self.tasks[ti + 1:]
        return Plan(tuple(assign), total, self._cwaf(rem, assign))

    def rebuild_values(self) -> Dict[str, float]:
        """Whole-table value rebuild: every scenario's value vector and
        total reward with NO assignment tracebacks.  Batched engine: a
        constant number of stacked launches per tree level; fused
        engine: ONE compiled device dispatch
        (``batch_stats["device_dispatches"]``).  Returns ``{scenario
        key: total reward}``.  The other engines (and the reference
        path) fall back to materializing every plan — that per-scenario
        cost is exactly what the whole-table churn benchmark measures
        against."""
        if self.engine in ("batched", "fused") and self._incremental:
            self._ensure_values()
            return {k: self._scen[k][2] for k in self.scenario_keys()}
        out: Dict[str, float] = {}
        for k in self.scenario_keys():
            plan = self.lookup(k)
            if plan is not None:
                out[k] = plan.total_reward
        return out

    def scenario_total(self, key: str) -> Optional[float]:
        """Total reward of one scenario without materializing its
        assignment.  Batched/fused engines: triggers the whole-table
        value sweep (totals are a whole-table product; single dispatches
        should use ``lookup``).  The other engines assemble the full
        plan."""
        if self.engine in ("batched", "fused") and self._incremental:
            hit = self.table.get(key)
            if hit is not None:
                return hit.total_reward
            self._ensure_values()
            entry = self._scen.get(key)
            return None if entry is None else entry[2]
        plan = self.lookup(key)
        return None if plan is None else plan.total_reward

    def _assemble(self, key: str) -> Optional[Plan]:
        if self.engine in ("batched", "fused"):
            # the fused engine shares the batched host-side machinery
            # for lazy single lookups and every argmax traceback
            return self._assemble_batched(key)
        if self.engine == "segtree":
            return self._assemble_segtree(key)
        return self._assemble_chain(key)

    def lookup(self, key: str) -> Optional[Plan]:
        plan = self.table.get(key)
        if plan is None and self._incremental and key not in self.table:
            plan = self._assemble(key)
            if plan is not None:
                self.table[key] = plan
        return plan


class PlannerCache:
    """Cross-rebuild planner cache (the ROADMAP follow-up to the PR-1
    incremental engine): reward rows, prefix/suffix DP value chains, whole
    lazy ``PlanTable``s, and fresh ``solve`` plans, shared across every
    rebuild a churn-heavy simulation issues.

    * A rebuild where only one task's assignment changed finds every P
      chain up to the change and every T chain past it already cached, and
      recomputes only the remainder.
    * A *recurring* cluster state (same task set + assignment + durations)
      is a whole-table hit — its scenarios are never reassembled.
    * Fresh solves (table misses, task launches) are memoized by their
      full ``PlanInput``.

    All stores are bounded LRUs; ``stats()`` exposes hit/miss counters for
    the benchmarks.  Plans served from the cache are float-identical to an
    uncached build: keys include every input the arrays depend on.
    """

    def __init__(self, max_arrays: int = 32768, max_tables: int = 4096,
                 max_plans: int = 32768):
        self._arrays: OrderedDict = OrderedDict()
        self._tables: OrderedDict = OrderedDict()
        self._plans: OrderedDict = OrderedDict()
        self._caps = {"arrays": max_arrays, "tables": max_tables,
                      "plans": max_plans}
        self._task_ids: Dict[object, int] = {}
        self._lock = threading.RLock()
        self.hits = {"arrays": 0, "tables": 0, "plans": 0}
        self.misses = {"arrays": 0, "tables": 0, "plans": 0}

    def task_id(self, task) -> int:
        """Intern a task: chain keys hash small ints, not task objects."""
        with self._lock:
            tid = self._task_ids.get(task)
            if tid is None:
                tid = len(self._task_ids)
                self._task_ids[task] = tid
            return tid

    def _memo(self, store: OrderedDict, name: str, key, build):
        """Thread-compatible get-or-build.  The build runs outside the
        lock: concurrent Monte-Carlo seeds may duplicate a computation,
        but every entry is fully determined by its key, so whichever
        lands is identical — results never depend on scheduling."""
        with self._lock:
            got = store.get(key)
            if got is not None:
                store.move_to_end(key)
                self.hits[name] += 1
                return got
        if build is None:
            return None
        got = build()
        with self._lock:
            if key not in store:
                self.misses[name] += 1
                store[key] = got
                if len(store) > self._caps[name]:
                    store.popitem(last=False)
            else:
                got = store[key]
        return got

    def array(self, key, build=None) -> Optional[np.ndarray]:
        return self._memo(self._arrays, "arrays", key, build)

    def table(self, tasks: Sequence[Task], assignment: Sequence[int],
              hw: Hardware, d_running: float, d_transition: float,
              workers_per_fault: int = 8,
              n_budget: Optional[int] = None,
              engine: Optional[str] = None,
              task_ids: Optional[Tuple[int, ...]] = None,
              prebuild: bool = False) -> PlanTable:
        """A lazy PlanTable for this cluster state, memoized by state.
        ``engine``: canonical name from ``engines()["engine"]`` (default
        ``"batched"``; part of the memo key).  ``task_ids``: the
        already-interned ``task_id`` tuple for ``tasks`` (callers that
        refresh per event keep it across rebuilds — the task set only
        changes on churn).  ``prebuild=True`` runs the whole-table value
        rebuild before returning (idempotent; on the batched engine a
        constant number of stacked launches per tree level, value-only —
        no tracebacks): churn-driven coordinators use it to restore
        O(1)-ish dispatch for every scenario after a task set change."""
        engine = resolve_engine(engine)
        tasks, assignment = tuple(tasks), tuple(assignment)
        if task_ids is None:
            task_ids = tuple(self.task_id(t) for t in tasks)
        key = (task_ids, assignment, hw,
               d_running, d_transition, workers_per_fault, n_budget,
               engine)
        table = self._memo(
            self._tables, "tables", key,
            lambda: PlanTable(tasks, assignment, hw, d_running,
                              d_transition, workers_per_fault,
                              lazy=True, cache=self, n_budget=n_budget,
                              engine=engine))
        if prebuild:
            table.rebuild_values()
        return table

    def solve(self, inp: PlanInput, hw: Hardware) -> Plan:
        """Memoized fresh dispatch (``solve_fast`` — same plans as
        ``solve``, value-chain kernel)."""
        key = (tuple(self.task_id(t) for t in inp.tasks), inp.assignment,
               inp.n_workers, inp.d_running, inp.d_transition,
               inp.faulted, hw)
        return self._memo(self._plans, "plans", key,
                          lambda: solve_fast(inp, hw))

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {"hits": dict(self.hits), "misses": dict(self.misses),
                "sizes": {"arrays": len(self._arrays),
                          "tables": len(self._tables),
                          "plans": len(self._plans)}}
