"""Optimal reconfiguration plan generation (§5.2).

Knapsack-style dynamic program over (tasks x workers):

    S(i, j) = max_k { S(i-1, j-k) + G(t_i, k) }           (Eq. 5)

O(m n^2) time; ``PlanTable`` additionally precomputes the one-step
lookahead lookup table the paper uses for O(1) dispatch at failure time —
keyed by (faulted task or joining worker count) scenarios.

``brute_force`` is an exponential reference used by the property tests.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import waf as waf_mod
from repro.core.costmodel import Hardware
from repro.core.waf import Task


@dataclass(frozen=True)
class PlanInput:
    tasks: Tuple[Task, ...]
    assignment: Tuple[int, ...]        # current workers per task (x_i)
    n_workers: int                     # n' available after the event
    d_running: float
    d_transition: float
    faulted: Tuple[bool, ...]          # per task: did one of its workers fault


@dataclass(frozen=True)
class Plan:
    assignment: Tuple[int, ...]
    total_reward: float
    waf: float                         # cluster WAF under the new assignment


def _reward_row(inp: PlanInput, i: int, hw: Hardware) -> List[float]:
    """G(t_i, k) for k = 0..n_workers."""
    t = inp.tasks[i]
    return [waf_mod.reward(t, inp.assignment[i], k,
                           d_running=inp.d_running,
                           d_transition=inp.d_transition,
                           worker_faulted=inp.faulted[i], hw=hw)
            for k in range(inp.n_workers + 1)]


def solve(inp: PlanInput, hw: Hardware) -> Plan:
    """Dynamic program (Eq. 5) with traceback."""
    m, n = len(inp.tasks), inp.n_workers
    rows = [_reward_row(inp, i, hw) for i in range(m)]
    NEG = float("-inf")
    # S[i][j]: best reward of first i tasks using j workers
    S = [[0.0] + [0.0] * n]
    choice: List[List[int]] = []
    for i in range(1, m + 1):
        row = [NEG] * (n + 1)
        ch = [0] * (n + 1)
        g = rows[i - 1]
        for j in range(n + 1):
            best, bk = NEG, 0
            for k in range(j + 1):
                v = S[i - 1][j - k] + g[k]
                if v > best:
                    best, bk = v, k
            row[j], ch[j] = best, bk
        S.append(row)
        choice.append(ch)
    # traceback from S(m, n)
    assign = [0] * m
    j = max(range(n + 1), key=lambda jj: S[m][jj])
    total = S[m][j]
    for i in range(m, 0, -1):
        k = choice[i - 1][j]
        assign[i - 1] = k
        j -= k
    cluster_waf = sum(waf_mod.waf(t, x, hw)
                      for t, x in zip(inp.tasks, assign))
    return Plan(tuple(assign), total, cluster_waf)


def brute_force(inp: PlanInput, hw: Hardware) -> Plan:
    """Exponential reference solver (tests only)."""
    m, n = len(inp.tasks), inp.n_workers
    rows = [_reward_row(inp, i, hw) for i in range(m)]
    best: Optional[Tuple[float, Tuple[int, ...]]] = None
    for assign in itertools.product(range(n + 1), repeat=m):
        if sum(assign) > n:
            continue
        v = sum(rows[i][assign[i]] for i in range(m))
        if best is None or v > best[0]:
            best = (v, assign)
    v, assign = best
    cluster_waf = sum(waf_mod.waf(t, x, hw)
                      for t, x in zip(inp.tasks, assign))
    return Plan(tuple(assign), v, cluster_waf)


class PlanTable:
    """Precomputed lookup table (§5.2 'Complexity'): one-step lookahead
    plans for every single-event scenario from the current configuration —
    any task losing one worker, a worker joining, a task finishing —
    giving O(1) dispatch when the event actually happens."""

    def __init__(self, tasks: Sequence[Task], assignment: Sequence[int],
                 hw: Hardware, d_running: float, d_transition: float,
                 workers_per_fault: int = 8):
        self.tasks = tuple(tasks)
        self.assignment = tuple(assignment)
        self.hw = hw
        self.d_running = d_running
        self.d_transition = d_transition
        self.workers_per_fault = workers_per_fault  # a node drain = 8 GPUs
        self.table: Dict[str, Plan] = {}
        self._precompute()

    def _scenario_input(self, n_workers: int,
                        faulted_task: Optional[int]) -> PlanInput:
        faulted = tuple(i == faulted_task for i in range(len(self.tasks)))
        return PlanInput(self.tasks, self.assignment, n_workers,
                         self.d_running, self.d_transition, faulted)

    def _precompute(self) -> None:
        n_now = sum(self.assignment)
        w = self.workers_per_fault
        for ti in range(len(self.tasks)):
            key = f"fault:{ti}"
            self.table[key] = solve(
                self._scenario_input(max(n_now - w, 0), ti), self.hw)
        self.table["join:1"] = solve(
            self._scenario_input(n_now + w, None), self.hw)
        for ti in range(len(self.tasks)):
            # task ti finished: its workers return to the pool
            rem_tasks = self.tasks[:ti] + self.tasks[ti + 1:]
            rem_assign = self.assignment[:ti] + self.assignment[ti + 1:]
            inp = PlanInput(rem_tasks, rem_assign, n_now,
                            self.d_running, self.d_transition,
                            (False,) * len(rem_tasks))
            self.table[f"finish:{ti}"] = solve(inp, self.hw)

    def lookup(self, key: str) -> Optional[Plan]:
        return self.table.get(key)
