"""Optimal reconfiguration plan generation (§5.2).

Knapsack-style dynamic program over (tasks x workers):

    S(i, j) = max_k { S(i-1, j-k) + G(t_i, k) }           (Eq. 5)

Two solver paths share the recurrence:

* ``solve`` — the vectorized engine: reward rows come out of the memoized
  cost-model sweep as whole vectors (``waf.reward_curve``), and the DP inner
  loop is a max-plus convolution evaluated as one NumPy windowed matrix per
  task (O(n^2) cells but a single vector op), with argmax traceback.
* ``solve_reference`` — the original pure-Python scalar DP, kept as the
  ground truth for property tests and the speedup baseline.

Max-plus kernel family
----------------------
The DP inner loop is a max-plus (tropical) convolution; four evaluations
share the candidate set (``prev[j-k] + g[k]``), so their maxima agree:

* ``_maxplus_vals`` — plain windowed matrix (PR-1 baseline kernel);
* ``_maxplus_vals_fast`` — row-blocked (PR-2 chain-engine kernel);
* ``_maxplus_vals_fused`` — tiled fused add+max: candidate tiles are added
  and max-reduced block-by-block so the (n x n) candidate matrix is never
  materialized, and an optional **band** restricts the convolution to
  ``k <= band``.  The band is sound whenever ``prev`` is monotone
  non-decreasing (every DP value vector is) and ``g`` is flat past the
  band (reward rows of tasks with ``Task.max_workers`` caps are; so are
  span value vectors past the sum of their tasks' caps) — the banded
  output is then bitwise-identical to the dense one.
* ``kernels.maxplus.maxplus_conv`` — Pallas TPU kernel (interpret on
  CPU/GPU, compiled via Mosaic on TPU), float32.  Selected with the
  backend switch: ``set_maxplus_backend("pallas")`` or
  ``REPRO_PLANNER_BACKEND=pallas``; default stays ``numpy`` (float64).

Segment-tree incremental engine
-------------------------------
``PlanTable`` precomputes the one-step lookahead lookup table the paper
uses for O(1) dispatch at failure time.  Two incremental engines build it:

* ``engine="segtree"`` (default) — a dyadic segment tree over task
  positions.  Each node stores the max-plus merge V[lo, hi) of its span's
  reward rows (leaves are running maxima, internal nodes one banded
  convolution of their children), and every scenario assembles from
  O(log m) cached node merges: ``join`` reads the root, ``finish:i`` the
  complement chain C(i) = merge of i's root-path siblings, ``fault:i``
  one extra banded convolution of C(i) with the fault row.  A churn step
  that changes one task's reward row therefore invalidates only the
  O(log m) nodes on its root path (plus the complements crossing it)
  instead of the O(m) prefix/suffix chain tail.
* ``engine="chain"`` — the PR-2 prefix/suffix DP chains, kept unchanged
  as the churn-rebuild speedup baseline (``bench_planner_scale``).

With ``lazy=True`` scenarios (and the node merges feeding them) are
assembled on first ``lookup``; with a ``PlannerCache`` reward rows and
node/chain vectors are keyed by their span *contents* and reused across
rebuilds, and a recurring cluster state is a whole-table hit.  The
churn-heavy cluster simulator (``core.simulator.VectorSimulator``) is the
main consumer.

``brute_force`` is an exponential reference used by the property tests.
Regenerate the committed benchmark baselines (``results/bench_*.json``)
with ``python benchmarks/run.py`` after any reward-model change here.
"""
from __future__ import annotations

import itertools
import os
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import waf as waf_mod
from repro.core.costmodel import Hardware, TaskModel
from repro.core.waf import Task

NEG = float("-inf")


@dataclass(frozen=True)
class PlanInput:
    tasks: Tuple[Task, ...]
    assignment: Tuple[int, ...]        # current workers per task (x_i)
    n_workers: int                     # n' available after the event
    d_running: float
    d_transition: float
    faulted: Tuple[bool, ...]          # per task: did one of its workers fault


@dataclass(frozen=True)
class Plan:
    assignment: Tuple[int, ...]
    total_reward: float
    waf: float                         # cluster WAF under the new assignment


def _vector_capable(tasks: Sequence) -> bool:
    """Reward rows can be built from the cost-model sweep (real ``Task``s
    with analytic ``TaskModel``s).  Duck-typed tasks — e.g. the tabulated
    tasks the property tests use with a monkeypatched ``waf`` — fall back
    to the scalar row builder so they keep their custom semantics."""
    return all(isinstance(t, Task) and isinstance(t.model, TaskModel)
               for t in tasks)


def _reward_row(inp: PlanInput, i: int, hw: Hardware) -> List[float]:
    """G(t_i, k) for k = 0..n_workers (scalar reference path)."""
    t = inp.tasks[i]
    return [waf_mod.reward(t, inp.assignment[i], k,
                           d_running=inp.d_running,
                           d_transition=inp.d_transition,
                           worker_faulted=inp.faulted[i], hw=hw)
            for k in range(inp.n_workers + 1)]


def _reward_matrix(inp: PlanInput, hw: Hardware) -> np.ndarray:
    """All m reward rows as an (m, n+1) matrix."""
    if _vector_capable(inp.tasks):
        return np.stack([
            waf_mod.reward_curve(t, inp.assignment[i], inp.n_workers,
                                 d_running=inp.d_running,
                                 d_transition=inp.d_transition,
                                 worker_faulted=inp.faulted[i], hw=hw)
            for i, t in enumerate(inp.tasks)])
    return np.array([_reward_row(inp, i, hw)
                     for i in range(len(inp.tasks))], dtype=float)


def _maxplus(prev: np.ndarray, g: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """One max-plus convolution step: out[j] = max_{0<=k<=j} prev[j-k] + g[k],
    plus the argmax k per j (first/lowest k on ties, matching the scalar
    DP's strict-improvement rule)."""
    n = prev.shape[0] - 1
    pad = np.concatenate([np.full(n, NEG), prev])
    win = np.lib.stride_tricks.sliding_window_view(pad, n + 1)
    vals = win[:, ::-1] + g[None, :]   # vals[j, k] = prev[j-k] + g[k]
    ch = vals.argmax(axis=1)           # one O(n^2) scan serves both outputs
    return vals[np.arange(n + 1), ch], ch


def _maxplus_vals(prev: np.ndarray, g: np.ndarray) -> np.ndarray:
    """Value vector of one max-plus step, without the per-cell argmax.

    Same candidate set per cell as ``_maxplus`` (so the maxima are
    float-identical), but evaluated without reversing the O(n^2) window
    matrix; tracebacks recover choices per *visited* cell via
    ``_argmax_at`` instead of materializing the whole argmax matrix."""
    n = prev.shape[0] - 1
    pad = np.concatenate([np.full(n, NEG), prev])
    win = np.lib.stride_tricks.sliding_window_view(pad, n + 1)
    return (win + g[::-1][None, :]).max(axis=1)


def _maxplus_vals_fast(prev: np.ndarray, g: np.ndarray) -> np.ndarray:
    """Bitwise-identical values to ``_maxplus_vals``, evaluated in row
    blocks that skip most of the -inf padding triangle (cell j only has
    j+1 real candidates; the rectangular kernel evaluates all n+1).
    Every real candidate is the same ``prev[j-k] + g[k]`` float and max
    is an exact, order-free reduction, so the output is unchanged.  This
    is the kernel of the cached/lazy engine path; the eager reference
    build keeps the plain kernels as the measured baseline."""
    n = prev.shape[0] - 1
    pad = np.concatenate([np.full(n, NEG), prev])
    win = np.lib.stride_tricks.sliding_window_view(pad, n + 1)
    gr = g[::-1]
    out = np.empty(n + 1)
    block = 128
    for j0 in range(0, n + 1, block):
        j1 = min(j0 + block, n + 1)
        t_lo = n - j1 + 1          # rows below j1 have no candidate before
        out[j0:j1] = (win[j0:j1, t_lo:] + gr[t_lo:]).max(axis=1)
    return out


def _maxplus_vals_fused(prev: np.ndarray, g: np.ndarray,
                        band: Optional[int] = None,
                        block: Optional[int] = None) -> np.ndarray:
    """Tiled fused add+max max-plus convolution.

    out[j] = max_{0 <= k <= min(j, band)} prev[j-k] + g[k]

    Candidate tiles of at most (block, band+1) cells are added and
    max-reduced immediately, so peak scratch is one tile — the (n x n)
    candidate matrix of the plain kernels is never materialized.  With
    ``band=None`` (dense) the candidate set per cell is exactly
    ``_maxplus_vals``'s, so the output is bitwise identical.  A finite
    band is sound — and still bitwise identical to dense — when ``prev``
    is monotone non-decreasing and ``g`` is flat past the band: every
    dropped candidate ``prev[j-k] + g[k]`` (k > band) is dominated by
    ``prev[j-band] + g[band]``, and first-max tie-breaking already picks
    the lowest k.

    Tile orientation adapts to the band: a narrow band (<= 1/4 of the
    width) lays k along the short outer axis and j along the long
    contiguous axis, so numpy's per-row loop overhead scales with the
    band instead of with n; wide/dense bands keep the j-blocked layout
    whose tiles bound peak scratch at one (block, band+1) slab.  Both
    orientations max-reduce the same candidate floats, so tiling never
    changes values."""
    n = prev.shape[0] - 1
    b = n if band is None else max(0, min(int(band), n))
    pad = np.concatenate([np.full(b, NEG), prev])
    if 4 * (b + 1) <= n + 1:           # narrow band: k-major tiles
        winT = np.lib.stride_tricks.sliding_window_view(pad, n + 1)
        gr = g[b::-1][:, None]         # gr[t] = g[b - t], i.e. k = b - t
        width = max(128, 131072 // (b + 1)) if block is None else block
        out = np.empty(n + 1)
        for j0 in range(0, n + 1, width):
            j1 = min(j0 + width, n + 1)
            out[j0:j1] = (winT[:, j0:j1] + gr).max(axis=0)
        return out
    if block is None:
        block = 128
    win = np.lib.stride_tricks.sliding_window_view(pad, b + 1)
    gr = g[b::-1]
    out = np.empty(n + 1)
    for j0 in range(0, n + 1, block):
        j1 = min(j0 + block, n + 1)
        t_lo = max(b - j1 + 1, 0)      # rows below j1 have no candidate before
        out[j0:j1] = (win[j0:j1, t_lo:] + gr[t_lo:]).max(axis=1)
    return out


# ---------------------------------------------------------------------------
# Max-plus backend switch: numpy (float64, default) or the Pallas kernel
# (kernels.maxplus.maxplus_conv, float32; interpret off-TPU).
# ---------------------------------------------------------------------------

_BACKEND_ENV = "REPRO_PLANNER_BACKEND"
_BACKENDS = ("numpy", "pallas")
_backend_override: Optional[str] = None


def set_maxplus_backend(name: Optional[str]) -> None:
    """Select the max-plus convolution backend for the incremental engines:
    ``"numpy"`` / ``"pallas"``, or ``None`` to defer to the
    ``REPRO_PLANNER_BACKEND`` env var (default numpy)."""
    global _backend_override
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"unknown max-plus backend {name!r}; "
                         f"choose from {_BACKENDS}")
    _backend_override = name


def get_maxplus_backend() -> str:
    if _backend_override is not None:
        return _backend_override
    env = os.environ.get(_BACKEND_ENV, "").strip().lower()
    if env and env not in _BACKENDS:
        raise ValueError(f"{_BACKEND_ENV}={env!r} is not recognized; "
                         f"choose from {_BACKENDS}")
    return env or "numpy"


def _conv_vals(prev: np.ndarray, g: np.ndarray,
               band: Optional[int] = None) -> np.ndarray:
    """Backend-dispatched banded max-plus value kernel (segment-tree
    engine's convolution).  Traceback-time argmax recovery stays on
    numpy either way — only the value vectors go through the kernel."""
    if get_maxplus_backend() == "pallas":
        from repro.kernels.maxplus import maxplus_conv
        return np.asarray(maxplus_conv(prev, g, band=band), dtype=float)
    return _maxplus_vals_fused(prev, g, band)


def _argmax_at(prev: np.ndarray, g: np.ndarray, j: int) -> int:
    """Choice k at cell j of ``_maxplus(prev, g)``: first/lowest k on ties
    (all candidates with k > j are -inf, so restricting to k <= j is
    exactly the stored-argmax matrix's answer)."""
    return int(np.argmax(prev[j::-1] + g[:j + 1]))


def _cluster_waf(tasks: Sequence[Task], assign: Sequence[int],
                 hw: Hardware) -> float:
    return sum(waf_mod.waf(t, x, hw) for t, x in zip(tasks, assign))


def solve(inp: PlanInput, hw: Hardware) -> Plan:
    """Vectorized dynamic program (Eq. 5) with traceback."""
    m, n = len(inp.tasks), inp.n_workers
    if m == 0:
        return Plan((), 0.0, 0.0)
    rows = _reward_matrix(inp, hw)
    S = np.zeros(n + 1)
    choice = np.zeros((m, n + 1), dtype=np.int64)
    for i in range(m):
        S, choice[i] = _maxplus(S, rows[i])
    assign = [0] * m
    j = int(np.argmax(S))
    total = float(S[j])
    for i in range(m - 1, -1, -1):
        k = int(choice[i, j])
        assign[i] = k
        j -= k
    return Plan(tuple(assign), total, _cluster_waf(inp.tasks, assign, hw))


def solve_fast(inp: PlanInput, hw: Hardware) -> Plan:
    """Same Plan as ``solve`` (same candidate floats, same first-max
    tie-breaking) using the value-only row-blocked kernel and
    traceback-time argmax recovery instead of per-cell argmax matrices —
    the fresh-dispatch path of the cached engine."""
    m, n = len(inp.tasks), inp.n_workers
    if m == 0:
        return Plan((), 0.0, 0.0)
    rows = _reward_matrix(inp, hw)
    S = [np.zeros(n + 1)]
    for i in range(m):
        S.append(_maxplus_vals_fast(S[i], rows[i]))
    assign = [0] * m
    j = int(np.argmax(S[m]))
    total = float(S[m][j])
    for i in range(m - 1, -1, -1):
        k = _argmax_at(S[i], rows[i], j)
        assign[i] = k
        j -= k
    return Plan(tuple(assign), total, _cluster_waf(inp.tasks, assign, hw))


def solve_reference(inp: PlanInput, hw: Hardware) -> Plan:
    """Scalar reference DP (the original implementation): property-test
    ground truth and the speedup baseline for the benchmarks."""
    m, n = len(inp.tasks), inp.n_workers
    rows = [_reward_row(inp, i, hw) for i in range(m)]
    # S[i][j]: best reward of first i tasks using j workers
    S = [[0.0] + [0.0] * n]
    choice: List[List[int]] = []
    for i in range(1, m + 1):
        row = [NEG] * (n + 1)
        ch = [0] * (n + 1)
        g = rows[i - 1]
        for j in range(n + 1):
            best, bk = NEG, 0
            for k in range(j + 1):
                v = S[i - 1][j - k] + g[k]
                if v > best:
                    best, bk = v, k
            row[j], ch[j] = best, bk
        S.append(row)
        choice.append(ch)
    # traceback from S(m, n)
    assign = [0] * m
    j = max(range(n + 1), key=lambda jj: S[m][jj])
    total = S[m][j]
    for i in range(m, 0, -1):
        k = choice[i - 1][j]
        assign[i - 1] = k
        j -= k
    return Plan(tuple(assign), total, _cluster_waf(inp.tasks, assign, hw))


def brute_force(inp: PlanInput, hw: Hardware) -> Plan:
    """Exponential reference solver (tests only)."""
    m, n = len(inp.tasks), inp.n_workers
    rows = [_reward_row(inp, i, hw) for i in range(m)]
    best: Optional[Tuple[float, Tuple[int, ...]]] = None
    for assign in itertools.product(range(n + 1), repeat=m):
        if sum(assign) > n:
            continue
        v = sum(rows[i][assign[i]] for i in range(m))
        if best is None or v > best[0]:
            best = (v, assign)
    v, assign = best
    return Plan(tuple(assign), v, _cluster_waf(inp.tasks, assign, hw))


class PlanTable:
    """Precomputed lookup table (§5.2 'Complexity'): one-step lookahead
    plans for every single-event scenario from the current configuration —
    any task losing one worker, a worker joining, a task finishing —
    giving O(1) dispatch when the event actually happens.

    Incremental build: base reward rows G(t_i, ·) at the largest scenario
    budget are computed once from the memoized cost-model curves, prefix
    DPs P[i] (tasks 0..i-1) and suffix DPs T[i] (tasks i..m-1) are each one
    max-plus pass, and every scenario is then assembled from them:

      fault:i   combine(P[i], fault-row_i, T[i+1])   (2 convolutions)
      join:1    combine(P[m//2], T[m//2])             (1 convolution)
      finish:i  combine(P[i], T[i+1])                 (1 convolution)

    ``lazy=True`` defers scenario assembly (and the P/T chains feeding it)
    to the first ``lookup`` of each key: a table consulted for one scenario
    before the cluster state changes again only pays for that scenario.
    A ``PlannerCache`` shares rows and P/T chains *across* rebuilds.

    ``incremental=False`` retains the original scenario-by-scenario full
    solves (the reference path the tests and benchmarks compare against).
    """

    def __init__(self, tasks: Sequence[Task], assignment: Sequence[int],
                 hw: Hardware, d_running: float, d_transition: float,
                 workers_per_fault: int = 8, incremental: bool = True,
                 solver=None, lazy: bool = False,
                 cache: Optional["PlannerCache"] = None,
                 n_budget: Optional[int] = None,
                 engine: str = "segtree"):
        """``incremental=False`` falls back to one full solve per scenario;
        ``solver`` then picks the per-scenario solver (default ``solve``;
        pass ``solve_reference`` for the all-scalar baseline).

        ``engine``: ``"segtree"`` (dyadic segment tree over task
        positions, O(log m) invalidation per churn step, banded
        convolutions where caps allow) or ``"chain"`` (the PR-2
        prefix/suffix DP chains, kept as the churn-rebuild baseline).

        ``n_budget``: size the DP value arrays for this many workers (>=
        the largest scenario budget).  Plans are unchanged — every
        scenario argmax is sliced to its own budget — but a *fixed*
        budget (e.g. cluster capacity + one node) keeps chain-cache keys
        and array shapes identical across rebuilds at different totals."""
        if engine not in ("segtree", "chain"):
            raise ValueError(f"unknown PlanTable engine {engine!r}")
        self.tasks = tuple(tasks)
        self.assignment = tuple(assignment)
        self.hw = hw
        self.d_running = d_running
        self.d_transition = d_transition
        self.workers_per_fault = workers_per_fault  # a node drain = 8 GPUs
        self.n_budget = n_budget
        self.engine = engine
        self._solver = solver or solve
        self._cache = cache
        self.table: Dict[str, Plan] = {}
        self._incremental = (incremental and solver is None
                             and len(self.tasks) > 0
                             and _vector_capable(self.tasks))
        if self._incremental:
            self._init_incremental()
            if not lazy:
                for key in self.scenario_keys():
                    self.lookup(key)
        else:
            self._precompute_reference()

    def scenario_keys(self) -> List[str]:
        m = len(self.tasks)
        return ([f"fault:{i}" for i in range(m)] + ["join:1"]
                + [f"finish:{i}" for i in range(m)])

    def _scenario_input(self, n_workers: int,
                        faulted_task: Optional[int]) -> PlanInput:
        faulted = tuple(i == faulted_task for i in range(len(self.tasks)))
        return PlanInput(self.tasks, self.assignment, n_workers,
                         self.d_running, self.d_transition, faulted)

    # ---- reference build: one full solve per scenario ---------------------

    def _precompute_reference(self) -> None:
        n_now = sum(self.assignment)
        w = self.workers_per_fault
        for ti in range(len(self.tasks)):
            key = f"fault:{ti}"
            self.table[key] = self._solver(
                self._scenario_input(max(n_now - w, 0), ti), self.hw)
        self.table["join:1"] = self._solver(
            self._scenario_input(n_now + w, None), self.hw)
        for ti in range(len(self.tasks)):
            # task ti finished: its workers return to the pool
            rem_tasks = self.tasks[:ti] + self.tasks[ti + 1:]
            rem_assign = self.assignment[:ti] + self.assignment[ti + 1:]
            inp = PlanInput(rem_tasks, rem_assign, n_now,
                            self.d_running, self.d_transition,
                            (False,) * len(rem_tasks))
            self.table[f"finish:{ti}"] = self._solver(inp, self.hw)

    # ---- incremental build: shared rows + prefix/suffix DP chains ---------

    def _init_incremental(self) -> None:
        m = len(self.tasks)
        n_now = sum(self.assignment)
        w = self.workers_per_fault
        self._n_now = n_now
        self._n_join = n_now + w                # join is the largest budget
        self._n_max = max(self._n_join, self.n_budget or 0)
        self._n_fault = max(n_now - w, 0)
        self._rows: List[Optional[np.ndarray]] = [None] * m
        self._frows: Dict[int, np.ndarray] = {}
        self._P: List[Optional[np.ndarray]] = [None] * (m + 1)
        self._T: List[Optional[np.ndarray]] = [None] * (m + 1)
        self._P[0] = np.zeros(self._n_max + 1)
        self._T[m] = np.zeros(self._n_max + 1)
        # The chain engine keeps the PR-1/PR-2 kernels on purpose: that
        # path IS the preserved churn-rebuild baseline whose wall-clock
        # the bench speedup floors are measured against.  The segment
        # tree runs on the fused banded kernel (backend-dispatched);
        # outputs of all kernels are bitwise identical on the same
        # candidate sets.
        self._conv = _maxplus_vals_fast if self._cache else _maxplus_vals
        self._V: Dict[Tuple[int, int], np.ndarray] = {}
        cache = self._cache
        if cache is not None:
            self._pairs = tuple((cache.task_id(t), x)
                                for t, x in zip(self.tasks,
                                                self.assignment))
            self._sig = (self.hw, self._n_max, self.d_running,
                         self.d_transition)

    def _pkey(self, i: int):
        return ("P", self._sig, self._pairs[:i])

    def _skey(self, i: int):
        return ("T", self._sig, self._pairs[i:])

    def _rkey(self, i: int, faulted: bool):
        return ("G", self._sig, self._pairs[i], faulted)

    def _row(self, i: int, faulted: bool = False) -> np.ndarray:
        store = self._frows if faulted else self._rows
        row = store.get(i) if faulted else store[i]
        if row is not None:
            return row

        def build() -> np.ndarray:
            return waf_mod.reward_curve(
                self.tasks[i], self.assignment[i], self._n_max,
                d_running=self.d_running, d_transition=self.d_transition,
                worker_faulted=faulted, hw=self.hw)

        if self._cache is not None:
            row = self._cache.array(self._rkey(i, faulted), build)
        else:
            row = build()
        store[i] = row
        return row

    def _prefix(self, i: int) -> np.ndarray:
        """P[i]: DP value vector over tasks 0..i-1 (cache-chained)."""
        start = i
        while self._P[start] is None:
            if self._cache is not None:
                hit = self._cache.array(self._pkey(start))
                if hit is not None:
                    self._P[start] = hit
                    break
            start -= 1
        for t in range(start + 1, i + 1):
            if self._P[t] is None:
                arr = self._conv(self._P[t - 1], self._row(t - 1))
                if self._cache is not None:
                    self._cache.array(self._pkey(t), lambda: arr)
                self._P[t] = arr
        return self._P[i]

    def _suffix(self, i: int) -> np.ndarray:
        """T[i]: DP value vector over tasks i..m-1 (cache-chained)."""
        start = i
        while self._T[start] is None:
            if self._cache is not None:
                hit = self._cache.array(self._skey(start))
                if hit is not None:
                    self._T[start] = hit
                    break
            start += 1
        for t in range(start - 1, i - 1, -1):
            if self._T[t] is None:
                arr = self._conv(self._T[t + 1], self._row(t))
                if self._cache is not None:
                    self._cache.array(self._skey(t), lambda: arr)
                self._T[t] = arr
        return self._T[i]

    def _cwaf(self, tasks: Sequence[Task], assign: Sequence[int]) -> float:
        """Cluster WAF of an assembled plan.  With a cache, reads F(t, ·)
        vectors (same floats as the scalar ``waf`` — the sweep mirrors the
        scalar arithmetic) instead of per-(task, x) model evaluations."""
        if self._cache is None:
            return _cluster_waf(tasks, assign, self.hw)
        total = 0.0
        for t, x in zip(tasks, assign):
            F = self._cache.array(
                ("F", self.hw, self._cache.task_id(t)),
                lambda t=t: waf_mod.waf_curve(t, self._n_max, self.hw))
            x = int(x)
            if x < F.shape[0]:
                total += float(F[x])
            else:
                total += waf_mod.waf(t, x, self.hw)
        return total

    def _walk_prefix(self, last: int, budget: int,
                     assign: List[int]) -> None:
        for t in range(last, -1, -1):
            k = _argmax_at(self._prefix(t), self._row(t), budget)
            assign[t] = k
            budget -= k

    def _walk_suffix(self, first: int, budget: int, assign: List[int],
                     offset: int = 0) -> None:
        for t in range(first, len(self.tasks)):
            k = _argmax_at(self._suffix(t + 1), self._row(t), budget)
            assign[t - offset] = k
            budget -= k

    def _assemble_chain(self, key: str) -> Optional[Plan]:
        """Build one scenario plan from the shared rows and P/T chains
        (same combine order and tie-breaking as the eager build)."""
        m = len(self.tasks)
        if key == "join:1":
            # combine at the mid split so both chain halves stay reusable
            # across rebuilds (a change at position i only invalidates the
            # half containing i)
            s = m // 2
            combined = self._conv(self._prefix(s), self._suffix(s))
            j = int(np.argmax(combined[:self._n_join + 1]))
            assign = [0] * m
            b = _argmax_at(self._prefix(s), self._suffix(s), j)
            self._walk_prefix(s - 1, j - b, assign)
            self._walk_suffix(s, b, assign)
            return Plan(tuple(assign), float(combined[j]),
                        self._cwaf(self.tasks, assign))
        kind, _, idx = key.partition(":")
        if not idx.isdigit():
            return None
        ti = int(idx)
        if not 0 <= ti < m:
            return None
        if kind == "fault":
            frow = self._row(ti, faulted=True)
            mid = None
            if self._cache is not None:    # P[ti] (+) fault-row, by prefix
                mid = self._cache.array(("M", self._sig,
                                         self._pairs[:ti + 1]))
            if mid is None:
                mid = self._conv(self._prefix(ti), frow)
                if self._cache is not None:
                    self._cache.array(("M", self._sig,
                                       self._pairs[:ti + 1]), lambda: mid)
            combined = self._conv(mid, self._suffix(ti + 1))
            j = int(np.argmax(combined[:self._n_fault + 1]))
            total = float(combined[j])
            assign = [0] * m
            b = _argmax_at(mid, self._suffix(ti + 1), j)   # suffix budget
            k = _argmax_at(self._prefix(ti), frow, j - b)  # faulted task
            assign[ti] = k
            self._walk_prefix(ti - 1, j - b - k, assign)
            self._walk_suffix(ti + 1, b, assign)
            return Plan(tuple(assign), total,
                        self._cwaf(self.tasks, assign))
        if kind == "finish":
            combined = self._conv(self._prefix(ti), self._suffix(ti + 1))
            j = int(np.argmax(combined[:self._n_now + 1]))
            total = float(combined[j])
            assign = [0] * (m - 1)
            b = _argmax_at(self._prefix(ti), self._suffix(ti + 1), j)
            self._walk_prefix(ti - 1, j - b, assign)
            self._walk_suffix(ti + 1, b, assign, offset=1)
            rem = self.tasks[:ti] + self.tasks[ti + 1:]
            return Plan(tuple(assign), total, self._cwaf(rem, assign))
        return None

    # ---- segment-tree engine: dyadic span merges + complement chains ------

    def _band(self, i: int, faulted: bool = False) -> Optional[int]:
        """Band of task i's reward row: the row is flat past it (worker
        cap; plus the unfaulted row's no-transition spike at x_old), so
        banded convolutions with it are exact.  None = uncapped/dense."""
        cap = self.tasks[i].max_workers
        if cap is None:
            return None
        b = min(max(cap, 0), self._n_max)
        if not faulted:                    # g[x_old] spike breaks flatness
            b = min(max(b, self.assignment[i]), self._n_max)
        return b

    def _sat(self, lo: int, hi: int) -> int:
        """Saturation of span [lo, hi): V[lo, hi) is flat past the sum of
        its tasks' bands (more workers than every cap combined are idle)."""
        s = 0
        for i in range(lo, hi):
            b = self._band(i)
            s += self._n_max if b is None else b
            if s >= self._n_max:
                return self._n_max
        return s

    def _vkey(self, lo: int, hi: int):
        return ("V", self._sig, self._pairs[lo:hi])

    def _vvec(self, lo: int, hi: int) -> np.ndarray:
        """V[lo, hi): max-plus merge of the span's reward rows (best span
        reward using at most j workers), built by dyadic midpoint split
        and cached by span *contents* — a churn step at task u only
        invalidates the O(log m) spans containing u."""
        got = self._V.get((lo, hi))
        if got is not None:
            return got
        arr = None
        if self._cache is not None:
            arr = self._cache.array(self._vkey(lo, hi))
        if arr is None:
            if hi - lo == 1:
                arr = np.maximum.accumulate(self._row(lo))
            else:
                mid = (lo + hi) // 2
                left, right = self._vvec(lo, mid), self._vvec(mid, hi)
                sl, sr = self._sat(lo, mid), self._sat(mid, hi)
                if sl < sr:               # band by the flatter operand
                    arr = _conv_vals(right, left,
                                     sl if sl < self._n_max else None)
                else:
                    arr = _conv_vals(left, right,
                                     sr if sr < self._n_max else None)
            if self._cache is not None:
                self._cache.array(self._vkey(lo, hi), lambda: arr)
        self._V[(lo, hi)] = arr
        return arr

    def _path_sibs(self, ti: int) -> List[Tuple[int, int]]:
        """Siblings along the root -> leaf(ti) path, top-down: their
        union is every task except ti."""
        sibs: List[Tuple[int, int]] = []
        lo, hi = 0, len(self.tasks)
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if ti < mid:
                sibs.append((mid, hi))
                hi = mid
            else:
                sibs.append((lo, mid))
                lo = mid
        return sibs

    def _ckey(self, sibs: Sequence[Tuple[int, int]]):
        return ("C", self._sig, tuple(self._pairs[a:b] for a, b in sibs))

    def _compl_chain(self, ti: int):
        """Complement chain of leaf ti: Cs[i] merges the first i root-path
        siblings, so Cs[-1] is the DP value vector over every task except
        ti (the ``finish:ti`` vector, and the ``fault:ti`` base)."""
        sibs = self._path_sibs(ti)
        Cs = [np.zeros(self._n_max + 1)]
        satc = 0
        for i, (a, b) in enumerate(sibs):
            C = None
            if self._cache is not None:
                C = self._cache.array(self._ckey(sibs[: i + 1]))
            if C is None:
                sat_v = self._sat(a, b)
                if satc < sat_v:          # band by the flatter operand
                    C = _conv_vals(self._vvec(a, b), Cs[i],
                                   satc if satc < self._n_max else None)
                else:
                    C = _conv_vals(Cs[i], self._vvec(a, b),
                                   sat_v if sat_v < self._n_max else None)
                if self._cache is not None:
                    self._cache.array(self._ckey(sibs[: i + 1]), lambda: C)
            satc = min(satc + self._sat(a, b), self._n_max)
            Cs.append(C)
        return sibs, Cs

    def _walk_span(self, lo: int, hi: int, budget: int,
                   assign: List[int]) -> None:
        """Traceback inside span [lo, hi): recover the per-task workers
        achieving V[lo, hi)[budget] by descending the tree (first-max
        splits, like the chain walks)."""
        if hi - lo == 1:
            assign[lo] = int(np.argmax(self._row(lo)[:budget + 1]))
            return
        mid = (lo + hi) // 2
        b = _argmax_at(self._vvec(lo, mid), self._vvec(mid, hi), budget)
        self._walk_span(mid, hi, b, assign)
        self._walk_span(lo, mid, budget - b, assign)

    def _walk_compl(self, sibs, Cs, budget: int,
                    assign: List[int]) -> None:
        for i in range(len(sibs) - 1, -1, -1):
            a, b_hi = sibs[i]
            b = _argmax_at(Cs[i], self._vvec(a, b_hi), budget)
            self._walk_span(a, b_hi, b, assign)
            budget -= b

    def _assemble_segtree(self, key: str) -> Optional[Plan]:
        """Build one scenario plan from O(log m) cached node merges."""
        m = len(self.tasks)
        if key == "join:1":
            root = self._vvec(0, m)
            j = int(np.argmax(root[:self._n_join + 1]))
            assign = [0] * m
            self._walk_span(0, m, j, assign)
            return Plan(tuple(assign), float(root[j]),
                        self._cwaf(self.tasks, assign))
        kind, _, idx = key.partition(":")
        if not idx.isdigit():
            return None
        ti = int(idx)
        if not 0 <= ti < m:
            return None
        if kind not in ("fault", "finish"):
            return None
        sibs, Cs = self._compl_chain(ti)
        C = Cs[-1]
        if kind == "fault":
            frow = self._row(ti, faulted=True)
            combined = None
            fkey = None
            if self._cache is not None:
                fkey = ("FM", self._sig,
                        (self._pairs[:ti], self._pairs[ti + 1:]),
                        self._pairs[ti])
                combined = self._cache.array(fkey)
            if combined is None:
                combined = _conv_vals(C, frow, self._band(ti, faulted=True))
                if self._cache is not None:
                    self._cache.array(fkey, lambda: combined)
            j = int(np.argmax(combined[:self._n_fault + 1]))
            total = float(combined[j])
            assign = [0] * m
            k = _argmax_at(C, frow, j)
            assign[ti] = k
            self._walk_compl(sibs, Cs, j - k, assign)
            return Plan(tuple(assign), total,
                        self._cwaf(self.tasks, assign))
        j = int(np.argmax(C[:self._n_now + 1]))
        total = float(C[j])
        assign = [0] * m
        self._walk_compl(sibs, Cs, j, assign)
        del assign[ti]
        rem = self.tasks[:ti] + self.tasks[ti + 1:]
        return Plan(tuple(assign), total, self._cwaf(rem, assign))

    def _assemble(self, key: str) -> Optional[Plan]:
        if self.engine == "segtree":
            return self._assemble_segtree(key)
        return self._assemble_chain(key)

    def lookup(self, key: str) -> Optional[Plan]:
        plan = self.table.get(key)
        if plan is None and self._incremental and key not in self.table:
            plan = self._assemble(key)
            if plan is not None:
                self.table[key] = plan
        return plan


class PlannerCache:
    """Cross-rebuild planner cache (the ROADMAP follow-up to the PR-1
    incremental engine): reward rows, prefix/suffix DP value chains, whole
    lazy ``PlanTable``s, and fresh ``solve`` plans, shared across every
    rebuild a churn-heavy simulation issues.

    * A rebuild where only one task's assignment changed finds every P
      chain up to the change and every T chain past it already cached, and
      recomputes only the remainder.
    * A *recurring* cluster state (same task set + assignment + durations)
      is a whole-table hit — its scenarios are never reassembled.
    * Fresh solves (table misses, task launches) are memoized by their
      full ``PlanInput``.

    All stores are bounded LRUs; ``stats()`` exposes hit/miss counters for
    the benchmarks.  Plans served from the cache are float-identical to an
    uncached build: keys include every input the arrays depend on.
    """

    def __init__(self, max_arrays: int = 32768, max_tables: int = 4096,
                 max_plans: int = 32768):
        self._arrays: OrderedDict = OrderedDict()
        self._tables: OrderedDict = OrderedDict()
        self._plans: OrderedDict = OrderedDict()
        self._caps = {"arrays": max_arrays, "tables": max_tables,
                      "plans": max_plans}
        self._task_ids: Dict[object, int] = {}
        self._lock = threading.RLock()
        self.hits = {"arrays": 0, "tables": 0, "plans": 0}
        self.misses = {"arrays": 0, "tables": 0, "plans": 0}

    def task_id(self, task) -> int:
        """Intern a task: chain keys hash small ints, not task objects."""
        with self._lock:
            tid = self._task_ids.get(task)
            if tid is None:
                tid = len(self._task_ids)
                self._task_ids[task] = tid
            return tid

    def _memo(self, store: OrderedDict, name: str, key, build):
        """Thread-compatible get-or-build.  The build runs outside the
        lock: concurrent Monte-Carlo seeds may duplicate a computation,
        but every entry is fully determined by its key, so whichever
        lands is identical — results never depend on scheduling."""
        with self._lock:
            got = store.get(key)
            if got is not None:
                store.move_to_end(key)
                self.hits[name] += 1
                return got
        if build is None:
            return None
        got = build()
        with self._lock:
            if key not in store:
                self.misses[name] += 1
                store[key] = got
                if len(store) > self._caps[name]:
                    store.popitem(last=False)
            else:
                got = store[key]
        return got

    def array(self, key, build=None) -> Optional[np.ndarray]:
        return self._memo(self._arrays, "arrays", key, build)

    def table(self, tasks: Sequence[Task], assignment: Sequence[int],
              hw: Hardware, d_running: float, d_transition: float,
              workers_per_fault: int = 8,
              n_budget: Optional[int] = None,
              engine: str = "segtree",
              task_ids: Optional[Tuple[int, ...]] = None) -> PlanTable:
        """A lazy PlanTable for this cluster state, memoized by state.
        ``task_ids``: the already-interned ``task_id`` tuple for ``tasks``
        (callers that refresh per event keep it across rebuilds — the
        task set only changes on churn)."""
        tasks, assignment = tuple(tasks), tuple(assignment)
        if task_ids is None:
            task_ids = tuple(self.task_id(t) for t in tasks)
        key = (task_ids, assignment, hw,
               d_running, d_transition, workers_per_fault, n_budget,
               engine)
        return self._memo(
            self._tables, "tables", key,
            lambda: PlanTable(tasks, assignment, hw, d_running,
                              d_transition, workers_per_fault,
                              lazy=True, cache=self, n_budget=n_budget,
                              engine=engine))

    def solve(self, inp: PlanInput, hw: Hardware) -> Plan:
        """Memoized fresh dispatch (``solve_fast`` — same plans as
        ``solve``, value-chain kernel)."""
        key = (tuple(self.task_id(t) for t in inp.tasks), inp.assignment,
               inp.n_workers, inp.d_running, inp.d_transition,
               inp.faulted, hw)
        return self._memo(self._plans, "plans", key,
                          lambda: solve_fast(inp, hw))

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {"hits": dict(self.hits), "misses": dict(self.misses),
                "sizes": {"arrays": len(self._arrays),
                          "tables": len(self._tables),
                          "plans": len(self._plans)}}
