"""Optimal reconfiguration plan generation (§5.2).

Knapsack-style dynamic program over (tasks x workers):

    S(i, j) = max_k { S(i-1, j-k) + G(t_i, k) }           (Eq. 5)

Two solver paths share the recurrence:

* ``solve`` — the vectorized engine: reward rows come out of the memoized
  cost-model sweep as whole vectors (``waf.reward_curve``), and the DP inner
  loop is a max-plus convolution evaluated as one NumPy windowed matrix per
  task (O(n^2) cells but a single vector op), with argmax traceback.
* ``solve_reference`` — the original pure-Python scalar DP, kept as the
  ground truth for property tests and the speedup baseline.

``PlanTable`` precomputes the one-step lookahead lookup table the paper uses
for O(1) dispatch at failure time.  The incremental build shares the m base
reward rows across ALL fault/join/finish scenarios: prefix and suffix DPs
over the base rows are computed once, and each scenario is then one or two
max-plus combines instead of a full m-row solve — O(m) convolutions for the
whole table instead of O(m^2).

``brute_force`` is an exponential reference used by the property tests.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import waf as waf_mod
from repro.core.costmodel import Hardware, TaskModel
from repro.core.waf import Task

NEG = float("-inf")


@dataclass(frozen=True)
class PlanInput:
    tasks: Tuple[Task, ...]
    assignment: Tuple[int, ...]        # current workers per task (x_i)
    n_workers: int                     # n' available after the event
    d_running: float
    d_transition: float
    faulted: Tuple[bool, ...]          # per task: did one of its workers fault


@dataclass(frozen=True)
class Plan:
    assignment: Tuple[int, ...]
    total_reward: float
    waf: float                         # cluster WAF under the new assignment


def _vector_capable(tasks: Sequence) -> bool:
    """Reward rows can be built from the cost-model sweep (real ``Task``s
    with analytic ``TaskModel``s).  Duck-typed tasks — e.g. the tabulated
    tasks the property tests use with a monkeypatched ``waf`` — fall back
    to the scalar row builder so they keep their custom semantics."""
    return all(isinstance(t, Task) and isinstance(t.model, TaskModel)
               for t in tasks)


def _reward_row(inp: PlanInput, i: int, hw: Hardware) -> List[float]:
    """G(t_i, k) for k = 0..n_workers (scalar reference path)."""
    t = inp.tasks[i]
    return [waf_mod.reward(t, inp.assignment[i], k,
                           d_running=inp.d_running,
                           d_transition=inp.d_transition,
                           worker_faulted=inp.faulted[i], hw=hw)
            for k in range(inp.n_workers + 1)]


def _reward_matrix(inp: PlanInput, hw: Hardware) -> np.ndarray:
    """All m reward rows as an (m, n+1) matrix."""
    if _vector_capable(inp.tasks):
        return np.stack([
            waf_mod.reward_curve(t, inp.assignment[i], inp.n_workers,
                                 d_running=inp.d_running,
                                 d_transition=inp.d_transition,
                                 worker_faulted=inp.faulted[i], hw=hw)
            for i, t in enumerate(inp.tasks)])
    return np.array([_reward_row(inp, i, hw)
                     for i in range(len(inp.tasks))], dtype=float)


def _maxplus(prev: np.ndarray, g: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """One max-plus convolution step: out[j] = max_{0<=k<=j} prev[j-k] + g[k],
    plus the argmax k per j (first/lowest k on ties, matching the scalar
    DP's strict-improvement rule)."""
    n = prev.shape[0] - 1
    pad = np.concatenate([np.full(n, NEG), prev])
    win = np.lib.stride_tricks.sliding_window_view(pad, n + 1)
    vals = win[:, ::-1] + g[None, :]   # vals[j, k] = prev[j-k] + g[k]
    ch = vals.argmax(axis=1)           # one O(n^2) scan serves both outputs
    return vals[np.arange(n + 1), ch], ch


def _cluster_waf(tasks: Sequence[Task], assign: Sequence[int],
                 hw: Hardware) -> float:
    return sum(waf_mod.waf(t, x, hw) for t, x in zip(tasks, assign))


def solve(inp: PlanInput, hw: Hardware) -> Plan:
    """Vectorized dynamic program (Eq. 5) with traceback."""
    m, n = len(inp.tasks), inp.n_workers
    if m == 0:
        return Plan((), 0.0, 0.0)
    rows = _reward_matrix(inp, hw)
    S = np.zeros(n + 1)
    choice = np.zeros((m, n + 1), dtype=np.int64)
    for i in range(m):
        S, choice[i] = _maxplus(S, rows[i])
    assign = [0] * m
    j = int(np.argmax(S))
    total = float(S[j])
    for i in range(m - 1, -1, -1):
        k = int(choice[i, j])
        assign[i] = k
        j -= k
    return Plan(tuple(assign), total, _cluster_waf(inp.tasks, assign, hw))


def solve_reference(inp: PlanInput, hw: Hardware) -> Plan:
    """Scalar reference DP (the original implementation): property-test
    ground truth and the speedup baseline for the benchmarks."""
    m, n = len(inp.tasks), inp.n_workers
    rows = [_reward_row(inp, i, hw) for i in range(m)]
    # S[i][j]: best reward of first i tasks using j workers
    S = [[0.0] + [0.0] * n]
    choice: List[List[int]] = []
    for i in range(1, m + 1):
        row = [NEG] * (n + 1)
        ch = [0] * (n + 1)
        g = rows[i - 1]
        for j in range(n + 1):
            best, bk = NEG, 0
            for k in range(j + 1):
                v = S[i - 1][j - k] + g[k]
                if v > best:
                    best, bk = v, k
            row[j], ch[j] = best, bk
        S.append(row)
        choice.append(ch)
    # traceback from S(m, n)
    assign = [0] * m
    j = max(range(n + 1), key=lambda jj: S[m][jj])
    total = S[m][j]
    for i in range(m, 0, -1):
        k = choice[i - 1][j]
        assign[i - 1] = k
        j -= k
    return Plan(tuple(assign), total, _cluster_waf(inp.tasks, assign, hw))


def brute_force(inp: PlanInput, hw: Hardware) -> Plan:
    """Exponential reference solver (tests only)."""
    m, n = len(inp.tasks), inp.n_workers
    rows = [_reward_row(inp, i, hw) for i in range(m)]
    best: Optional[Tuple[float, Tuple[int, ...]]] = None
    for assign in itertools.product(range(n + 1), repeat=m):
        if sum(assign) > n:
            continue
        v = sum(rows[i][assign[i]] for i in range(m))
        if best is None or v > best[0]:
            best = (v, assign)
    v, assign = best
    return Plan(tuple(assign), v, _cluster_waf(inp.tasks, assign, hw))


class PlanTable:
    """Precomputed lookup table (§5.2 'Complexity'): one-step lookahead
    plans for every single-event scenario from the current configuration —
    any task losing one worker, a worker joining, a task finishing —
    giving O(1) dispatch when the event actually happens.

    Incremental build: base reward rows G(t_i, ·) at the largest scenario
    budget are computed once from the memoized cost-model curves, prefix
    DPs P[i] (tasks 0..i-1) and suffix DPs T[i] (tasks i..m-1) are each one
    max-plus pass, and every scenario is then assembled from them:

      fault:i   combine(P[i], fault-row_i, T[i+1])   (2 convolutions)
      join:1    traceback of P[m]                     (0 convolutions)
      finish:i  combine(P[i], T[i+1])                 (1 convolution)

    ``incremental=False`` retains the original scenario-by-scenario full
    solves (the reference path the tests and benchmarks compare against).
    """

    def __init__(self, tasks: Sequence[Task], assignment: Sequence[int],
                 hw: Hardware, d_running: float, d_transition: float,
                 workers_per_fault: int = 8, incremental: bool = True,
                 solver=None):
        """``incremental=False`` falls back to one full solve per scenario;
        ``solver`` then picks the per-scenario solver (default ``solve``;
        pass ``solve_reference`` for the all-scalar baseline)."""
        self.tasks = tuple(tasks)
        self.assignment = tuple(assignment)
        self.hw = hw
        self.d_running = d_running
        self.d_transition = d_transition
        self.workers_per_fault = workers_per_fault  # a node drain = 8 GPUs
        self._solver = solver or solve
        self.table: Dict[str, Plan] = {}
        if incremental and solver is None and _vector_capable(self.tasks):
            self._precompute_incremental()
        else:
            self._precompute_reference()

    def _scenario_input(self, n_workers: int,
                        faulted_task: Optional[int]) -> PlanInput:
        faulted = tuple(i == faulted_task for i in range(len(self.tasks)))
        return PlanInput(self.tasks, self.assignment, n_workers,
                         self.d_running, self.d_transition, faulted)

    # ---- reference build: one full solve per scenario ---------------------

    def _precompute_reference(self) -> None:
        n_now = sum(self.assignment)
        w = self.workers_per_fault
        for ti in range(len(self.tasks)):
            key = f"fault:{ti}"
            self.table[key] = self._solver(
                self._scenario_input(max(n_now - w, 0), ti), self.hw)
        self.table["join:1"] = self._solver(
            self._scenario_input(n_now + w, None), self.hw)
        for ti in range(len(self.tasks)):
            # task ti finished: its workers return to the pool
            rem_tasks = self.tasks[:ti] + self.tasks[ti + 1:]
            rem_assign = self.assignment[:ti] + self.assignment[ti + 1:]
            inp = PlanInput(rem_tasks, rem_assign, n_now,
                            self.d_running, self.d_transition,
                            (False,) * len(rem_tasks))
            self.table[f"finish:{ti}"] = self._solver(inp, self.hw)

    # ---- incremental build: shared rows + prefix/suffix DPs ---------------

    def _precompute_incremental(self) -> None:
        m = len(self.tasks)
        if m == 0:                      # empty task set: only join exists
            self._precompute_reference()
            return
        n_now = sum(self.assignment)
        w = self.workers_per_fault
        n_max = n_now + w                       # join is the largest budget
        n_fault = max(n_now - w, 0)
        base = np.stack([
            waf_mod.reward_curve(t, self.assignment[i], n_max,
                                 d_running=self.d_running,
                                 d_transition=self.d_transition,
                                 worker_faulted=False, hw=self.hw)
            for i, t in enumerate(self.tasks)])
        # prefix DPs: P[i] covers tasks 0..i-1; pch[i] is task i's choice
        P = [np.zeros(n_max + 1)]
        pch = np.zeros((m, n_max + 1), dtype=np.int64)
        for i in range(m):
            nxt, pch[i] = _maxplus(P[i], base[i])
            P.append(nxt)
        # suffix DPs: T[i] covers tasks i..m-1; sch[i] is task i's choice
        T = [np.zeros(n_max + 1) for _ in range(m + 1)]
        sch = np.zeros((m, n_max + 1), dtype=np.int64)
        for i in range(m - 1, -1, -1):
            T[i], sch[i] = _maxplus(T[i + 1], base[i])

        def walk_prefix(last: int, budget: int, assign: List[int]) -> None:
            for t in range(last, -1, -1):
                k = int(pch[t, budget])
                assign[t] = k
                budget -= k

        def walk_suffix(first: int, budget: int, assign: List[int],
                        offset: int = 0) -> None:
            for t in range(first, m):
                k = int(sch[t, budget])
                assign[t - offset] = k
                budget -= k

        def finish_plan(skip: int) -> Plan:
            combined, cch = _maxplus(P[skip], T[skip + 1])
            j = int(np.argmax(combined[:n_now + 1]))
            total = float(combined[j])
            assign = [0] * (m - 1)
            b = int(cch[j])
            walk_prefix(skip - 1, j - b, assign)
            walk_suffix(skip + 1, b, assign, offset=1)
            rem = self.tasks[:skip] + self.tasks[skip + 1:]
            return Plan(tuple(assign), total,
                        _cluster_waf(rem, assign, self.hw))

        for ti in range(m):
            frow = waf_mod.reward_curve(
                self.tasks[ti], self.assignment[ti], n_max,
                d_running=self.d_running, d_transition=self.d_transition,
                worker_faulted=True, hw=self.hw)
            mid, mch = _maxplus(P[ti], frow)
            combined, cch = _maxplus(mid, T[ti + 1])
            j = int(np.argmax(combined[:n_fault + 1]))
            total = float(combined[j])
            assign = [0] * m
            b = int(cch[j])                     # suffix budget
            k = int(mch[j - b])                 # faulted task's workers
            assign[ti] = k
            walk_prefix(ti - 1, j - b - k, assign)
            walk_suffix(ti + 1, b, assign)
            self.table[f"fault:{ti}"] = Plan(
                tuple(assign), total, _cluster_waf(self.tasks, assign,
                                                   self.hw))

        j = int(np.argmax(P[m]))                # join: full budget n_max
        assign = [0] * m
        walk_prefix(m - 1, j, assign)
        self.table["join:1"] = Plan(tuple(assign), float(P[m][j]),
                                    _cluster_waf(self.tasks, assign,
                                                 self.hw))
        for ti in range(m):
            self.table[f"finish:{ti}"] = finish_plan(ti)

    def lookup(self, key: str) -> Optional[Plan]:
        return self.table.get(key)
