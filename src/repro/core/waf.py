"""WAF — weighted achieved aggregate FLOP/s (§5.1, Eq. 2) and the
reconfiguration reward G (Eq. 3/4).

Scalar entry points (``waf``, ``reward``) are the reference semantics; the
vector entry points (``waf_curve``, ``reward_curve``) produce whole
F(t, ·) / G(t, ·) rows at once from the memoized cost-model sweep, which is
what the vectorized planner consumes."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import costmodel
from repro.core.costmodel import Hardware, TaskModel


@dataclass(frozen=True)
class Task:
    """A cluster training task: model + priority weight + min requirement.

    ``max_workers`` is a per-task worker ceiling (data-parallel width
    limits, quota, license caps): workers past the cap idle, so F(t, ·)
    is *flat* past it.  The planner exploits the flat tail with banded
    max-plus convolutions (band cap+1 instead of n)."""
    model: TaskModel
    weight: float = 1.0                    # w(t), recommended 0.5..2.0
    min_workers: Optional[int] = None      # T_necessary(t); None = auto
    max_workers: Optional[int] = None      # worker cap; None = uncapped

    def necessary(self, hw: Hardware) -> int:
        if self.min_workers is not None:
            return self.min_workers
        return costmodel.min_feasible_workers(self.model, hw)


def waf(task: Task, x: int, hw: Hardware) -> float:
    """F(t, x) = w(t) * T(t, x) if requirement satisfied else 0 (Eq. 2).
    Workers past ``task.max_workers`` idle: x is clamped to the cap before
    both the requirement check and the throughput lookup, so a task whose
    cap sits below its requirement floor can never run."""
    cap = getattr(task, "max_workers", None)   # duck-typed test tasks
    if cap is not None:
        x = min(x, cap)
    if x < task.necessary(hw) or x <= 0:
        return 0.0
    return task.weight * costmodel.achieved_flops(task.model, x, hw)


def reward(task: Task, x_old: int, x_new: int, *, d_running: float,
           d_transition: float, worker_faulted: bool,
           hw: Hardware) -> float:
    """G(t, x') (Eq. 3): post-reconfiguration WAF over the expected run
    duration, minus the WAF lost during the transition when the task must
    transition (Eq. 4 indicator)."""
    g = waf(task, x_new, hw) * d_running
    if x_old != x_new or worker_faulted:
        g -= waf(task, x_old, hw) * d_transition
    return g


def waf_curve(task: Task, n: int, hw: Hardware) -> np.ndarray:
    """F(t, ·) for x = 0..n as one vector (Eq. 2), from the memoized
    cost-model sweep: weight * T(t, x), zeroed below the requirement floor,
    flat past ``task.max_workers`` (same values as the scalar ``waf`` at
    every x)."""
    curve = costmodel.throughput_curve(task.model, n, hw,
                                       cap=task.max_workers)
    F = task.weight * curve.flops[:n + 1]          # fresh array (not a view)
    floor = max(task.necessary(hw), 1)
    if task.max_workers is not None and task.max_workers < floor:
        F[:] = 0.0                      # cap below the requirement: never runs
    else:
        F[:min(floor, n + 1)] = 0.0
    return F


def waf_matrix(tasks, n: int, hw: Hardware) -> np.ndarray:
    """F(t_i, ·) for every task as one (m, n+1) matrix (Eq. 2 rows): the
    vectorized simulator's WAF integrand is a gather out of this."""
    F = costmodel.throughput_matrix([t.model for t in tasks], n, hw)
    for i, t in enumerate(tasks):
        F[i] *= t.weight
        floor = max(t.necessary(hw), 1)
        cap = t.max_workers
        if cap is not None and cap < floor:
            F[i] = 0.0
            continue
        F[i, :min(floor, n + 1)] = 0.0
        if cap is not None and cap < n:
            F[i, cap + 1:] = F[i, cap]
    return F


def reward_curve(task: Task, x_old: int, n: int, *, d_running: float,
                 d_transition: float, worker_faulted: bool,
                 hw: Hardware) -> np.ndarray:
    """G(t, ·) for x' = 0..n as one vector (Eq. 3/4).

    Same values as ``reward`` at every x': the no-transition entry
    (x' == x_old, not faulted) is recomputed directly rather than by
    adding the penalty back, to stay float-identical to the scalar path."""
    F = waf_curve(task, n, hw)
    g = F * d_running - waf(task, x_old, hw) * d_transition
    if not worker_faulted and 0 <= x_old <= n:
        g[x_old] = F[x_old] * d_running
    return g


def expected_run_duration(n_workers: int, mtbf_per_worker: float) -> float:
    """D_running(n'): expected time to next failure with n' workers (larger
    pools fail sooner)."""
    if n_workers <= 0:
        return 0.0
    return mtbf_per_worker / n_workers
