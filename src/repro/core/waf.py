"""Per-task objectives: pluggable reward models behind the §5 planner.

The paper's §5 reward is training WAF — weighted achieved aggregate
FLOP/s (Eq. 2) and the reconfiguration reward G (Eq. 3/4).  This module
generalizes that to an ``Objective`` protocol so a :class:`Task` can
carry any scalar metric the planner should maximize:

* :class:`TrainingWAF` (the default) keeps the paper's semantics
  bit-identical: ``value`` is ``w(t) * T(t, x)`` from the memoized
  cost-model sweep, ``state_bytes`` the fp32+Adam ``16 * n_params``
  transition payload, ``necessary`` the §5.2 feasibility floor.
* :class:`ServingSLO` scores an inference fleet: goodput — requests/s
  served *within* a p99 latency SLO — under an offered request rate,
  with a lane-failure discount calibrated from
  ``serve.scheduler.ContinuousBatcher`` statistics.

An objective produces two things the planner consumes without knowing
which objective built them:

* ``value(task, x, hw)`` — the scalar reference metric at ``x`` workers
  (weight applied; no floor/cap handling — :func:`waf` owns those);
* ``curve(task, n, hw)`` — the same metric for x = 0..n as one fresh
  float64 vector, elementwise identical to ``value`` at every x.

**Band contract** (what a conforming reward row must satisfy for the
banded max-plus kernels to stay bitwise-safe): rows produced by
:func:`reward_curve` must be *flat past the task's cap* — G(t, x') ==
G(t, cap) for all x' > cap — which :func:`waf_curve` enforces
generically by clamping every curve past ``task.max_workers``.  Rows
need *not* be monotone: the DP's value vectors are made monotone at the
leaves by the engines themselves, and that (not row shape) is what the
band proof requires.  Objectives whose metric keeps growing past any
finite worker count (ServingSLO's attainment tail) are therefore safe
exactly when the task carries an explicit ``max_workers`` cap or the
full-width band is used.

Scalar entry points (``waf``, ``reward``) are the reference semantics;
the vector entry points (``waf_curve``, ``reward_curve``,
``waf_matrix``) produce whole F(t, ·) / G(t, ·) rows at once, which is
what the vectorized planner consumes."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import costmodel
from repro.core.costmodel import Hardware, TaskModel


class Objective:
    """Protocol for per-task reward models (see module docstring).

    Implementations must be frozen/hashable (Task is a frozen dataclass
    used as a cache key) and must keep ``value``/``curve`` elementwise
    identical — ``curve(task, n, hw)[x] == value(task, x, hw)`` for
    every x — so the scalar reference solver and the vector engines
    agree to float precision."""

    def value(self, task: "Task", x: int, hw: Hardware) -> float:
        """Weighted scalar metric at ``x`` workers (no floor/cap)."""
        raise NotImplementedError

    def curve(self, task: "Task", n: int, hw: Hardware) -> np.ndarray:
        """Weighted metric for x = 0..n as one fresh float64 vector.

        Default: stack of scalar ``value`` calls — correct for any
        objective, but O(n) scalar evaluations; override with a
        vectorized sweep when one exists."""
        return np.array([self.value(task, x, hw) for x in range(n + 1)],
                        dtype=np.float64)

    def state_bytes(self, task: "Task") -> float:
        """Bytes that must move when the task is reconfigured."""
        raise NotImplementedError

    def necessary(self, task: "Task", hw: Hardware) -> int:
        """Default requirement floor when ``task.min_workers`` is None."""
        raise NotImplementedError

    def vector_capable(self, task: "Task") -> bool:
        """Whether ``curve`` is safe for this task (planner fast path)."""
        return True


@dataclass(frozen=True)
class TrainingWAF(Objective):
    """The paper's §5.1 objective: weighted achieved aggregate FLOP/s.

    Bit-identical to the pre-objective code path: ``value`` is the
    scalar ``achieved_flops`` lookup, ``curve`` the memoized cost-model
    sweep (flat past the cap via the sweep's index gather), and
    ``state_bytes`` the fp32 params + grads + Adam moments payload."""

    def value(self, task: "Task", x: int, hw: Hardware) -> float:
        return task.weight * costmodel.achieved_flops(task.model, x, hw)

    def curve(self, task: "Task", n: int, hw: Hardware) -> np.ndarray:
        sweep = costmodel.throughput_curve(task.model, n, hw,
                                           cap=task.max_workers)
        return task.weight * sweep.flops[:n + 1]   # fresh array (not a view)

    def state_bytes(self, task: "Task") -> float:
        return 16.0 * task.model.n_params

    def necessary(self, task: "Task", hw: Hardware) -> int:
        return costmodel.min_feasible_workers(task.model, hw)

    def vector_capable(self, task: "Task") -> bool:
        return isinstance(task.model, TaskModel)


@dataclass(frozen=True)
class ServingSLO(Objective):
    """Serving objective: goodput under a p99 latency SLO.

    Models the task as ``x`` identical replicas each sustaining
    ``capacity_rps`` requests/s, derated by ``lane_fail_discount`` (the
    fraction of decode lanes lost to faults, calibrated from
    ``ContinuousBatcher.slo_stats``).  With offered load ``rate_rps``
    and utilization rho = rate / capacity, the sojourn tail is the
    M/M/1 exponential ``P(T > slo) = exp(-(1 - rho) * slo / base)``, so

        goodput(x) = min(rate, capacity) * max(0, 1 - e^((rho-1)·k))

    with ``k = slo_latency_s / base_latency_s``.  Deterministic,
    monotone non-decreasing in x, and saturating toward ``rate_rps`` —
    pair with an explicit ``Task.max_workers`` cap to give the banded
    kernels a flat tail (see module docstring)."""
    rate_rps: float                     # offered request rate
    slo_latency_s: float = 0.5          # p99 latency target
    base_latency_s: float = 0.05        # zero-load service time
    capacity_rps: float = 8.0           # per-worker saturation throughput
    lane_fail_discount: float = 0.0     # fraction of lanes lost to faults

    def _goodput(self, x: np.ndarray) -> np.ndarray:
        cap_rps = self.capacity_rps * (1.0 - self.lane_fail_discount)
        c = x * cap_rps
        served = np.minimum(self.rate_rps, c)
        rho = self.rate_rps / np.where(c > 0.0, c, 1.0)
        k = self.slo_latency_s / self.base_latency_s
        with np.errstate(over="ignore"):
            attain = 1.0 - np.exp((rho - 1.0) * k)
        return np.where(c > 0.0, served * np.maximum(attain, 0.0), 0.0)

    def value(self, task: "Task", x: int, hw: Hardware) -> float:
        row = self._goodput(np.array([float(x)], dtype=np.float64))
        return float(task.weight * row[0])

    def curve(self, task: "Task", n: int, hw: Hardware) -> np.ndarray:
        return task.weight * self._goodput(
            np.arange(n + 1, dtype=np.float64))

    def state_bytes(self, task: "Task") -> float:
        # inference replicas ship fp16 weights only — no grads/optimizer
        return 2.0 * task.model.n_params

    def necessary(self, task: "Task", hw: Hardware) -> int:
        return 1                        # any non-empty replica set serves

    def vector_capable(self, task: "Task") -> bool:
        return True

    def with_rate(self, rate_rps: float) -> "ServingSLO":
        """New objective at a different offered load — the payload of a
        :class:`~repro.core.scenarios.RateChangeEvent` trace step."""
        return dataclasses.replace(self, rate_rps=float(rate_rps))

    def calibrated(self, stats: dict) -> "ServingSLO":
        """New objective with ``lane_fail_discount`` refreshed from
        :meth:`ContinuousBatcher.slo_stats` counters (lane-failure
        evictions over all lane completions)."""
        failed = float(stats.get("lane_failures", 0))
        done = float(stats.get("completed", 0))
        frac = failed / max(failed + done, 1.0)
        return dataclasses.replace(self, lane_fail_discount=frac)


#: Module-level default: all instances compare/hash equal, so Tasks built
#: before and after this PR are interchangeable cache keys.
TRAINING_WAF = TrainingWAF()


@dataclass(frozen=True)
class Task:
    """A cluster task: model + priority weight + objective + worker bounds.

    ``objective`` selects the reward model (default: the paper's
    training WAF).  ``max_workers`` is a per-task worker ceiling
    (data-parallel width limits, quota, license caps): workers past the
    cap idle, so F(t, ·) is *flat* past it.  The planner exploits the
    flat tail with banded max-plus convolutions (band cap+1 instead of
    n).  The cap is part of the Task contract proper — every Task-like
    object the reward layer sees must expose ``max_workers`` (None for
    uncapped), ``weight``, ``necessary(hw)`` and ``objective``."""
    model: TaskModel
    weight: float = 1.0                    # w(t), recommended 0.5..2.0
    min_workers: Optional[int] = None      # T_necessary(t); None = auto
    max_workers: Optional[int] = None      # worker cap; None = uncapped
    objective: Objective = TRAINING_WAF    # reward model

    def necessary(self, hw: Hardware) -> int:
        if self.min_workers is not None:
            return self.min_workers
        return self.objective.necessary(self, hw)


def state_bytes(task: Task) -> float:
    """Reconfiguration payload for ``task`` (objective-defined)."""
    return task.objective.state_bytes(task)


def waf(task: Task, x: int, hw: Hardware) -> float:
    """F(t, x) = objective value if requirement satisfied else 0 (Eq. 2).
    Workers past ``task.max_workers`` idle: x is clamped to the cap before
    both the requirement check and the metric lookup, so a task whose
    cap sits below its requirement floor can never run."""
    cap = task.max_workers
    if cap is not None:
        x = min(x, cap)
    if x < task.necessary(hw) or x <= 0:
        return 0.0
    return task.objective.value(task, x, hw)


def reward(task: Task, x_old: int, x_new: int, *, d_running: float,
           d_transition: float, worker_faulted: bool,
           hw: Hardware) -> float:
    """G(t, x') (Eq. 3): post-reconfiguration reward over the expected run
    duration, minus the reward lost during the transition when the task
    must transition (Eq. 4 indicator)."""
    g = waf(task, x_new, hw) * d_running
    if x_old != x_new or worker_faulted:
        g -= waf(task, x_old, hw) * d_transition
    return g


def waf_curve(task: Task, n: int, hw: Hardware) -> np.ndarray:
    """F(t, ·) for x = 0..n as one vector (Eq. 2): the objective's curve,
    zeroed below the requirement floor and clamped flat past
    ``task.max_workers`` (same values as the scalar ``waf`` at every x)."""
    F = task.objective.curve(task, n, hw)
    floor = max(task.necessary(hw), 1)
    cap = task.max_workers
    if cap is not None and cap < floor:
        F[:] = 0.0                      # cap below the requirement: never runs
        return F
    F[:min(floor, n + 1)] = 0.0
    if cap is not None and cap < n:
        F[cap + 1:] = F[cap]            # flat tail (band contract)
    return F


def waf_matrix(tasks, n: int, hw: Hardware) -> np.ndarray:
    """F(t_i, ·) for every task as one (m, n+1) matrix (Eq. 2 rows): the
    vectorized simulator's WAF integrand is a gather out of this.

    All-training fleets take the shared ``throughput_matrix`` sweep
    (bit-identical to the pre-objective path); mixed-objective fleets
    stack per-task ``waf_curve`` rows."""
    if not all(type(t.objective) is TrainingWAF for t in tasks):
        if not tasks:
            return np.zeros((0, n + 1))
        return np.stack([waf_curve(t, n, hw) for t in tasks])
    F = costmodel.throughput_matrix([t.model for t in tasks], n, hw)
    for i, t in enumerate(tasks):
        F[i] *= t.weight
        floor = max(t.necessary(hw), 1)
        cap = t.max_workers
        if cap is not None and cap < floor:
            F[i] = 0.0
            continue
        F[i, :min(floor, n + 1)] = 0.0
        if cap is not None and cap < n:
            F[i, cap + 1:] = F[i, cap]
    return F


def reward_curve(task: Task, x_old: int, n: int, *, d_running: float,
                 d_transition: float, worker_faulted: bool,
                 hw: Hardware) -> np.ndarray:
    """G(t, ·) for x' = 0..n as one vector (Eq. 3/4).

    Same values as ``reward`` at every x': the no-transition entry
    (x' == x_old, not faulted) is recomputed directly rather than by
    adding the penalty back, to stay float-identical to the scalar path."""
    F = waf_curve(task, n, hw)
    g = F * d_running - waf(task, x_old, hw) * d_transition
    if not worker_faulted and 0 <= x_old <= n:
        g[x_old] = F[x_old] * d_running
    return g


def expected_run_duration(n_workers: int, mtbf_per_worker: float) -> float:
    """D_running(n'): expected time to next failure with n' workers (larger
    pools fail sooner)."""
    if n_workers <= 0:
        return 0.0
    return mtbf_per_worker / n_workers
