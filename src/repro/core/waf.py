"""WAF — weighted achieved aggregate FLOP/s (§5.1, Eq. 2) and the
reconfiguration reward G (Eq. 3/4)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core import costmodel
from repro.core.costmodel import Hardware, TaskModel


@dataclass(frozen=True)
class Task:
    """A cluster training task: model + priority weight + min requirement."""
    model: TaskModel
    weight: float = 1.0                    # w(t), recommended 0.5..2.0
    min_workers: Optional[int] = None      # T_necessary(t); None = auto

    def necessary(self, hw: Hardware) -> int:
        if self.min_workers is not None:
            return self.min_workers
        return costmodel.min_feasible_workers(self.model, hw)


def waf(task: Task, x: int, hw: Hardware) -> float:
    """F(t, x) = w(t) * T(t, x) if requirement satisfied else 0 (Eq. 2)."""
    if x < task.necessary(hw) or x <= 0:
        return 0.0
    return task.weight * costmodel.achieved_flops(task.model, x, hw)


def reward(task: Task, x_old: int, x_new: int, *, d_running: float,
           d_transition: float, worker_faulted: bool,
           hw: Hardware) -> float:
    """G(t, x') (Eq. 3): post-reconfiguration WAF over the expected run
    duration, minus the WAF lost during the transition when the task must
    transition (Eq. 4 indicator)."""
    g = waf(task, x_new, hw) * d_running
    if x_old != x_new or worker_faulted:
        g -= waf(task, x_old, hw) * d_transition
    return g


def expected_run_duration(n_workers: int, mtbf_per_worker: float) -> float:
    """D_running(n'): expected time to next failure with n' workers (larger
    pools fail sooner)."""
    if n_workers <= 0:
        return 0.0
    return mtbf_per_worker / n_workers
