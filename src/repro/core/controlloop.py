"""The Unicron control loop — operational glue between agents and the
coordinator (§3, Figure 5).

Agents publish heartbeats and error reports into the status monitor (the
etcd-like KV store); the control loop is the coordinator-side poller
that turns that stream into decisions:

  1. expire heartbeat leases -> LOST_CONNECTION (SEV1) for silent nodes,
  2. collect in-band error reports whose detection latency has elapsed,
  3. classify severity and decide the action (reattempt / restart /
     reconfigure) with escalation on repeated failure,
  4. on SEV1: drain the node in the cluster state and fetch the
     reconfiguration plan (lookup table first, fresh solve on miss),
  5. on node repair: rejoin + replan.

The loop is deliberately synchronous and driven by an external clock so
the discrete-event simulator and the real examples share it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.agent import UnicronAgent
from repro.core.cluster import Cluster
from repro.core.coordinator import UnicronCoordinator
from repro.core.detection import ErrorKind
from repro.core.handling import Action, Trigger
from repro.core.kvstore import PLAN_EPOCH_KEY


@dataclass
class LoopEvent:
    """One decision taken by the control loop (for logs / tests)."""
    time: float
    node: int
    kind: Optional[ErrorKind]                # None: task churn, not an error
    action: Action
    plan: Optional[Tuple[int, ...]] = None
    plan_latency_s: Optional[float] = None   # dispatch latency (lookup/solve)
    # batched planner-engine counters at event time (cumulative
    # coordinator.PlanStats values: level sweeps, stacked kernel
    # launches, lazily materialized tracebacks); None when the event
    # produced no plan or the coordinator runs a non-batched plan engine
    plan_levels: Optional[int] = None
    plan_launches: Optional[int] = None
    plan_tracebacks: Optional[int] = None


class ControlLoop:
    def __init__(self, coordinator: UnicronCoordinator, cluster: Cluster,
                 agents: Dict[int, UnicronAgent]):
        self.coord = coordinator
        self.cluster = cluster
        self.agents = agents
        self.kv = coordinator.kv
        self.events: List[LoopEvent] = []
        self._seen: set = set()
        self._case_seq = 0

    def _stamped(self, ev: LoopEvent) -> LoopEvent:
        """Stamp plan-producing events with the coordinator's cumulative
        batched-engine counters (like ``plan_latency_s``, a point-in-time
        read of ``PlanStats``).  Non-batched plan engines have no such
        counters — those events stay None rather than reading as
        zero-cost batched dispatches in mixed-engine logs."""
        if ev.plan is not None and self.coord.plan_engine == "batched":
            ps = self.coord.plan_stats
            ev.plan_levels = ps.batched_levels
            ev.plan_launches = ps.batched_launches
            ev.plan_tracebacks = ps.lazy_tracebacks
        return ev

    # ---- one tick of the loop ---------------------------------------------

    def tick(self, now: float) -> List[LoopEvent]:
        out: List[LoopEvent] = []
        out += self._expire_heartbeats(now)
        out += self._drain_error_reports(now)
        out += self._drain_task_reports(now)
        out += self._drain_launch_requests(now)
        out += self._rejoin_repaired(now)
        self.events += out
        return out

    def _expire_heartbeats(self, now: float) -> List[LoopEvent]:
        out = []
        for key in self.kv.expire(now):
            if not key.startswith("/nodes/"):
                continue
            node = int(key.split("/")[2])
            out.append(self._handle(now, node, ErrorKind.LOST_CONNECTION))
        return out

    def _drain_error_reports(self, now: float) -> List[LoopEvent]:
        out = []
        for key, rec in sorted(self.kv.prefix("/errors/").items()):
            if key in self._seen or rec["visible_at"] > now:
                continue
            self._seen.add(key)
            out.append(self._handle(now, rec["node"],
                                    ErrorKind(rec["kind"])))
        return out

    def _drain_task_reports(self, now: float) -> List[LoopEvent]:
        """Agent-announced task completions (``/tasks/finished/`` keys):
        deduplicate per coordinator task index — every worker of a task
        may report — and fire the ``task_finished`` trigger, highest
        index first so the remaining indices stay valid as entries pop.

        Reports are positional, so only those stamped with the current
        plan epoch are honored: once any finish/launch shifts the task
        set, still-queued reports refer to indices that no longer name
        the same task and are consumed without firing (their workers
        re-report against the new epoch if the task is genuinely done)."""
        epoch = self.kv.get(PLAN_EPOCH_KEY, 0)
        done = set()
        for key, rec in sorted(self.kv.prefix("/tasks/finished/").items()):
            if key in self._seen or rec["visible_at"] > now:
                continue
            self._seen.add(key)
            if rec.get("epoch", epoch) != epoch:
                continue                       # stale: indices have shifted
            done.add(int(rec["task"]))
        out = []
        for idx in sorted(done, reverse=True):
            if 0 <= idx < len(self.coord.entries):
                out.append(self._task_finished_event(now, idx))
        return out

    def _drain_launch_requests(self, now: float) -> List[LoopEvent]:
        """Agent-announced task launches (``/tasks/launch/`` keys): the
        task_arrival trigger (Figure 7 trigger 6), deduplicated per task
        per tick and guarded by the same published plan-epoch check as
        ``task_finished`` — a request computed against a superseded plan
        state is consumed without firing (its submitter re-announces
        against the new epoch if the launch still stands)."""
        epoch = self.kv.get(PLAN_EPOCH_KEY, 0)
        pending: Dict[object, Dict] = {}
        for key, rec in sorted(self.kv.prefix("/tasks/launch/").items()):
            if key in self._seen or rec["visible_at"] > now:
                continue
            self._seen.add(key)
            if rec.get("epoch", epoch) != epoch:
                continue                       # stale: plan state moved on
            pending.setdefault(rec["task"], rec)
        out = []
        for task, rec in pending.items():
            plan = self.coord.task_launched(
                task, self.cluster.healthy_workers(),
                avg_iter_s=rec.get("avg_iter_s", 30.0))
            self.cluster.assign(list(plan.assignment))
            out.append(self._stamped(LoopEvent(
                now, rec["node"], None, Action.RESUME, plan.assignment,
                self.coord.plan_stats.last_dispatch_s)))
        return out

    def _rejoin_repaired(self, now: float) -> List[LoopEvent]:
        out = []
        for node in self.cluster.nodes:
            if not node.healthy and node.repair_done_at is not None \
                    and node.repair_done_at <= now:
                self.cluster.recover_node(node.node_id)
                if node.node_id in self.agents:
                    self.agents[node.node_id].alive = True
                plan = self.coord.reconfigure(
                    self.cluster.healthy_workers(),
                    trigger=Trigger.NODE_JOIN)
                self.cluster.assign(list(plan.assignment))
                out.append(self._stamped(LoopEvent(
                    now, node.node_id, ErrorKind.LOST_CONNECTION,
                    Action.RESUME, plan.assignment,
                    self.coord.plan_stats.last_dispatch_s)))
        return out

    # ---- decision path -----------------------------------------------------

    def _handle(self, now: float, node: int, kind: ErrorKind) -> LoopEvent:
        self._case_seq += 1
        case_id = f"{node}:{kind.value}:{self._case_seq}"
        decision = self.coord.on_error(case_id, kind)
        plan, plan_s = None, None
        if decision.action is Action.RECONFIGURE:
            owner = self.cluster.placement.get(node)
            self.cluster.fail_node(node, repair_done_at=now + 86400.0)
            p = self.coord.reconfigure(self.cluster.healthy_workers(),
                                       faulted_task=owner,
                                       trigger=Trigger.ERROR)
            self.cluster.assign(list(p.assignment))
            plan = p.assignment
            plan_s = self.coord.plan_stats.last_dispatch_s
        self.coord.close_case(case_id)
        return self._stamped(LoopEvent(now, node, kind, decision.action,
                                       plan, plan_s))

    # ---- task churn entry points (Figure 7 triggers 5 and 6) --------------

    def _task_finished_event(self, now: float, task_index: int) -> LoopEvent:
        plan = self.coord.task_finished(task_index,
                                        self.cluster.healthy_workers())
        self.cluster.assign(list(plan.assignment))
        return self._stamped(LoopEvent(
            now, -1, None, Action.RESUME, plan.assignment,
            self.coord.plan_stats.last_dispatch_s))

    def task_finished(self, now: float, task_index: int) -> LoopEvent:
        """A task completed: free its workers and replan the remainder.
        Direct entry point; agent-announced completions arrive through
        the KV store instead (``_drain_task_reports`` in ``tick``)."""
        ev = self._task_finished_event(now, task_index)
        self.events.append(ev)
        return ev

    def task_launched(self, now: float, task,
                      avg_iter_s: float = 30.0) -> LoopEvent:
        """A new task was admitted: replan the whole cluster around it."""
        plan = self.coord.task_launched(task,
                                        self.cluster.healthy_workers(),
                                        avg_iter_s=avg_iter_s)
        self.cluster.assign(list(plan.assignment))
        ev = self._stamped(LoopEvent(
            now, -1, None, Action.RESUME, plan.assignment,
            self.coord.plan_stats.last_dispatch_s))
        self.events.append(ev)
        return ev

    # ---- escalation entry point (agents report an action failed) ----------

    def action_failed(self, now: float, node: int,
                      kind: ErrorKind) -> LoopEvent:
        """A reattempt/restart did not fix it: escalate one level."""
        self._case_seq += 1
        case_id = f"{node}:{kind.value}:esc{self._case_seq}"
        self.coord.on_error(case_id, kind)
        decision = self.coord.on_action_failed(case_id)
        plan, plan_s = None, None
        if decision.action is Action.RECONFIGURE:
            owner = self.cluster.placement.get(node)
            self.cluster.fail_node(node, repair_done_at=now + 86400.0)
            p = self.coord.reconfigure(self.cluster.healthy_workers(),
                                       faulted_task=owner,
                                       trigger=Trigger.ERROR)
            self.cluster.assign(list(p.assignment))
            plan = p.assignment
            plan_s = self.coord.plan_stats.last_dispatch_s
        self.coord.close_case(case_id)
        ev = self._stamped(LoopEvent(now, node, kind, decision.action,
                                     plan, plan_s))
        self.events.append(ev)
        return ev
