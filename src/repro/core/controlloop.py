"""The Unicron control loop — operational glue between agents and the
coordinator (§3, Figure 5), event-driven at fleet scale.

Agents publish heartbeats and error reports into the status monitor (the
etcd-like KV store); the control loop is the coordinator-side consumer
that turns that stream into decisions:

  1. expire heartbeat leases -> LOST_CONNECTION (SEV1) for silent nodes,
  2. collect in-band error reports whose detection latency has elapsed,
  3. classify severity and decide the action (reattempt / restart /
     reconfigure) with escalation on repeated failure,
  4. on SEV1: drain the node in the cluster state and fetch the
     reconfiguration plan (lookup table first, fresh solve on miss),
  5. on node repair or reappearance: rejoin + replan (or restore).

Event-driven tick (the consumer side of the sharded-store contract in
``kvstore.py``): each drain family is consumed from its append-cursor
event queue — the loop reads ``queue_slice(family, cursor)``, consumes
the visible records, and advances a *conservative* cursor (the index of
the first entry that is neither consumed nor deleted, i.e. the oldest
record still waiting out its detection latency).  The cursor is
persisted under ``CURSOR_PREFIX + family``, so a recovered loop resumes
at the dead loop's position instead of rescanning history; because the
cursor never passes an unresolved record, a crash between consume and
cursor write only re-reads — the ``/consumed`` markers make the replay
a no-op.  A tick whose queues are all empty does **zero** prefix scans
and zero sort allocations (``tick_stats`` counts them); marker GC runs
every ``gc_interval_s`` instead of scanning ``/consumed/`` per tick
(sound because the at-least-once contract already requires retention to
exceed the worst re-delivery lag — GC timing is bounded-residency
bookkeeping, not correctness).  On a store without queues
(``LegacyKVStore``) the loop falls back to the original
scan+sort+delete drains with identical observable semantics — the
equivalence suite replays one trace through both and asserts byte-equal
event streams.

Delivery semantics: agents publish at-least-once, so every record (and
every queue entry) may arrive more than once and out of order.  The
loop is idempotent under that: a record is *consumed* by deleting it
and writing a processed marker under ``CONSUMED_PREFIX + key`` (the
producer-visible ack); a re-delivered record whose marker exists is
deleted without re-firing.  All consumption state lives in the KV — a
restarted loop (after a coordinator crash) inherits the markers and
never double-fires a trigger.  Markers are garbage-collected after
``marker_retention_s`` (which must exceed the transport's maximum
re-delivery lag); records themselves are deleted on consume, so KV
residency stays bounded over arbitrarily long traces.

False-positive drains: a partition can silence a healthy node's
heartbeats long enough to expire its lease.  Before draining on
LOST_CONNECTION the loop snapshots the pre-drain assignment under
``/coord/lost/<node>``; when the node's heartbeat *reappears* (a beat
newer than the drain), the loop rejoins it and — if the plan state is
otherwise unchanged — restores that exact assignment instead of
replanning.  Restoring matters because the planner's reward is
hysteretic (transition penalties make it sticky): replanning after a
spurious drain would not return to the pre-drain optimum, so restore is
what makes chaos runs converge to the chaos-free state exactly.  The
loop tracks outstanding snapshots in memory (seeded from one
``/coord/lost/`` scan at construction), so the reappearance sweep is
free when nothing is drained.

The loop is deliberately synchronous and driven by an external clock so
the discrete-event simulator and the real examples share it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from repro.core.agent import UnicronAgent
from repro.core.cluster import Cluster
from repro.core.coordinator import UnicronCoordinator
from repro.core.detection import ErrorKind
from repro.core.handling import Action, Trigger
from repro.core.kvstore import (CONSUMED_PREFIX, CURSOR_PREFIX,
                                PLAN_EPOCH_KEY, QUEUE_FAMILIES)

LOST_PREFIX = "/coord/lost/"

ERRORS_FAMILY, FINISHED_FAMILY, LAUNCH_FAMILY = QUEUE_FAMILIES


@dataclass
class LoopEvent:
    """One decision taken by the control loop (for logs / tests)."""
    time: float
    node: int
    kind: Optional[ErrorKind]                # None: task churn, not an error
    action: Action
    plan: Optional[Tuple[int, ...]] = None
    plan_latency_s: Optional[float] = None   # dispatch latency (lookup/solve)
    # batched planner-engine counters at event time (cumulative
    # coordinator.PlanStats values: level sweeps, stacked kernel
    # launches, lazily materialized tracebacks); None when the event
    # produced no plan or the coordinator runs a non-batched plan engine
    plan_levels: Optional[int] = None
    plan_launches: Optional[int] = None
    plan_tracebacks: Optional[int] = None


class ControlLoop:
    def __init__(self, coordinator: UnicronCoordinator, cluster: Cluster,
                 agents: Dict[int, UnicronAgent],
                 marker_retention_s: float = 600.0,
                 gc_interval_s: float = 60.0):
        self.coord = coordinator
        self.cluster = cluster
        self.agents = agents
        self.kv = coordinator.kv
        self.events: List[LoopEvent] = []
        self.marker_retention_s = marker_retention_s
        self.gc_interval_s = gc_interval_s
        self._last_gc: Optional[float] = None
        self._case_seq = 0
        # per-loop tick-cost counters (regression-tested: a quiet tick
        # must do zero prefix scans and zero drain sorts on a queued
        # store — the event-driven guarantee)
        self.tick_stats = {"ticks": 0, "prefix_scans": 0,
                           "drain_sorts": 0, "queue_reads": 0,
                           "records_consumed": 0, "gc_runs": 0}
        # queue-cursor drains when the store offers append queues,
        # scan+sort fallback otherwise (LegacyKVStore)
        self._queued = callable(getattr(self.kv, "queue_slice", None))
        self._cursors: Dict[str, int] = {}
        if self._queued:
            for fam in QUEUE_FAMILIES:
                self._cursors[fam] = int(self.kv.get(CURSOR_PREFIX + fam, 0))
        # outstanding false-positive-drain snapshots (one scan here;
        # incrementally maintained so the reappearance sweep is free
        # when nothing is drained)
        self._lost_nodes: Set[int] = {
            int(key[len(LOST_PREFIX):])
            for key in self.kv.prefix(LOST_PREFIX)}

    def _stamped(self, ev: LoopEvent) -> LoopEvent:
        """Stamp plan-producing events with the coordinator's cumulative
        batched-engine counters (like ``plan_latency_s``, a point-in-time
        read of ``PlanStats``).  Non-batched plan engines have no such
        counters — those events stay None rather than reading as
        zero-cost batched dispatches in mixed-engine logs."""
        if ev.plan is not None and self.coord.plan_engine == "batched":
            ps = self.coord.plan_stats
            ev.plan_levels = ps.batched_levels
            ev.plan_launches = ps.batched_launches
            ev.plan_tracebacks = ps.lazy_tracebacks
        return ev

    # ---- idempotent consumption (KV-backed processed markers) --------------

    def _consumed(self, key: str) -> bool:
        return self.kv.get(CONSUMED_PREFIX + key) is not None

    def _consume(self, key: str, now: float) -> None:
        """Delete-on-consume + processed marker: the delete bounds KV
        residency, the marker is both the re-delivery guard and the
        producer-visible acknowledgement (outbox retirement)."""
        self.kv.delete(key)
        self.kv.put(CONSUMED_PREFIX + key, now, now=now)

    def _gc_markers(self, now: float) -> None:
        """Purge expired processed markers, amortized to one
        ``/consumed/`` sweep per ``gc_interval_s``.  Late duplicates are
        unaffected: the at-least-once contract requires
        ``marker_retention_s`` to exceed the worst re-delivery lag, so
        any marker a duplicate could still need is never GC-eligible —
        the interval only delays reclaiming provably dead markers."""
        if self._last_gc is not None \
                and now - self._last_gc < self.gc_interval_s:
            return
        self._last_gc = now
        self.tick_stats["gc_runs"] += 1
        self.tick_stats["prefix_scans"] += 1
        for key, t in self.kv.prefix(CONSUMED_PREFIX).items():
            if now - float(t) > self.marker_retention_s:
                self.kv.delete(key)

    # ---- drain-family consumption ------------------------------------------

    def _due_records(self, family: str, now: float) -> List[Tuple[str, Dict]]:
        """Consume every visible, unconsumed record of one drain family;
        returns (key, record) pairs in sorted key order (the legacy drain
        order — lexicographic == chronological for these key schemas).

        Queue path: read appended keys from the persisted cursor,
        resolve each (duplicate -> delete, not-yet-visible -> leave,
        visible -> consume), and advance the cursor past the resolved
        head.  The cursor is conservative — it never passes a record
        still waiting out its detection latency — so the re-read tail is
        bounded by the in-flight window, not history."""
        if not self._queued:
            self.tick_stats["prefix_scans"] += 1
            records = self.kv.prefix(family)
            if not records:
                return []
            self.tick_stats["drain_sorts"] += 1
            out = []
            for key in sorted(records):
                if self._consumed(key):
                    self.kv.delete(key)        # re-delivered duplicate
                    continue
                rec = records[key]
                if rec["visible_at"] > now:
                    continue
                self._consume(key, now)
                out.append((key, rec))
            self.tick_stats["records_consumed"] += len(out)
            return out

        cursor = self._cursors[family]
        if self.kv.queue_len(family) == cursor:
            return []                          # family idle: zero work
        self.tick_stats["queue_reads"] += 1
        out = []
        resolved_head = 0
        at_head = True
        for i, key in enumerate(self.kv.queue_slice(family, cursor)):
            rec = self.kv.get(key)
            if rec is None:
                # consumed earlier (marker holds the ack) or deleted:
                # either way resolved
                if at_head:
                    resolved_head = i + 1
                continue
            if self._consumed(key):
                self.kv.delete(key)            # re-delivered duplicate
                if at_head:
                    resolved_head = i + 1
                continue
            if rec["visible_at"] > now:
                at_head = False                # cursor must wait for it
                continue
            self._consume(key, now)
            out.append((key, rec))
            if at_head:
                resolved_head = i + 1
        if resolved_head:
            self._cursors[family] = cursor + resolved_head
            self.kv.put(CURSOR_PREFIX + family, cursor + resolved_head)
        if out:
            self.tick_stats["drain_sorts"] += 1
            out.sort(key=lambda kr: kr[0])
        self.tick_stats["records_consumed"] += len(out)
        return out

    # ---- one tick of the loop ---------------------------------------------

    def tick(self, now: float) -> List[LoopEvent]:
        self.tick_stats["ticks"] += 1
        out: List[LoopEvent] = []
        out += self._expire_heartbeats(now)
        out += self._drain_error_reports(now)
        out += self._drain_task_reports(now)
        out += self._drain_launch_requests(now)
        out += self._rejoin_repaired(now)
        out += self._rejoin_reappeared(now)
        self._gc_markers(now)
        self.events += out
        return out

    def _expire_heartbeats(self, now: float) -> List[LoopEvent]:
        out = []
        for key in self.kv.expire(now):
            if not key.startswith("/nodes/"):
                continue
            node = int(key.split("/")[2])
            out.append(self._handle(now, node, ErrorKind.LOST_CONNECTION))
        return out

    def _drain_error_reports(self, now: float) -> List[LoopEvent]:
        out = []
        for key, rec in self._due_records(ERRORS_FAMILY, now):
            out.append(self._handle(now, rec["node"],
                                    ErrorKind(rec["kind"])))
        return out

    def _drain_task_reports(self, now: float) -> List[LoopEvent]:
        """Agent-announced task completions (``/tasks/finished/`` keys):
        deduplicate per coordinator task index — every worker of a task
        may report — and fire the ``task_finished`` trigger, highest
        index first so the remaining indices stay valid as entries pop.

        Reports are positional, so only those stamped with the current
        plan epoch are honored: once any finish/launch shifts the task
        set, still-queued reports refer to indices that no longer name
        the same task and are consumed without firing (their workers
        re-report against the new epoch if the task is genuinely done)."""
        due = self._due_records(FINISHED_FAMILY, now)
        if not due:
            return []
        epoch = self.kv.get(PLAN_EPOCH_KEY, 0)
        done = set()
        for key, rec in due:
            if rec.get("epoch", epoch) != epoch:
                continue                       # stale: indices have shifted
            done.add(int(rec["task"]))
        out = []
        for idx in sorted(done, reverse=True):
            if 0 <= idx < len(self.coord.entries):
                out.append(self._task_finished_event(now, idx))
        return out

    def _drain_launch_requests(self, now: float) -> List[LoopEvent]:
        """Agent-announced task launches (``/tasks/launch/`` keys): the
        task_arrival trigger (Figure 7 trigger 6), deduplicated per task
        per tick and guarded by the same published plan-epoch check as
        ``task_finished`` — a request computed against a superseded plan
        state is consumed without firing (its submitter re-announces
        against the new epoch if the launch still stands)."""
        due = self._due_records(LAUNCH_FAMILY, now)
        if not due:
            return []
        epoch = self.kv.get(PLAN_EPOCH_KEY, 0)
        pending: Dict[object, Dict] = {}
        for key, rec in due:
            if rec.get("epoch", epoch) != epoch:
                continue                       # stale: plan state moved on
            pending.setdefault(rec["task"], rec)
        out = []
        for task, rec in pending.items():
            plan = self.coord.task_launched(
                task, self.cluster.healthy_workers(),
                avg_iter_s=rec.get("avg_iter_s", 30.0))
            self.cluster.assign(list(plan.assignment))
            out.append(self._stamped(LoopEvent(
                now, rec["node"], None, Action.RESUME, plan.assignment,
                self.coord.plan_stats.last_dispatch_s)))
        return out

    def _rejoin_repaired(self, now: float) -> List[LoopEvent]:
        out = []
        for node in self.cluster.repair_due(now):
            self.cluster.recover_node(node.node_id)
            if node.node_id in self.agents:
                self.agents[node.node_id].alive = True
            # a repaired node is a fresh join, not a reappearance:
            # drop any pending lost-node snapshot so the restore path
            # cannot fire once its heartbeats resume
            self.kv.delete(f"{LOST_PREFIX}{node.node_id}")
            self._lost_nodes.discard(node.node_id)
            plan = self.coord.reconfigure(
                self.cluster.healthy_workers(),
                trigger=Trigger.NODE_JOIN)
            self.cluster.assign(list(plan.assignment))
            out.append(self._stamped(LoopEvent(
                now, node.node_id, ErrorKind.LOST_CONNECTION,
                Action.RESUME, plan.assignment,
                self.coord.plan_stats.last_dispatch_s)))
        return out

    def _rejoin_reappeared(self, now: float) -> List[LoopEvent]:
        """Undo false-positive drains: a node drained for LOST_CONNECTION
        whose heartbeat resumes (a beat strictly newer than the drain)
        was partitioned, not dead.  Rejoin it and restore the exact
        pre-drain assignment when the plan state is unchanged (same
        epoch, same task count, same healthy capacity after rejoin);
        otherwise fall back to an ordinary join replan."""
        if not self._lost_nodes:
            return []
        out = []
        for node in sorted(self._lost_nodes):
            key = f"{LOST_PREFIX}{node}"
            saved = self.kv.get(key)
            if saved is None:
                self._lost_nodes.discard(node)
                continue
            if self.cluster.nodes[node].healthy:
                self.kv.delete(key)            # repaired through other path
                self._lost_nodes.discard(node)
                continue
            hb = self.kv.get(f"/nodes/{node}/alive")
            if hb is None or float(hb) <= saved["drained_at"]:
                continue                       # still silent
            self.kv.delete(key)
            self._lost_nodes.discard(node)
            self.cluster.recover_node(node)
            if node in self.agents:
                self.agents[node].alive = True
            restorable = (
                saved["epoch"] == self.coord.plan_epoch
                and len(saved["assignment"]) == len(self.coord.entries)
                and self.cluster.healthy_workers() == saved["healthy_workers"])
            if restorable:
                self.coord.restore_assignment(saved["assignment"])
                plan, plan_s = tuple(saved["assignment"]), None
            else:
                p = self.coord.reconfigure(self.cluster.healthy_workers(),
                                           trigger=Trigger.NODE_JOIN)
                plan = p.assignment
                plan_s = self.coord.plan_stats.last_dispatch_s
            self.cluster.assign(list(plan))
            out.append(self._stamped(LoopEvent(
                now, node, ErrorKind.LOST_CONNECTION, Action.RESUME,
                plan, plan_s)))
        return out

    # ---- decision path -----------------------------------------------------

    def _drain_and_replan(self, now: float, node: int,
                          kind: ErrorKind) -> Tuple[Tuple[int, ...], float]:
        """SEV1 drain: snapshot the pre-drain state (for the reappearance
        restore path), fail the node, and fetch the reconfiguration plan."""
        if kind is ErrorKind.LOST_CONNECTION:
            self.kv.put(f"{LOST_PREFIX}{node}", {
                "drained_at": now,
                "healthy_workers": self.cluster.healthy_workers(),
                "assignment": tuple(e.n_workers for e in self.coord.entries),
                "epoch": self.coord.plan_epoch,
            }, now=now)
            self._lost_nodes.add(node)
        owner = self.cluster.placement.get(node)
        self.cluster.fail_node(node, repair_done_at=now + 86400.0)
        p = self.coord.reconfigure(self.cluster.healthy_workers(),
                                   faulted_task=owner,
                                   trigger=Trigger.ERROR)
        self.cluster.assign(list(p.assignment))
        return p.assignment, self.coord.plan_stats.last_dispatch_s

    def _handle(self, now: float, node: int, kind: ErrorKind) -> LoopEvent:
        self._case_seq += 1
        # case ids carry the wall clock so they stay unique across a
        # coordinator crash (the per-loop sequence restarts at 0)
        case_id = f"{node}:{kind.value}:{now:.3f}:{self._case_seq}"
        decision = self.coord.on_error(case_id, kind)
        plan, plan_s = None, None
        if decision.action is Action.RECONFIGURE \
                and self.cluster.nodes[node].healthy:
            # the healthy guard makes duplicate SEV1s on an
            # already-drained node (e.g. a delayed heartbeat re-creating
            # then re-expiring a lease) a no-op instead of a double drain
            plan, plan_s = self._drain_and_replan(now, node, kind)
        self.coord.close_case(case_id)
        return self._stamped(LoopEvent(now, node, kind, decision.action,
                                       plan, plan_s))

    # ---- task churn entry points (Figure 7 triggers 5 and 6) --------------

    def _task_finished_event(self, now: float, task_index: int) -> LoopEvent:
        plan = self.coord.task_finished(task_index,
                                        self.cluster.healthy_workers())
        self.cluster.assign(list(plan.assignment))
        return self._stamped(LoopEvent(
            now, -1, None, Action.RESUME, plan.assignment,
            self.coord.plan_stats.last_dispatch_s))

    def task_finished(self, now: float, task_index: int) -> LoopEvent:
        """A task completed: free its workers and replan the remainder.
        Direct entry point; agent-announced completions arrive through
        the KV store instead (``_drain_task_reports`` in ``tick``)."""
        ev = self._task_finished_event(now, task_index)
        self.events.append(ev)
        return ev

    def task_launched(self, now: float, task,
                      avg_iter_s: float = 30.0) -> LoopEvent:
        """A new task was admitted: replan the whole cluster around it."""
        plan = self.coord.task_launched(task,
                                        self.cluster.healthy_workers(),
                                        avg_iter_s=avg_iter_s)
        self.cluster.assign(list(plan.assignment))
        ev = self._stamped(LoopEvent(
            now, -1, None, Action.RESUME, plan.assignment,
            self.coord.plan_stats.last_dispatch_s))
        self.events.append(ev)
        return ev

    # ---- escalation entry point (agents report an action failed) ----------

    def action_failed(self, now: float, node: int,
                      kind: ErrorKind) -> LoopEvent:
        """A reattempt/restart did not fix it: escalate one level."""
        self._case_seq += 1
        case_id = f"{node}:{kind.value}:{now:.3f}:esc{self._case_seq}"
        self.coord.on_error(case_id, kind)
        decision = self.coord.on_action_failed(case_id)
        plan, plan_s = None, None
        if decision.action is Action.RECONFIGURE \
                and self.cluster.nodes[node].healthy:
            plan, plan_s = self._drain_and_replan(now, node, kind)
        self.coord.close_case(case_id)
        ev = self._stamped(LoopEvent(now, node, kind, decision.action,
                                     plan, plan_s))
        self.events.append(ev)
        return ev
