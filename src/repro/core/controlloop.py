"""The Unicron control loop — operational glue between agents and the
coordinator (§3, Figure 5).

Agents publish heartbeats and error reports into the status monitor (the
etcd-like KV store); the control loop is the coordinator-side poller
that turns that stream into decisions:

  1. expire heartbeat leases -> LOST_CONNECTION (SEV1) for silent nodes,
  2. collect in-band error reports whose detection latency has elapsed,
  3. classify severity and decide the action (reattempt / restart /
     reconfigure) with escalation on repeated failure,
  4. on SEV1: drain the node in the cluster state and fetch the
     reconfiguration plan (lookup table first, fresh solve on miss),
  5. on node repair or reappearance: rejoin + replan (or restore).

Delivery semantics (the consumer side of the contract in ``kvstore.py``):
agents publish at-least-once, so every record may arrive more than once
and out of order.  The loop is idempotent under that: a record is
*consumed* by deleting it and writing a processed marker under
``CONSUMED_PREFIX + key`` (the producer-visible ack); a re-delivered
record whose marker exists is deleted without re-firing.  All
consumption state lives in the KV — a restarted loop (after a
coordinator crash) inherits the markers and never double-fires a
trigger.  Markers are garbage-collected after ``marker_retention_s``
(which must exceed the transport's maximum re-delivery lag); records
themselves are deleted on consume, so KV residency stays bounded over
arbitrarily long traces.

False-positive drains: a partition can silence a healthy node's
heartbeats long enough to expire its lease.  Before draining on
LOST_CONNECTION the loop snapshots the pre-drain assignment under
``/coord/lost/<node>``; when the node's heartbeat *reappears* (a beat
newer than the drain), the loop rejoins it and — if the plan state is
otherwise unchanged — restores that exact assignment instead of
replanning.  Restoring matters because the planner's reward is
hysteretic (transition penalties make it sticky): replanning after a
spurious drain would not return to the pre-drain optimum, so restore is
what makes chaos runs converge to the chaos-free state exactly.

The loop is deliberately synchronous and driven by an external clock so
the discrete-event simulator and the real examples share it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.agent import UnicronAgent
from repro.core.cluster import Cluster
from repro.core.coordinator import UnicronCoordinator
from repro.core.detection import ErrorKind
from repro.core.handling import Action, Trigger
from repro.core.kvstore import CONSUMED_PREFIX, PLAN_EPOCH_KEY

LOST_PREFIX = "/coord/lost/"


@dataclass
class LoopEvent:
    """One decision taken by the control loop (for logs / tests)."""
    time: float
    node: int
    kind: Optional[ErrorKind]                # None: task churn, not an error
    action: Action
    plan: Optional[Tuple[int, ...]] = None
    plan_latency_s: Optional[float] = None   # dispatch latency (lookup/solve)
    # batched planner-engine counters at event time (cumulative
    # coordinator.PlanStats values: level sweeps, stacked kernel
    # launches, lazily materialized tracebacks); None when the event
    # produced no plan or the coordinator runs a non-batched plan engine
    plan_levels: Optional[int] = None
    plan_launches: Optional[int] = None
    plan_tracebacks: Optional[int] = None


class ControlLoop:
    def __init__(self, coordinator: UnicronCoordinator, cluster: Cluster,
                 agents: Dict[int, UnicronAgent],
                 marker_retention_s: float = 600.0):
        self.coord = coordinator
        self.cluster = cluster
        self.agents = agents
        self.kv = coordinator.kv
        self.events: List[LoopEvent] = []
        self.marker_retention_s = marker_retention_s
        self._case_seq = 0

    def _stamped(self, ev: LoopEvent) -> LoopEvent:
        """Stamp plan-producing events with the coordinator's cumulative
        batched-engine counters (like ``plan_latency_s``, a point-in-time
        read of ``PlanStats``).  Non-batched plan engines have no such
        counters — those events stay None rather than reading as
        zero-cost batched dispatches in mixed-engine logs."""
        if ev.plan is not None and self.coord.plan_engine == "batched":
            ps = self.coord.plan_stats
            ev.plan_levels = ps.batched_levels
            ev.plan_launches = ps.batched_launches
            ev.plan_tracebacks = ps.lazy_tracebacks
        return ev

    # ---- idempotent consumption (KV-backed processed markers) --------------

    def _consumed(self, key: str) -> bool:
        return self.kv.get(CONSUMED_PREFIX + key) is not None

    def _consume(self, key: str, now: float) -> None:
        """Delete-on-consume + processed marker: the delete bounds KV
        residency, the marker is both the re-delivery guard and the
        producer-visible acknowledgement (outbox retirement)."""
        self.kv.delete(key)
        self.kv.put(CONSUMED_PREFIX + key, now, now=now)

    def _gc_markers(self, now: float) -> None:
        for key, t in self.kv.prefix(CONSUMED_PREFIX).items():
            if now - float(t) > self.marker_retention_s:
                self.kv.delete(key)

    # ---- one tick of the loop ---------------------------------------------

    def tick(self, now: float) -> List[LoopEvent]:
        out: List[LoopEvent] = []
        out += self._expire_heartbeats(now)
        out += self._drain_error_reports(now)
        out += self._drain_task_reports(now)
        out += self._drain_launch_requests(now)
        out += self._rejoin_repaired(now)
        out += self._rejoin_reappeared(now)
        self._gc_markers(now)
        self.events += out
        return out

    def _expire_heartbeats(self, now: float) -> List[LoopEvent]:
        out = []
        for key in self.kv.expire(now):
            if not key.startswith("/nodes/"):
                continue
            node = int(key.split("/")[2])
            out.append(self._handle(now, node, ErrorKind.LOST_CONNECTION))
        return out

    def _drain_error_reports(self, now: float) -> List[LoopEvent]:
        out = []
        for key, rec in sorted(self.kv.prefix("/errors/").items()):
            if self._consumed(key):
                self.kv.delete(key)            # re-delivered duplicate
                continue
            if rec["visible_at"] > now:
                continue
            self._consume(key, now)
            out.append(self._handle(now, rec["node"],
                                    ErrorKind(rec["kind"])))
        return out

    def _drain_task_reports(self, now: float) -> List[LoopEvent]:
        """Agent-announced task completions (``/tasks/finished/`` keys):
        deduplicate per coordinator task index — every worker of a task
        may report — and fire the ``task_finished`` trigger, highest
        index first so the remaining indices stay valid as entries pop.

        Reports are positional, so only those stamped with the current
        plan epoch are honored: once any finish/launch shifts the task
        set, still-queued reports refer to indices that no longer name
        the same task and are consumed without firing (their workers
        re-report against the new epoch if the task is genuinely done)."""
        epoch = self.kv.get(PLAN_EPOCH_KEY, 0)
        done = set()
        for key, rec in sorted(self.kv.prefix("/tasks/finished/").items()):
            if self._consumed(key):
                self.kv.delete(key)            # re-delivered duplicate
                continue
            if rec["visible_at"] > now:
                continue
            self._consume(key, now)
            if rec.get("epoch", epoch) != epoch:
                continue                       # stale: indices have shifted
            done.add(int(rec["task"]))
        out = []
        for idx in sorted(done, reverse=True):
            if 0 <= idx < len(self.coord.entries):
                out.append(self._task_finished_event(now, idx))
        return out

    def _drain_launch_requests(self, now: float) -> List[LoopEvent]:
        """Agent-announced task launches (``/tasks/launch/`` keys): the
        task_arrival trigger (Figure 7 trigger 6), deduplicated per task
        per tick and guarded by the same published plan-epoch check as
        ``task_finished`` — a request computed against a superseded plan
        state is consumed without firing (its submitter re-announces
        against the new epoch if the launch still stands)."""
        epoch = self.kv.get(PLAN_EPOCH_KEY, 0)
        pending: Dict[object, Dict] = {}
        for key, rec in sorted(self.kv.prefix("/tasks/launch/").items()):
            if self._consumed(key):
                self.kv.delete(key)            # re-delivered duplicate
                continue
            if rec["visible_at"] > now:
                continue
            self._consume(key, now)
            if rec.get("epoch", epoch) != epoch:
                continue                       # stale: plan state moved on
            pending.setdefault(rec["task"], rec)
        out = []
        for task, rec in pending.items():
            plan = self.coord.task_launched(
                task, self.cluster.healthy_workers(),
                avg_iter_s=rec.get("avg_iter_s", 30.0))
            self.cluster.assign(list(plan.assignment))
            out.append(self._stamped(LoopEvent(
                now, rec["node"], None, Action.RESUME, plan.assignment,
                self.coord.plan_stats.last_dispatch_s)))
        return out

    def _rejoin_repaired(self, now: float) -> List[LoopEvent]:
        out = []
        for node in self.cluster.nodes:
            if not node.healthy and node.repair_done_at is not None \
                    and node.repair_done_at <= now:
                self.cluster.recover_node(node.node_id)
                if node.node_id in self.agents:
                    self.agents[node.node_id].alive = True
                # a repaired node is a fresh join, not a reappearance:
                # drop any pending lost-node snapshot so the restore path
                # cannot fire once its heartbeats resume
                self.kv.delete(f"{LOST_PREFIX}{node.node_id}")
                plan = self.coord.reconfigure(
                    self.cluster.healthy_workers(),
                    trigger=Trigger.NODE_JOIN)
                self.cluster.assign(list(plan.assignment))
                out.append(self._stamped(LoopEvent(
                    now, node.node_id, ErrorKind.LOST_CONNECTION,
                    Action.RESUME, plan.assignment,
                    self.coord.plan_stats.last_dispatch_s)))
        return out

    def _rejoin_reappeared(self, now: float) -> List[LoopEvent]:
        """Undo false-positive drains: a node drained for LOST_CONNECTION
        whose heartbeat resumes (a beat strictly newer than the drain)
        was partitioned, not dead.  Rejoin it and restore the exact
        pre-drain assignment when the plan state is unchanged (same
        epoch, same task count, same healthy capacity after rejoin);
        otherwise fall back to an ordinary join replan."""
        out = []
        for key, saved in sorted(self.kv.prefix(LOST_PREFIX).items()):
            node = int(key[len(LOST_PREFIX):])
            if self.cluster.nodes[node].healthy:
                self.kv.delete(key)            # repaired through other path
                continue
            hb = self.kv.get(f"/nodes/{node}/alive")
            if hb is None or float(hb) <= saved["drained_at"]:
                continue                       # still silent
            self.kv.delete(key)
            self.cluster.recover_node(node)
            if node in self.agents:
                self.agents[node].alive = True
            restorable = (
                saved["epoch"] == self.coord.plan_epoch
                and len(saved["assignment"]) == len(self.coord.entries)
                and self.cluster.healthy_workers() == saved["healthy_workers"])
            if restorable:
                self.coord.restore_assignment(saved["assignment"])
                plan, plan_s = tuple(saved["assignment"]), None
            else:
                p = self.coord.reconfigure(self.cluster.healthy_workers(),
                                           trigger=Trigger.NODE_JOIN)
                plan = p.assignment
                plan_s = self.coord.plan_stats.last_dispatch_s
            self.cluster.assign(list(plan))
            out.append(self._stamped(LoopEvent(
                now, node, ErrorKind.LOST_CONNECTION, Action.RESUME,
                plan, plan_s)))
        return out

    # ---- decision path -----------------------------------------------------

    def _drain_and_replan(self, now: float, node: int,
                          kind: ErrorKind) -> Tuple[Tuple[int, ...], float]:
        """SEV1 drain: snapshot the pre-drain state (for the reappearance
        restore path), fail the node, and fetch the reconfiguration plan."""
        if kind is ErrorKind.LOST_CONNECTION:
            self.kv.put(f"{LOST_PREFIX}{node}", {
                "drained_at": now,
                "healthy_workers": self.cluster.healthy_workers(),
                "assignment": tuple(e.n_workers for e in self.coord.entries),
                "epoch": self.coord.plan_epoch,
            }, now=now)
        owner = self.cluster.placement.get(node)
        self.cluster.fail_node(node, repair_done_at=now + 86400.0)
        p = self.coord.reconfigure(self.cluster.healthy_workers(),
                                   faulted_task=owner,
                                   trigger=Trigger.ERROR)
        self.cluster.assign(list(p.assignment))
        return p.assignment, self.coord.plan_stats.last_dispatch_s

    def _handle(self, now: float, node: int, kind: ErrorKind) -> LoopEvent:
        self._case_seq += 1
        # case ids carry the wall clock so they stay unique across a
        # coordinator crash (the per-loop sequence restarts at 0)
        case_id = f"{node}:{kind.value}:{now:.3f}:{self._case_seq}"
        decision = self.coord.on_error(case_id, kind)
        plan, plan_s = None, None
        if decision.action is Action.RECONFIGURE \
                and self.cluster.nodes[node].healthy:
            # the healthy guard makes duplicate SEV1s on an
            # already-drained node (e.g. a delayed heartbeat re-creating
            # then re-expiring a lease) a no-op instead of a double drain
            plan, plan_s = self._drain_and_replan(now, node, kind)
        self.coord.close_case(case_id)
        return self._stamped(LoopEvent(now, node, kind, decision.action,
                                       plan, plan_s))

    # ---- task churn entry points (Figure 7 triggers 5 and 6) --------------

    def _task_finished_event(self, now: float, task_index: int) -> LoopEvent:
        plan = self.coord.task_finished(task_index,
                                        self.cluster.healthy_workers())
        self.cluster.assign(list(plan.assignment))
        return self._stamped(LoopEvent(
            now, -1, None, Action.RESUME, plan.assignment,
            self.coord.plan_stats.last_dispatch_s))

    def task_finished(self, now: float, task_index: int) -> LoopEvent:
        """A task completed: free its workers and replan the remainder.
        Direct entry point; agent-announced completions arrive through
        the KV store instead (``_drain_task_reports`` in ``tick``)."""
        ev = self._task_finished_event(now, task_index)
        self.events.append(ev)
        return ev

    def task_launched(self, now: float, task,
                      avg_iter_s: float = 30.0) -> LoopEvent:
        """A new task was admitted: replan the whole cluster around it."""
        plan = self.coord.task_launched(task,
                                        self.cluster.healthy_workers(),
                                        avg_iter_s=avg_iter_s)
        self.cluster.assign(list(plan.assignment))
        ev = self._stamped(LoopEvent(
            now, -1, None, Action.RESUME, plan.assignment,
            self.coord.plan_stats.last_dispatch_s))
        self.events.append(ev)
        return ev

    # ---- escalation entry point (agents report an action failed) ----------

    def action_failed(self, now: float, node: int,
                      kind: ErrorKind) -> LoopEvent:
        """A reattempt/restart did not fix it: escalate one level."""
        self._case_seq += 1
        case_id = f"{node}:{kind.value}:{now:.3f}:esc{self._case_seq}"
        self.coord.on_error(case_id, kind)
        decision = self.coord.on_action_failed(case_id)
        plan, plan_s = None, None
        if decision.action is Action.RECONFIGURE \
                and self.cluster.nodes[node].healthy:
            plan, plan_s = self._drain_and_replan(now, node, kind)
        self.coord.close_case(case_id)
        ev = self._stamped(LoopEvent(now, node, kind, decision.action,
                                     plan, plan_s))
        self.events.append(ev)
        return ev
