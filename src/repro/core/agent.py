"""Unicron agent (§3.1) — one per machine.

Responsibilities: (i) persistent heartbeat to the coordinator through the
status monitor (node health detection), (ii) one monitoring thread per GPU
(process supervision + exception propagation), (iii) executing recovery
actions — including restoring training state from the nearest checkpoint
tier (``recover_checkpoint``), (iv) managing the GEMINI-style in-memory
checkpoint tier.

Delivery semantics (the producer side of the contract in ``kvstore.py``):
every report — errors, task finishes, launch admissions — is published
*at least once*.  The agent keeps each record in a local outbox and
re-publishes it with seeded exponential backoff + jitter until the
control loop acknowledges consumption by writing the record's
``CONSUMED_PREFIX`` marker; during a partition (``KVUnavailable``) the
outbox simply queues and flushes on heal (graceful degradation).  Keys
are deterministic per report, so a re-publish can never double-fire a
trigger: the consumer's marker makes re-delivery a no-op.  Heartbeats
are NOT outboxed — a lost beat is superseded by the next one, and a
stale beat must not refresh a lease.

In this reproduction the agent's timing behavior runs inside the
discrete-event simulator; its *state machine* is the real code below.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.detection import (ErrorKind, OnlineStatMonitor, classify,
                                  detection_time)
from repro.core.kvstore import CONSUMED_PREFIX, KVStore, KVUnavailable

HEARTBEAT_INTERVAL_S = 2.0
HEARTBEAT_TTL_S = 6.0

# outbox re-publish backoff: base * 2^attempt, capped, with seeded
# jitter in [0.5, 1.5).  The cap keeps the worst-case re-publish lag
# (and therefore the spacing the chaos convergence harness needs
# between world events) small.
BACKOFF_BASE_S = 1.0
BACKOFF_CAP_S = 8.0


def heartbeat_cohort(agents, now: float) -> None:
    """Publish heartbeats for a whole agent cohort in one array write
    per shared store (``KVStore.heartbeat_batch`` — the fleet-scale
    ingestion path).  Agents whose client offers no batch entry point
    (chaos-bound node clients, the legacy store) beat individually, so
    partition semantics and the legacy path are unchanged.  ``agents``
    is the usual node-id -> agent mapping; dead agents are skipped
    (same contract as ``UnicronAgent.heartbeat``)."""
    singles = []
    batches: Dict[int, Tuple[object, list]] = {}
    for agent in agents.values():
        if not agent.alive:
            continue
        batch = getattr(agent.kv, "heartbeat_batch", None)
        if batch is None:
            singles.append(agent)
        else:
            batches.setdefault(id(agent.kv), (agent.kv, []))[1].append(
                agent.node_id)
    for store, node_ids in batches.values():
        store.heartbeat_batch(node_ids, now, ttl=HEARTBEAT_TTL_S)
    for agent in singles:
        agent.heartbeat(now)


@dataclass
class GPUMonitor:
    """Dedicated CPU monitoring thread for one GPU (§3.1)."""
    gpu_id: int
    healthy: bool = True
    last_exception: Optional[ErrorKind] = None

    def observe_exception(self, kind: ErrorKind) -> ErrorKind:
        self.last_exception = kind
        self.healthy = False
        return kind


@dataclass
class _OutboxItem:
    record: Dict
    created: float
    next_retry: float
    attempts: int = 0


class UnicronAgent:
    def __init__(self, node_id: int, kv: KVStore, n_gpus: int = 8,
                 seed: Optional[int] = None):
        self.node_id = node_id
        self.kv = kv
        self.monitors = [GPUMonitor(g) for g in range(n_gpus)]
        self.stat_monitor = OnlineStatMonitor()
        self.alive = True
        self._launch_seq = 0
        self._rng = random.Random(node_id if seed is None else seed)
        self._outbox: Dict[str, _OutboxItem] = {}

    # ---- heartbeat / node health -------------------------------------------

    def heartbeat(self, now: float) -> None:
        if not self.alive:
            return
        try:
            self.kv.put(f"/nodes/{self.node_id}/alive", now,
                        ttl=HEARTBEAT_TTL_S, now=now)
        except KVUnavailable:
            pass          # partitioned: the lease lapses; next beat retries

    def kill(self) -> None:
        """Simulated node loss: heartbeats stop; the coordinator's lease
        expiry raises LOST_CONNECTION."""
        self.alive = False

    # ---- at-least-once publication (outbox) --------------------------------

    @property
    def outbox_size(self) -> int:
        return len(self._outbox)

    def _backoff(self, attempts: int) -> float:
        base = min(BACKOFF_CAP_S, BACKOFF_BASE_S * (2.0 ** attempts))
        return base * (0.5 + self._rng.random())

    def _publish(self, key: str, record: Dict, now: float) -> None:
        self._outbox[key] = _OutboxItem(record=record, created=now,
                                        next_retry=now)
        self.flush_outbox(now)

    def flush_outbox(self, now: float) -> None:
        """Re-publish every unacknowledged record that is due.  A record
        retires when its processed marker appears (the control loop's
        delete-on-consume ack); until then each attempt re-puts the SAME
        key, so duplicates collapse at the consumer."""
        for key, item in list(self._outbox.items()):
            if item.next_retry > now:
                continue
            try:
                if self.kv.get(CONSUMED_PREFIX + key) is not None:
                    del self._outbox[key]          # acked: retire
                    continue
                self.kv.put(key, item.record, now=now)
            except KVUnavailable:
                pass                # partitioned: stay queued, back off
            item.attempts += 1
            item.next_retry = now + self._backoff(item.attempts)

    # ---- in-band error reporting ---------------------------------------

    def report(self, kind: ErrorKind, now: float,
               avg_iter_s: float = 30.0) -> Dict:
        """Detect + publish an error to the status monitor.  Returns the
        record including when the coordinator will see it."""
        method, sev = classify(kind)
        latency = detection_time(kind, avg_iter_s, unicron=True)
        record = {"node": self.node_id, "kind": kind.value,
                  "severity": int(sev), "method": method.value,
                  "raised_at": now, "visible_at": now + latency}
        self._publish(f"/errors/{self.node_id}/{now:.3f}", record, now)
        return record

    # ---- task churn reports (Figure 7 trigger 5) -------------------------

    def report_task_finished(self, task_index: int, now: float,
                             epoch: int) -> Dict:
        """Announce through the status monitor that the coordinator task
        this node participates in has completed (Figure 7 trigger 5).
        Completion is in-band and immediate — no detection latency — and
        every worker of the task may report; the control loop deduplicates
        per task per tick before firing ``task_finished``.

        ``epoch`` MUST be the plan epoch under which the agent learned
        ``task_index`` — index and epoch travel together in a plan
        dispatch (``PLAN_EPOCH_KEY`` at dispatch time), and pairing a
        dispatch-time index with a fresher epoch would defeat the
        staleness guard.  Task indices are positional, so the control
        loop drops any report whose epoch predates a task-set change
        instead of resolving it against shifted indices."""
        record = {"node": self.node_id, "task": int(task_index),
                  "epoch": int(epoch), "finished_at": now,
                  "visible_at": now}
        self._publish(f"/tasks/finished/{now:.3f}/{self.node_id}",
                      record, now)
        return record

    # ---- task launch admission (Figure 7 trigger 6) ----------------------

    def request_task_launch(self, task, now: float, epoch: int,
                            avg_iter_s: float = 30.0) -> Dict:
        """Announce through the status monitor that a new task asks to be
        admitted to the cluster (Figure 7 trigger 6) — the agent-side
        counterpart of ``report_task_finished`` that closes the ROADMAP
        churn gap: launches previously only entered through the
        scenario/driver side.  Worker counts are NOT part of the request:
        admission sizing is the planner's decision (the coordinator
        replans the whole cluster around the new task).

        ``epoch`` MUST be the plan epoch the requester computed its
        admission request against: the control loop drops requests whose
        epoch predates a task-set change (the same staleness guard as
        finish reports — a request sized against a stale plan state is
        re-announced by its submitter against the new epoch).  Multiple
        nodes may announce the same launch; the control loop deduplicates
        per task per tick before firing ``task_launched``."""
        self._launch_seq += 1
        record = {"node": self.node_id, "task": task,
                  "epoch": int(epoch), "avg_iter_s": float(avg_iter_s),
                  "requested_at": now, "visible_at": now}
        # Key carries a per-agent sequence (two distinct launches from one
        # node at the same timestamp must not overwrite each other) and a
        # zero-padded timestamp: the control loop drains keys in sorted
        # order, and admission order determines coordinator entry order
        # and which record wins the per-task dedup, so lexicographic must
        # equal chronological across digit-width boundaries.
        self._publish(
            f"/tasks/launch/{now:017.3f}/{self.node_id}/{self._launch_seq}",
            record, now)
        return record

    # ---- recovery: nearest-tier checkpoint restore (§6.3 / GEMINI) -------

    def recover_checkpoint(self, store, task: str, rank: int, *,
                           persist_dir: Optional[str] = None,
                           template=None) -> Tuple[object, int, str]:
        """Restore a rank's training state along the recovery preference
        order: local host RAM -> ring-neighbor replica (both via the
        GEMINI ``InMemoryStore``) -> persistent remote tier.  Returns
        (state, step, source).  Raises ``FileNotFoundError`` when no tier
        holds the state (fresh start)."""
        hit = store.get(task, rank)
        if hit is not None:
            step, tree, src = hit
            return tree, step, src
        if persist_dir is not None:
            from repro.checkpoint import persistent
            step = persistent.latest_step(persist_dir)
            if step is not None:
                return (persistent.restore(persist_dir, template), step,
                        "persistent")
        raise FileNotFoundError(
            f"no checkpoint for task={task!r} rank={rank} in any tier")

    # ---- iteration statistics (online statistical monitoring) -----------

    def observe_iteration(self, seconds: float) -> None:
        self.stat_monitor.observe(seconds)

    def check_progress(self, waited_s: float) -> str:
        return self.stat_monitor.status(waited_s)
