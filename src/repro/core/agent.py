"""Unicron agent (§3.1) — one per machine.

Responsibilities: (i) persistent heartbeat to the coordinator through the
status monitor (node health detection), (ii) one monitoring thread per GPU
(process supervision + exception propagation), (iii) executing recovery
actions, (iv) managing the GEMINI-style in-memory checkpoint tier.

In this reproduction the agent's timing behavior runs inside the
discrete-event simulator; its *state machine* is the real code below.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.detection import (ErrorKind, OnlineStatMonitor, classify,
                                  detection_time)
from repro.core.kvstore import KVStore

HEARTBEAT_INTERVAL_S = 2.0
HEARTBEAT_TTL_S = 6.0


@dataclass
class GPUMonitor:
    """Dedicated CPU monitoring thread for one GPU (§3.1)."""
    gpu_id: int
    healthy: bool = True
    last_exception: Optional[ErrorKind] = None

    def observe_exception(self, kind: ErrorKind) -> ErrorKind:
        self.last_exception = kind
        self.healthy = False
        return kind


class UnicronAgent:
    def __init__(self, node_id: int, kv: KVStore, n_gpus: int = 8):
        self.node_id = node_id
        self.kv = kv
        self.monitors = [GPUMonitor(g) for g in range(n_gpus)]
        self.stat_monitor = OnlineStatMonitor()
        self.alive = True

    # ---- heartbeat / node health -------------------------------------------

    def heartbeat(self, now: float) -> None:
        if self.alive:
            self.kv.put(f"/nodes/{self.node_id}/alive", now,
                        ttl=HEARTBEAT_TTL_S, now=now)

    def kill(self) -> None:
        """Simulated node loss: heartbeats stop; the coordinator's lease
        expiry raises LOST_CONNECTION."""
        self.alive = False

    # ---- in-band error reporting ---------------------------------------

    def report(self, kind: ErrorKind, now: float,
               avg_iter_s: float = 30.0) -> Dict:
        """Detect + publish an error to the status monitor.  Returns the
        record including when the coordinator will see it."""
        method, sev = classify(kind)
        latency = detection_time(kind, avg_iter_s, unicron=True)
        record = {"node": self.node_id, "kind": kind.value,
                  "severity": int(sev), "method": method.value,
                  "raised_at": now, "visible_at": now + latency}
        self.kv.put(f"/errors/{self.node_id}/{now:.3f}", record, now=now)
        return record

    # ---- iteration statistics (online statistical monitoring) -----------

    def observe_iteration(self, seconds: float) -> None:
        self.stat_monitor.observe(seconds)

    def check_progress(self, waited_s: float) -> str:
        return self.stat_monitor.status(waited_s)
