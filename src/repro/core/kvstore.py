"""etcd-like distributed KV store (the coordinator's *status monitor*).

Single-process stand-in for etcd [11]: prefix watches, leases with TTL
(expiry driven by the simulator clock), and compare-and-swap.  The
coordinator consolidates agent-reported process statuses here (§3.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

# Well-known status-monitor keys shared by coordinator, control loop and
# agents.  PLAN_EPOCH_KEY holds the coordinator's task-set epoch: bumped
# whenever the entry list mutates (finish/launch), so positional task
# indices in agent churn reports can be checked for freshness.
PLAN_EPOCH_KEY = "/plan/epoch"


@dataclass
class _Entry:
    value: Any
    lease_expires: Optional[float] = None       # absolute sim time


class KVStore:
    def __init__(self):
        self._data: Dict[str, _Entry] = {}
        self._watches: List[Tuple[str, Callable[[str, str, Any], None]]] = []

    # ---- basic ops ---------------------------------------------------------

    def put(self, key: str, value: Any, *, ttl: Optional[float] = None,
            now: float = 0.0) -> None:
        self._data[key] = _Entry(value, now + ttl if ttl else None)
        self._notify("put", key, value)

    def get(self, key: str, default: Any = None) -> Any:
        e = self._data.get(key)
        return default if e is None else e.value

    def delete(self, key: str) -> None:
        if key in self._data:
            del self._data[key]
            self._notify("delete", key, None)

    def prefix(self, pre: str) -> Dict[str, Any]:
        return {k: e.value for k, e in self._data.items()
                if k.startswith(pre)}

    def cas(self, key: str, expect: Any, value: Any) -> bool:
        if self.get(key) == expect:
            self.put(key, value)
            return True
        return False

    # ---- leases (heartbeats) -----------------------------------------------

    def expire(self, now: float) -> List[str]:
        """Drop entries whose lease lapsed; returns the expired keys.
        The coordinator treats an expired /nodes/<id>/alive key as a lost
        connection -> SEV1 (Table 1)."""
        dead = [k for k, e in self._data.items()
                if e.lease_expires is not None and e.lease_expires <= now]
        for k in dead:
            del self._data[k]
            self._notify("expire", k, None)
        return dead

    # ---- watches -----------------------------------------------------------

    def watch(self, pre: str, cb: Callable[[str, str, Any], None]) -> None:
        self._watches.append((pre, cb))

    def _notify(self, op: str, key: str, value: Any) -> None:
        for pre, cb in self._watches:
            if key.startswith(pre):
                cb(op, key, value)
