"""etcd-like distributed KV store (the coordinator's *status monitor*),
sharded for fleet scale.

Single-process stand-in for etcd [11]: prefix watches, leases with TTL
(expiry driven by the simulator clock), and compare-and-swap.  The
coordinator consolidates agent-reported process statuses here (§3.2).

Sharded layout (fleet-scale contract)
-------------------------------------

The namespace is partitioned into per-prefix **shard buckets** so every
hot-path operation touches only the keys that could match:

* A static registry of control-plane namespaces (``/errors/``,
  ``/tasks/finished/``, ``/coord/journal/``, ...) routes each key to its
  namespace by longest prefix match; keys outside every registered
  namespace land in a catch-all shard.
* Namespaces whose next path segment is a node id (``/errors/<node>/``,
  ``/nodes/<node>/``, ``/coord/lost/<node>``) are further split into
  node-group buckets of ``NODE_GROUP_SIZE`` ids, so ``prefix()`` over a
  single node's keys scans one bucket, and ``prefix()`` over a whole
  family merges only that family's buckets — O(matching keys), never
  O(store).
* Heartbeat keys (``/nodes/<id>/alive``) bypass the dict shards
  entirely and live in an array-native ``detection.HeartbeatTable``:
  beat values and lease deadlines sit in per-node-group numpy arrays,
  ``expire()`` is one vectorized comparison + argwhere per group, and
  ``heartbeat_batch()`` ingests a whole agent cohort's beats as one
  array scatter.  Leases on ordinary keys live in a per-bucket
  ``_LeaseLedger`` (parallel numpy deadline array + slot map), expired
  the same vectorized way.

Event queues (cursor-consume contract)
--------------------------------------

Each drain family (``/errors/``, ``/tasks/finished/``,
``/tasks/launch/``) additionally has an **append-cursor event queue**:
every ``put`` of a key in the family appends the key to the family's
append-only log, and the control loop consumes from a cursor it
persists under ``CURSOR_PREFIX + family`` instead of scanning,
sorting, and deleting the whole prefix each tick.  The queue is an
*index*, not the source of truth: records, ``/consumed`` markers and
delete-on-consume stay exactly as below, so a consumer that crashes
mid-drain replays the un-cursored tail idempotently, and a scan-based
consumer (``LegacyKVStore``) sees identical semantics.  Entries below
the persisted cursor are compacted away lazily.

Delivery-semantics contract (shared with ``agent.py``/``controlloop.py``,
exercised by ``core.chaos``):

* **At-least-once publish.**  An agent ``put`` may be dropped, delayed,
  duplicated, or rejected during a partition (``KVUnavailable``) by a
  chaotic transport (``chaos.ChaosKVStore``).  Producers therefore keep
  every report in a local outbox and re-publish with seeded exponential
  backoff until the consumer acknowledges it; a record may consequently
  be delivered more than once, and may re-appear *after* it was deleted
  (each re-delivery re-appends to the family queue — queue entries are
  at-least-once too).
* **Idempotent consume.**  The control loop deletes a record on consume
  (bounding KV residency) and writes a processed marker under
  ``CONSUMED_PREFIX + key`` whose value is the consume time.  The marker
  doubles as the producer-visible acknowledgement; a re-delivered record
  whose marker exists is deleted without re-firing.  Markers are
  garbage-collected after a retention window that must exceed the
  transport's maximum delay + partition span (``chaos.ChaosSchedule``
  generators guarantee this for generated schedules).
* **Epoch fencing.**  The coordinator journals its state under
  ``/coord/journal/*`` and claims an incarnation epoch; writes from a
  deposed incarnation raise (``coordinator.StaleCoordinatorError``), so
  a crashed-and-recovered coordinator can never be shadowed by its
  predecessor.

``KVStore`` is the sharded store; ``LegacyKVStore`` keeps the original
flat-dict implementation as the equivalence baseline (identical
observable semantics, O(store) scans).  Both are *perfect* stores (no
loss, no delay); ``chaos.ChaosKVStore`` wraps the sharded store and
injects the failure modes while preserving this interface.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.detection import HeartbeatTable

# Well-known status-monitor keys shared by coordinator, control loop and
# agents.  PLAN_EPOCH_KEY holds the coordinator's task-set epoch: bumped
# whenever the entry list mutates (finish/launch), so positional task
# indices in agent churn reports can be checked for freshness.
PLAN_EPOCH_KEY = "/plan/epoch"

# Processed-marker namespace: the control loop acknowledges a consumed
# record by writing ``CONSUMED_PREFIX + key`` = consume time (and deletes
# the record itself).  Agents poll the marker to retire outbox entries.
CONSUMED_PREFIX = "/consumed"

# Families with an append-cursor event queue (the control loop's drain
# sources).  The loop persists its consume cursor per family under
# ``CURSOR_PREFIX + family`` so a recovered loop resumes where the dead
# one stopped instead of rescanning history.
QUEUE_FAMILIES = ("/errors/", "/tasks/finished/", "/tasks/launch/")
CURSOR_PREFIX = "/cursors"

# Node-id-bucketed namespaces split into groups of this many ids.
NODE_GROUP_SIZE = 1024

# Longest-match namespace registry (order: longest first).  Second
# element: does the segment after the prefix carry a node id (-> group
# buckets)?  The catch-all "" namespace is implicit.
_NAMESPACES: Tuple[Tuple[str, bool], ...] = (
    ("/consumed/tasks/finished/", False),
    ("/consumed/tasks/launch/", False),
    ("/consumed/errors/", True),
    ("/consumed/", False),
    ("/tasks/finished/", False),
    ("/tasks/launch/", False),
    ("/coord/journal/", False),
    ("/coord/lost/", True),
    ("/cursors/", False),
    ("/errors/", True),
    ("/nodes/", True),
)

_HB_PRE = "/nodes/"
_HB_SUF = "/alive"


class KVUnavailable(Exception):
    """The store is unreachable from this client (network partition).

    Raised only by chaotic transports (``chaos.ChaosKVStore`` node
    clients); the base in-process store never raises it.  Producers
    treat it as a queue-locally signal and flush on heal."""


def _hb_node(key: str) -> Optional[int]:
    """Node id for a heartbeat key ``/nodes/<id>/alive``, else None."""
    if key.startswith(_HB_PRE) and key.endswith(_HB_SUF):
        mid = key[len(_HB_PRE):-len(_HB_SUF)]
        if mid.isdigit():
            return int(mid)
    return None


class _LeaseLedger:
    """Array-native lease deadlines for one shard bucket.

    The ``detection.FleetMonitor`` idiom applied to leases: deadlines
    live in a numpy array indexed by slot, keys map to slots through a
    dict + free list, and expiry is one vectorized comparison +
    argwhere instead of a per-entry Python scan.  Capacity doubles
    geometrically."""

    __slots__ = ("_deadline", "_keys", "_slot", "_free", "_n")

    def __init__(self, cap: int = 8):
        self._deadline = np.full(cap, np.inf)
        self._keys: List[Optional[str]] = [None] * cap
        self._slot: Dict[str, int] = {}
        self._free: List[int] = list(range(cap - 1, -1, -1))
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def set(self, key: str, deadline: float) -> None:
        slot = self._slot.get(key)
        if slot is None:
            if not self._free:
                cap = self._deadline.size
                grown = np.full(2 * cap, np.inf)
                grown[:cap] = self._deadline
                self._deadline = grown
                self._keys.extend([None] * cap)
                self._free = list(range(2 * cap - 1, cap - 1, -1))
            slot = self._free.pop()
            self._slot[key] = slot
            self._keys[slot] = key
            self._n += 1
        self._deadline[slot] = deadline

    def drop(self, key: str) -> None:
        slot = self._slot.pop(key, None)
        if slot is not None:
            self._deadline[slot] = np.inf
            self._keys[slot] = None
            self._free.append(slot)
            self._n -= 1

    def expired(self, now: float) -> List[str]:
        if not self._n:
            return []
        hits = np.nonzero(self._deadline <= now)[0]
        out = []
        for slot in hits:
            key = self._keys[slot]
            if key is not None:
                out.append(key)
        for key in out:
            self.drop(key)
        return out


class _Bucket:
    """One shard: a plain dict of key -> value plus a lazily created
    lease ledger for the (rare) leased non-heartbeat keys."""

    __slots__ = ("data", "leases")

    def __init__(self):
        self.data: Dict[str, Any] = {}
        self.leases: Optional[_LeaseLedger] = None

    def ledger(self) -> _LeaseLedger:
        if self.leases is None:
            self.leases = _LeaseLedger()
        return self.leases


class KVStore:
    """Sharded status monitor (see module docstring for the layout)."""

    def __init__(self):
        # namespace -> {group-or-None -> _Bucket}
        self._shards: Dict[str, Dict[Optional[int], _Bucket]] = {
            ns: {} for ns, _ in _NAMESPACES}
        self._shards[""] = {}
        self._heartbeats = HeartbeatTable(group_size=NODE_GROUP_SIZE)
        # family -> (compacted base index, live tail of appended keys)
        self._qbase: Dict[str, int] = {f: 0 for f in QUEUE_FAMILIES}
        self._qlog: Dict[str, List[str]] = {f: [] for f in QUEUE_FAMILIES}
        self._watches: List[Tuple[str, Callable[[str, str, Any], None]]] = []

    # ---- routing -----------------------------------------------------------

    @staticmethod
    def _route(key: str) -> Tuple[str, Optional[int]]:
        """(namespace, node-group) for a key; ("", None) = catch-all."""
        for ns, grouped in _NAMESPACES:
            if key.startswith(ns):
                if grouped:
                    seg = key[len(ns):]
                    cut = seg.find("/")
                    if cut >= 0:
                        seg = seg[:cut]
                    if seg.isdigit():
                        return ns, int(seg) // NODE_GROUP_SIZE
                return ns, None
        return "", None

    def _bucket(self, ns: str, group: Optional[int]) -> _Bucket:
        shards = self._shards[ns]
        b = shards.get(group)
        if b is None:
            b = shards[group] = _Bucket()
        return b

    # ---- basic ops ---------------------------------------------------------

    def put(self, key: str, value: Any, *, ttl: Optional[float] = None,
            now: float = 0.0) -> None:
        node = _hb_node(key)
        if node is not None:
            self._heartbeats.beat(node, value,
                                  now + ttl if ttl else np.inf)
            self._notify("put", key, value)
            return
        ns, group = self._route(key)
        b = self._bucket(ns, group)
        b.data[key] = value
        if ttl:
            b.ledger().set(key, now + ttl)
        elif b.leases is not None:
            b.leases.drop(key)
        if ns in self._qbase:
            self._qlog[ns].append(key)
        self._notify("put", key, value)

    def get(self, key: str, default: Any = None) -> Any:
        node = _hb_node(key)
        if node is not None:
            return self._heartbeats.get(node, default)
        ns, group = self._route(key)
        b = self._shards[ns].get(group)
        if b is None:
            return default
        return b.data.get(key, default)

    def delete(self, key: str) -> None:
        node = _hb_node(key)
        if node is not None:
            if self._heartbeats.pop(node):
                self._notify("delete", key, None)
            return
        ns, group = self._route(key)
        b = self._shards[ns].get(group)
        if b is not None and key in b.data:
            del b.data[key]
            if b.leases is not None:
                b.leases.drop(key)
            self._notify("delete", key, None)

    def prefix(self, pre: str) -> Dict[str, Any]:
        """All key -> value pairs under ``pre`` — O(matching keys): only
        shard buckets whose namespace can intersect the prefix are
        visited, and a namespace fully inside the prefix is merged
        without per-key filtering."""
        out: Dict[str, Any] = {}
        for ns, shards in self._shards.items():
            if ns and ns.startswith(pre):
                # whole namespace matches: bulk-merge its buckets
                for b in shards.values():
                    out.update(b.data)
                continue
            if ns and not pre.startswith(ns):
                continue
            if ns == "" and pre:
                # catch-all: must filter (cheap — hot families are
                # registered namespaces, the catch-all stays small)
                for b in shards.values():
                    for k, v in b.data.items():
                        if k.startswith(pre):
                            out[k] = v
                continue
            # pre lies inside this namespace: narrow to one group bucket
            # when the next segment is a complete node id
            buckets: Iterable[_Bucket] = shards.values()
            if ns:
                seg = pre[len(ns):]
                cut = seg.find("/")
                if cut >= 0 and seg[:cut].isdigit():
                    b = shards.get(int(seg[:cut]) // NODE_GROUP_SIZE)
                    buckets = (b,) if b is not None else ()
            for b in buckets:
                for k, v in b.data.items():
                    if k.startswith(pre):
                        out[k] = v
        if _HB_PRE.startswith(pre) or pre.startswith(_HB_PRE):
            for node, value in self._heartbeats.items():
                k = f"{_HB_PRE}{node}{_HB_SUF}"
                if k.startswith(pre):
                    out[k] = value
        return out

    def cas(self, key: str, expect: Any, value: Any) -> bool:
        """Compare-and-swap the *value* only: a successful swap on a
        leased key (e.g. a heartbeat) keeps its existing lease instead of
        silently clearing the expiry."""
        node = _hb_node(key)
        if node is not None:
            if self._heartbeats.cas(node, expect, value):
                self._notify("put", key, value)
                return True
            return False
        ns, group = self._route(key)
        b = self._bucket(ns, group)
        if b.data.get(key) == expect:
            b.data[key] = value
            if ns in self._qbase:
                self._qlog[ns].append(key)
            self._notify("put", key, value)
            return True
        return False

    # ---- leases (heartbeats) -----------------------------------------------

    def heartbeat_batch(self, node_ids, now: float,
                        ttl: Optional[float] = None) -> None:
        """Ingest a whole agent cohort's heartbeats as one array write:
        equivalent to ``put(f"/nodes/<id>/alive", now, ttl=ttl, now=now)``
        per id, minus the per-key Python overhead."""
        deadline = now + ttl if ttl else np.inf
        self._heartbeats.beat_batch(node_ids, now, deadline)
        if self._watches:
            for node in node_ids:
                self._notify("put", f"{_HB_PRE}{int(node)}{_HB_SUF}", now)

    def expire(self, now: float) -> List[str]:
        """Drop entries whose lease lapsed; returns the expired keys in
        sorted order.  Heartbeats expire through one vectorized
        comparison per node-group array; ordinary leased keys through
        each bucket's ledger.  The coordinator treats an expired
        /nodes/<id>/alive key as a lost connection -> SEV1 (Table 1)."""
        dead = [f"{_HB_PRE}{node}{_HB_SUF}"
                for node in self._heartbeats.expired(now)]
        for shards in self._shards.values():
            for b in shards.values():
                if b.leases is None or not len(b.leases):
                    continue
                for key in b.leases.expired(now):
                    b.data.pop(key, None)
                    dead.append(key)
        dead.sort()
        for k in dead:
            self._notify("expire", k, None)
        return dead

    # ---- event queues (drain families) -------------------------------------

    def queue_len(self, family: str) -> int:
        """Total appends ever made to a family queue (monotonic)."""
        return self._qbase[family] + len(self._qlog[family])

    def queue_slice(self, family: str, start: int) -> List[str]:
        """Appended keys from absolute index ``start`` onward.  Entries
        below ``start`` are compacted away (the caller's persisted
        cursor guarantees it will never ask for them again)."""
        base = self._qbase[family]
        if start > base:
            del self._qlog[family][:start - base]
            self._qbase[family] = base = start
        return self._qlog[family][start - base:]

    # ---- watches -----------------------------------------------------------

    def watch(self, pre: str, cb: Callable[[str, str, Any], None]) -> None:
        self._watches.append((pre, cb))

    def _notify(self, op: str, key: str, value: Any) -> None:
        for pre, cb in self._watches:
            if key.startswith(pre):
                cb(op, key, value)


# ---------------------------------------------------------------------------
# Legacy flat-dict store (equivalence baseline)
# ---------------------------------------------------------------------------


@dataclass
class _Entry:
    value: Any
    lease_expires: Optional[float] = None       # absolute sim time


class LegacyKVStore:
    """The original O(store)-scan implementation: one flat dict, every
    ``prefix()`` a full scan, every lease a Python object.  Kept as the
    behavioural baseline — the control loop falls back to scan-based
    drains on stores without queues, and the equivalence suite replays
    identical traces through both stores to prove the sharded path
    changes no observable semantics (``bench_controlplane`` measures
    what that costs at fleet scale)."""

    def __init__(self):
        self._data: Dict[str, _Entry] = {}
        self._watches: List[Tuple[str, Callable[[str, str, Any], None]]] = []

    # ---- basic ops ---------------------------------------------------------

    def put(self, key: str, value: Any, *, ttl: Optional[float] = None,
            now: float = 0.0) -> None:
        self._data[key] = _Entry(value, now + ttl if ttl else None)
        self._notify("put", key, value)

    def get(self, key: str, default: Any = None) -> Any:
        e = self._data.get(key)
        return default if e is None else e.value

    def delete(self, key: str) -> None:
        if key in self._data:
            del self._data[key]
            self._notify("delete", key, None)

    def prefix(self, pre: str) -> Dict[str, Any]:
        return {k: e.value for k, e in self._data.items()
                if k.startswith(pre)}

    def cas(self, key: str, expect: Any, value: Any) -> bool:
        """Compare-and-swap the *value* only: a successful swap on a
        leased key (e.g. a heartbeat) keeps its existing lease instead of
        silently clearing the expiry."""
        e = self._data.get(key)
        if (e.value if e is not None else None) == expect:
            self._data[key] = _Entry(value,
                                     e.lease_expires if e else None)
            self._notify("put", key, value)
            return True
        return False

    # ---- leases (heartbeats) -----------------------------------------------

    def expire(self, now: float) -> List[str]:
        """Drop entries whose lease lapsed; returns the expired keys in
        sorted order (matching the sharded store, whose shard iteration
        order is not insertion order)."""
        dead = sorted(k for k, e in self._data.items()
                      if e.lease_expires is not None
                      and e.lease_expires <= now)
        for k in dead:
            del self._data[k]
            self._notify("expire", k, None)
        return dead

    # ---- watches -----------------------------------------------------------

    def watch(self, pre: str, cb: Callable[[str, str, Any], None]) -> None:
        self._watches.append((pre, cb))

    def _notify(self, op: str, key: str, value: Any) -> None:
        for pre, cb in self._watches:
            if key.startswith(pre):
                cb(op, key, value)
