"""etcd-like distributed KV store (the coordinator's *status monitor*).

Single-process stand-in for etcd [11]: prefix watches, leases with TTL
(expiry driven by the simulator clock), and compare-and-swap.  The
coordinator consolidates agent-reported process statuses here (§3.2).

Delivery-semantics contract (shared with ``agent.py``/``controlloop.py``,
exercised by ``core.chaos``):

* **At-least-once publish.**  An agent ``put`` may be dropped, delayed,
  duplicated, or rejected during a partition (``KVUnavailable``) by a
  chaotic transport (``chaos.ChaosKVStore``).  Producers therefore keep
  every report in a local outbox and re-publish with seeded exponential
  backoff until the consumer acknowledges it; a record may consequently
  be delivered more than once, and may re-appear *after* it was deleted.
* **Idempotent consume.**  The control loop deletes a record on consume
  (bounding KV residency) and writes a processed marker under
  ``CONSUMED_PREFIX + key`` whose value is the consume time.  The marker
  doubles as the producer-visible acknowledgement; a re-delivered record
  whose marker exists is deleted without re-firing.  Markers are
  garbage-collected after a retention window that must exceed the
  transport's maximum delay + partition span (``chaos.ChaosSchedule``
  generators guarantee this for generated schedules).
* **Epoch fencing.**  The coordinator journals its state under
  ``/coord/journal/*`` and claims an incarnation epoch; writes from a
  deposed incarnation raise (``coordinator.StaleCoordinatorError``), so
  a crashed-and-recovered coordinator can never be shadowed by its
  predecessor.

This base class is the *perfect* store (no loss, no delay); the chaos
wrapper injects the failure modes while preserving this interface.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

# Well-known status-monitor keys shared by coordinator, control loop and
# agents.  PLAN_EPOCH_KEY holds the coordinator's task-set epoch: bumped
# whenever the entry list mutates (finish/launch), so positional task
# indices in agent churn reports can be checked for freshness.
PLAN_EPOCH_KEY = "/plan/epoch"

# Processed-marker namespace: the control loop acknowledges a consumed
# record by writing ``CONSUMED_PREFIX + key`` = consume time (and deletes
# the record itself).  Agents poll the marker to retire outbox entries.
CONSUMED_PREFIX = "/consumed"


class KVUnavailable(Exception):
    """The store is unreachable from this client (network partition).

    Raised only by chaotic transports (``chaos.ChaosKVStore`` node
    clients); the base in-process store never raises it.  Producers
    treat it as a queue-locally signal and flush on heal."""


@dataclass
class _Entry:
    value: Any
    lease_expires: Optional[float] = None       # absolute sim time


class KVStore:
    def __init__(self):
        self._data: Dict[str, _Entry] = {}
        self._watches: List[Tuple[str, Callable[[str, str, Any], None]]] = []

    # ---- basic ops ---------------------------------------------------------

    def put(self, key: str, value: Any, *, ttl: Optional[float] = None,
            now: float = 0.0) -> None:
        self._data[key] = _Entry(value, now + ttl if ttl else None)
        self._notify("put", key, value)

    def get(self, key: str, default: Any = None) -> Any:
        e = self._data.get(key)
        return default if e is None else e.value

    def delete(self, key: str) -> None:
        if key in self._data:
            del self._data[key]
            self._notify("delete", key, None)

    def prefix(self, pre: str) -> Dict[str, Any]:
        return {k: e.value for k, e in self._data.items()
                if k.startswith(pre)}

    def cas(self, key: str, expect: Any, value: Any) -> bool:
        """Compare-and-swap the *value* only: a successful swap on a
        leased key (e.g. a heartbeat) keeps its existing lease instead of
        silently clearing the expiry."""
        e = self._data.get(key)
        if (e.value if e is not None else None) == expect:
            self._data[key] = _Entry(value,
                                     e.lease_expires if e else None)
            self._notify("put", key, value)
            return True
        return False

    # ---- leases (heartbeats) -----------------------------------------------

    def expire(self, now: float) -> List[str]:
        """Drop entries whose lease lapsed; returns the expired keys.
        The coordinator treats an expired /nodes/<id>/alive key as a lost
        connection -> SEV1 (Table 1)."""
        dead = [k for k, e in self._data.items()
                if e.lease_expires is not None and e.lease_expires <= now]
        for k in dead:
            del self._data[k]
            self._notify("expire", k, None)
        return dead

    # ---- watches -----------------------------------------------------------

    def watch(self, pre: str, cb: Callable[[str, str, Any], None]) -> None:
        self._watches.append((pre, cb))

    def _notify(self, op: str, key: str, value: Any) -> None:
        for pre, cb in self._watches:
            if key.startswith(pre):
                cb(op, key, value)
