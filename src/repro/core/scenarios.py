"""Failure-scenario library — seeded, parameterized cluster traces.

Each generator returns a :class:`ClusterScenario` whose events all flow
through the real detection -> severity -> planner -> transition path in
``core.simulator``.  Mapping to the paper and the related fleet studies
(PAPERS.md):

``independent_failures``
    Per-node Poisson faults with the §2.2 severity mix (73% transient) —
    the generalization of the §7.5 trace-a/trace-b workloads behind
    Fig. 11, scaled to arbitrary (nodes, span, MTBF).
``correlated_failures``
    Switch/rack-domain bursts: every failure in a burst lands inside one
    node group and the group returns together, the dominant correlated
    mode in ByteDance's robust-training report and Meta's reliability
    characterization.
``slow_nodes``
    Slow-node degradation feeding the §4.1 online statistical monitor
    (Fig. 6): a sub-3x slowdown is invisible to baseline watchdogs but
    trips Unicron's 1.1x degradation margin.
``preemption_waves``
    Spot/preemption waves: a fraction of nodes is reclaimed at once and
    re-provisioned later — beyond the paper, standard in spot fleets.
``task_churn``
    Multi-task join/finish churn, the Figure 7 reconfiguration triggers
    (5) task finished and (6) task launched at cluster scale (§5.2).
``diurnal_load`` / ``traffic_spikes``
    Request-rate traces for serving tasks (``waf.ServingSLO``): a
    sinusoidal day/night cycle sampled as piecewise-constant steps, and
    short multiplicative traffic spikes.  Each step is a
    :class:`RateChangeEvent` that swaps the slot's objective (rate only;
    workers are untouched), so the planner's next failure replan trades
    training WAF against the *current* serving goodput.
``mixed_fleet``
    All of the above superimposed — the §7.5-style multi-task sweep at
    (n=1024, m=32) that ``benchmarks/bench_cluster_sim.py`` reproduces.
``calibrated_failures`` / ``calibrated_slow_nodes`` /
``calibrated_bursts`` / ``calibrated_preemption`` / ``calibrated_fleet``
    The trace-calibrated family: rates and category mixes come from the
    committed :mod:`repro.core.calibration` tables instead of free
    parameters.  Per-category event rates (NVLink / ECC / NIC-class
    hardware, software crashes, transient network, hangs), SEV1 repair
    ranges, slow-node and correlated-burst rates, and the 1/n
    MTTF-vs-fleet-size scaling are pinned to the Acme datacenter
    characterization (arXiv 2403.07648) and Meta's reliability study
    (arXiv 2410.21680) — see ``calibration.py`` for the provenance of
    every number.  ``tests/test_calibration.py`` statistically asserts
    the generated streams match the tables (Poisson counts, category
    shares, exponential inter-arrival KS, MTTF scaling), and
    ``benchmarks/bench_frontier.py`` drives the recovery-policy
    cost/WAF frontier over ``calibrated_fleet`` traces.
``chaos_schedule`` / ``chaos_suite``
    Control-plane fault schedules (``core.chaos.ChaosSchedule``): message
    drop / delayed visibility / duplication, per-node partition windows,
    and scheduled coordinator crashes — the transport- and
    coordinator-level faults the ByteDance and Meta fleet reports put
    above hardware faults in operational pain.  Partition windows are
    placed sequentially with heal slack and away from caller-supplied
    ``avoid`` windows (``chaos.world_windows``), which is what makes the
    chaos convergence property (``tests/test_chaos.py``) decidable.

Generators draw from ``numpy.random.default_rng(seed)`` only: identical
seeds produce identical scenarios, and batches of Monte-Carlo seeds are
vectorized draws, not per-event Python loops.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.calibration import DEFAULT_CALIBRATION, FleetCalibration
from repro.core.chaos import ChaosSchedule
from repro.core.detection import ErrorKind
from repro.core.traces import (DAY, NON_SEV1_KINDS, SEV1_KINDS, FailureEvent,
                               poisson_times, sample_kinds)
from repro.core.waf import Objective, ServingSLO, Task


@dataclass(frozen=True)
class DegradationEvent:
    """A node turns slow (not dead): iteration time inflates by
    ``slowdown`` for ``duration_s`` seconds (§4.1 / Fig. 6)."""
    time: float
    node: int
    slowdown: float            # iteration-time multiplier, >= 1
    duration_s: float


@dataclass(frozen=True)
class TaskArrival:
    """A new task is admitted to the cluster (Figure 7 trigger 6)."""
    time: float
    task: Task
    workers_hint: int = 0      # baseline policies grant min(hint, free)
    avg_iter_s: float = 30.0   # steady-state iteration time hint


@dataclass(frozen=True)
class TaskFinish:
    """Task in simulator slot ``slot`` completes (Figure 7 trigger 5)."""
    time: float
    slot: int


@dataclass(frozen=True)
class RateChangeEvent:
    """The offered load of the task in simulator slot ``slot`` changes:
    the slot's task swaps to an identical task carrying ``objective``
    (typically a :class:`~repro.core.waf.ServingSLO` at a new
    ``rate_rps``).  Reward-only — no workers move, no transition cost is
    paid, and no replan is triggered; the updated reward rows simply
    shape the planner's *next* reconfiguration."""
    time: float
    slot: int
    objective: Objective


@dataclass(frozen=True)
class NodeGroups:
    """Failure domains (switch/rack): ``groups[g]`` lists node ids that
    share fate under a correlated failure."""
    groups: Tuple[Tuple[int, ...], ...]

    @classmethod
    def contiguous(cls, n_nodes: int, group_size: int) -> "NodeGroups":
        return cls(tuple(
            tuple(range(lo, min(lo + group_size, n_nodes)))
            for lo in range(0, n_nodes, group_size)))

    def group_of(self, node: int) -> int:
        for gi, g in enumerate(self.groups):
            if node in g:
                return gi
        raise ValueError(f"node {node} not in any group")


@dataclass
class ClusterScenario:
    """One seeded cluster trace: failures + degradations + task churn."""
    name: str
    n_nodes: int
    gpus_per_node: int
    span_s: float
    failures: List[FailureEvent] = field(default_factory=list)
    degradations: List[DegradationEvent] = field(default_factory=list)
    churn: List[object] = field(default_factory=list)   # TaskArrival/Finish
    groups: Optional[NodeGroups] = None
    seed: Optional[int] = None

    def merged(self, other: "ClusterScenario",
               name: Optional[str] = None) -> "ClusterScenario":
        assert (self.n_nodes, self.gpus_per_node) == \
            (other.n_nodes, other.gpus_per_node)
        return ClusterScenario(
            name=name or f"{self.name}+{other.name}",
            n_nodes=self.n_nodes, gpus_per_node=self.gpus_per_node,
            span_s=max(self.span_s, other.span_s),
            failures=sorted(self.failures + other.failures,
                            key=lambda e: e.time),
            degradations=sorted(self.degradations + other.degradations,
                                key=lambda e: e.time),
            churn=sorted(self.churn + other.churn, key=lambda e: e.time),
            groups=self.groups or other.groups, seed=self.seed)

    @property
    def n_events(self) -> int:
        return (len(self.failures) + len(self.degradations)
                + len(self.churn))


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------


def independent_failures(*, n_nodes: int, span_s: float, seed: int,
                         gpus_per_node: int = 8,
                         mtbf_node_s: float = 60 * DAY,
                         sev1_fraction: float = 0.27,
                         repair_s: Tuple[float, float] = (2 * 3600.0,
                                                          12 * 3600.0)
                         ) -> ClusterScenario:
    """Per-node Poisson faults, §2.2 mix (default 27% SEV1 node loss)."""
    rng = np.random.default_rng(seed)
    times = poisson_times(rng, n_nodes / mtbf_node_s, span_s)
    n = times.size
    nodes = rng.integers(0, n_nodes, size=n)
    is_sev1 = rng.random(n) < sev1_fraction
    sev1_kinds = sample_kinds(rng, SEV1_KINDS, int(is_sev1.sum()))
    other_kinds = sample_kinds(rng, NON_SEV1_KINDS, int(n - is_sev1.sum()))
    repairs = rng.uniform(repair_s[0], repair_s[1], size=n)
    events, i1, i2 = [], 0, 0
    for i in range(n):
        if is_sev1[i]:
            kind, rep = sev1_kinds[i1], float(repairs[i])
            i1 += 1
        else:
            kind, rep = other_kinds[i2], None
            i2 += 1
        events.append(FailureEvent(time=float(times[i]),
                                   node=int(nodes[i]), kind=kind,
                                   repair_s=rep))
    return ClusterScenario("independent", n_nodes, gpus_per_node, span_s,
                           failures=events, seed=seed)


def correlated_failures(*, n_nodes: int, span_s: float, seed: int,
                        gpus_per_node: int = 8, group_size: int = 8,
                        n_bursts: int = 4, burst_span_s: float = 120.0,
                        hit_fraction: float = 0.75,
                        outage_s: Tuple[float, float] = (1800.0, 4 * 3600.0)
                        ) -> ClusterScenario:
    """Switch-domain bursts: each burst drops ``hit_fraction`` of one node
    group within ``burst_span_s`` and the whole group returns together."""
    rng = np.random.default_rng(seed)
    groups = NodeGroups.contiguous(n_nodes, group_size)
    onsets = np.sort(rng.uniform(0, span_s, size=n_bursts))
    events: List[FailureEvent] = []
    for onset in onsets:
        gi = int(rng.integers(0, len(groups.groups)))
        outage = float(rng.uniform(*outage_s))
        members = np.array(groups.groups[gi])
        hit = members[rng.random(members.size) < hit_fraction]
        offsets = rng.uniform(0, burst_span_s, size=hit.size)
        for node, off in zip(hit, offsets):
            t = float(onset + off)
            events.append(FailureEvent(
                time=t, node=int(node), kind=ErrorKind.LOST_CONNECTION,
                repair_s=max(float(onset) + outage - t, 60.0)))
    events.sort(key=lambda e: e.time)
    return ClusterScenario("correlated", n_nodes, gpus_per_node, span_s,
                           failures=events, groups=groups, seed=seed)


def slow_nodes(*, n_nodes: int, span_s: float, seed: int,
               gpus_per_node: int = 8, n_events: int = 8,
               slowdown: Tuple[float, float] = (1.15, 2.5),
               duration_s: Tuple[float, float] = (3600.0, 8 * 3600.0)
               ) -> ClusterScenario:
    """Slow-node degradation for the §4.1 statistical monitor: slowdowns
    default to >= 1.15x so every event clears the 1.1x margin (Fig. 6)
    while staying below the 3x failure threshold."""
    rng = np.random.default_rng(seed)
    times = np.sort(rng.uniform(0, span_s, size=n_events))
    nodes = rng.integers(0, n_nodes, size=n_events)
    slows = rng.uniform(slowdown[0], slowdown[1], size=n_events)
    durs = rng.uniform(duration_s[0], duration_s[1], size=n_events)
    events = [DegradationEvent(time=float(t), node=int(nd),
                               slowdown=float(s), duration_s=float(d))
              for t, nd, s, d in zip(times, nodes, slows, durs)]
    return ClusterScenario("slow_nodes", n_nodes, gpus_per_node, span_s,
                           degradations=events, seed=seed)


def preemption_waves(*, n_nodes: int, span_s: float, seed: int,
                     gpus_per_node: int = 8, n_waves: int = 3,
                     wave_fraction: float = 0.2,
                     reprovision_s: Tuple[float, float] = (1800.0, 7200.0)
                     ) -> ClusterScenario:
    """Spot-preemption waves: ``wave_fraction`` of the fleet is reclaimed
    near-simultaneously and re-provisioned after a delay."""
    rng = np.random.default_rng(seed)
    onsets = np.sort(rng.uniform(0, span_s, size=n_waves))
    events: List[FailureEvent] = []
    for onset in onsets:
        k = max(1, int(round(wave_fraction * n_nodes)))
        nodes = rng.choice(n_nodes, size=k, replace=False)
        reprov = rng.uniform(reprovision_s[0], reprovision_s[1], size=k)
        offsets = rng.uniform(0, 30.0, size=k)     # reclaim skew
        for node, off, rep in zip(nodes, offsets, reprov):
            events.append(FailureEvent(
                time=float(onset + off), node=int(node),
                kind=ErrorKind.LOST_CONNECTION, repair_s=float(rep)))
    events.sort(key=lambda e: e.time)
    return ClusterScenario("preemption", n_nodes, gpus_per_node, span_s,
                           failures=events, seed=seed)


def task_churn(*, span_s: float, seed: int, n_nodes: int,
               gpus_per_node: int = 8, m_initial: int,
               candidates: Sequence[Task], n_arrivals: int = 2,
               n_finishes: int = 2, workers_hint: int = 32
               ) -> ClusterScenario:
    """Join/finish churn (Figure 7 triggers 5 and 6): ``n_finishes``
    distinct initial slots complete, ``n_arrivals`` tasks from the
    candidate catalog are admitted.  Cap-aware: an arriving task with a
    ``max_workers`` ceiling never hints for more than its cap (the
    planner's banded reward rows make the excess worthless anyway)."""
    rng = np.random.default_rng(seed)
    n_finishes = min(n_finishes, m_initial)
    churn: List[object] = []
    slots = rng.choice(m_initial, size=n_finishes, replace=False)
    for slot, t in zip(slots, rng.uniform(0.2 * span_s, 0.9 * span_s,
                                          size=n_finishes)):
        churn.append(TaskFinish(time=float(t), slot=int(slot)))
    picks = rng.integers(0, len(candidates), size=n_arrivals)
    for pick, t in zip(picks, rng.uniform(0.1 * span_s, 0.8 * span_s,
                                          size=n_arrivals)):
        cand = candidates[int(pick)]
        hint = workers_hint
        if cand.max_workers is not None:
            hint = min(hint, cand.max_workers)
        churn.append(TaskArrival(time=float(t), task=cand,
                                 workers_hint=hint))
    churn.sort(key=lambda e: e.time)
    return ClusterScenario("churn", n_nodes, gpus_per_node, span_s,
                           churn=churn, seed=seed)


def diurnal_load(*, n_nodes: int, span_s: float, seed: int, slot: int,
                 base: ServingSLO, gpus_per_node: int = 8,
                 amplitude: float = 0.5, period_s: float = DAY,
                 step_s: float = 3600.0, jitter: float = 0.05
                 ) -> ClusterScenario:
    """Diurnal request-rate trace for one serving slot: a day/night sine
    around ``base.rate_rps`` (peak-to-trough set by ``amplitude``),
    sampled as piecewise-constant ``step_s`` steps with seeded
    multiplicative jitter.  Each step is a reward-only
    :class:`RateChangeEvent`."""
    rng = np.random.default_rng(seed)
    times = np.arange(step_s, span_s, step_s)
    phase = float(rng.uniform(0.0, period_s))
    level = 1.0 + amplitude * np.sin(2.0 * np.pi * (times + phase)
                                     / period_s)
    noise = np.clip(rng.normal(1.0, jitter, size=times.size), 0.1, None)
    rates = np.maximum(base.rate_rps * level * noise, 1e-3)
    churn: List[object] = [
        RateChangeEvent(time=float(t), slot=slot,
                        objective=base.with_rate(float(r)))
        for t, r in zip(times, rates)]
    return ClusterScenario("diurnal", n_nodes, gpus_per_node, span_s,
                           churn=churn, seed=seed)


def traffic_spikes(*, n_nodes: int, span_s: float, seed: int, slot: int,
                   base: ServingSLO, gpus_per_node: int = 8,
                   n_spikes: int = 3, spike_factor: float = 4.0,
                   spike_s: float = 1800.0) -> ClusterScenario:
    """Short traffic spikes for one serving slot: ``n_spikes`` disjoint
    windows of ``spike_s`` seconds at ``spike_factor`` times the base
    rate; each window's trailing edge restores ``base`` exactly."""
    rng = np.random.default_rng(seed)
    starts = np.sort(rng.uniform(0.05 * span_s, 0.85 * span_s,
                                 size=n_spikes))
    churn: List[object] = []
    prev_end = -np.inf
    for onset in starts:
        t0 = max(float(onset), prev_end + 60.0)
        t1 = min(t0 + spike_s, span_s - 1.0)
        if t1 <= t0:
            continue
        churn.append(RateChangeEvent(
            time=t0, slot=slot,
            objective=base.with_rate(base.rate_rps * spike_factor)))
        churn.append(RateChangeEvent(time=t1, slot=slot, objective=base))
        prev_end = t1
    return ClusterScenario("spikes", n_nodes, gpus_per_node, span_s,
                           churn=churn, seed=seed)


def mixed_fleet(*, n_nodes: int, span_s: float, seed: int,
                gpus_per_node: int = 8, m_initial: int = 0,
                candidates: Sequence[Task] = (),
                mtbf_node_s: float = 60 * DAY, group_size: int = 8,
                n_bursts: int = 2, n_degradations: int = 6,
                n_waves: int = 2, wave_fraction: float = 0.2,
                n_arrivals: int = 2, n_finishes: int = 2
                ) -> ClusterScenario:
    """Everything at once — the cluster-scale workload of
    ``benchmarks/bench_cluster_sim.py`` (§7.5 at n=1024, m=32)."""
    base = independent_failures(
        n_nodes=n_nodes, span_s=span_s, seed=seed * 10 + 1,
        gpus_per_node=gpus_per_node, mtbf_node_s=mtbf_node_s)
    out = base.merged(correlated_failures(
        n_nodes=n_nodes, span_s=span_s, seed=seed * 10 + 2,
        gpus_per_node=gpus_per_node, group_size=group_size,
        n_bursts=n_bursts))
    out = out.merged(slow_nodes(
        n_nodes=n_nodes, span_s=span_s, seed=seed * 10 + 3,
        gpus_per_node=gpus_per_node, n_events=n_degradations))
    out = out.merged(preemption_waves(
        n_nodes=n_nodes, span_s=span_s, seed=seed * 10 + 4,
        gpus_per_node=gpus_per_node, n_waves=n_waves,
        wave_fraction=wave_fraction))
    if m_initial and len(candidates) and (n_arrivals or n_finishes):
        out = out.merged(task_churn(
            span_s=span_s, seed=seed * 10 + 5, n_nodes=n_nodes,
            gpus_per_node=gpus_per_node, m_initial=m_initial,
            candidates=candidates, n_arrivals=n_arrivals,
            n_finishes=n_finishes))
    out.name, out.seed = "mixed_fleet", seed
    return out


def scenario_suite(*, n_nodes: int, span_s: float, seed: int,
                   gpus_per_node: int = 8, m_initial: int = 0,
                   candidates: Sequence[Task] = ()) -> dict:
    """One representative scenario per class, all on the same cluster
    shape — the sweep ``bench_cluster_sim`` and the tests iterate."""
    return {
        "independent": independent_failures(
            n_nodes=n_nodes, span_s=span_s, seed=seed,
            gpus_per_node=gpus_per_node),
        "correlated": correlated_failures(
            n_nodes=n_nodes, span_s=span_s, seed=seed,
            gpus_per_node=gpus_per_node),
        "slow_nodes": slow_nodes(
            n_nodes=n_nodes, span_s=span_s, seed=seed,
            gpus_per_node=gpus_per_node),
        "preemption": preemption_waves(
            n_nodes=n_nodes, span_s=span_s, seed=seed,
            gpus_per_node=gpus_per_node),
        "mixed_fleet": mixed_fleet(
            n_nodes=n_nodes, span_s=span_s, seed=seed,
            gpus_per_node=gpus_per_node, m_initial=m_initial,
            candidates=candidates),
    }


# ---- trace-calibrated family (core.calibration tables) --------------------


def calibrated_failures(*, n_nodes: int, span_s: float, seed: int,
                        gpus_per_node: int = 8,
                        calib: FleetCalibration = DEFAULT_CALIBRATION
                        ) -> ClusterScenario:
    """Per-category Poisson faults at the committed calibrated rates.

    The fleet event rate is ``calib.failure_rate_s(n_nodes)`` (per-node
    MTBF superposed, so fleet MTTF scales as 1/n), each event's category
    is drawn by the committed shares, its kind uniformly within the
    category, and SEV1 categories carry a repair time from their
    calibrated range (non-SEV1 events release the node immediately)."""
    rng = np.random.default_rng(seed)
    times = poisson_times(rng, calib.failure_rate_s(n_nodes), span_s)
    n = times.size
    nodes = rng.integers(0, n_nodes, size=n)
    cats = calib.categories
    shares = np.array([c.share for c in cats])
    cat_idx = rng.choice(len(cats), size=n, p=shares / shares.sum())
    events: List[FailureEvent] = []
    for i in range(n):
        cat = cats[int(cat_idx[i])]
        kind = cat.kinds[int(rng.integers(0, len(cat.kinds)))]
        rep = None
        if cat.repair_range_s is not None:
            rep = float(rng.uniform(*cat.repair_range_s))
        events.append(FailureEvent(time=float(times[i]),
                                   node=int(nodes[i]), kind=kind,
                                   repair_s=rep))
    return ClusterScenario("calibrated_failures", n_nodes, gpus_per_node,
                           span_s, failures=events, seed=seed)


def calibrated_slow_nodes(*, n_nodes: int, span_s: float, seed: int,
                          gpus_per_node: int = 8,
                          calib: FleetCalibration = DEFAULT_CALIBRATION
                          ) -> ClusterScenario:
    """Slow-node degradations at the calibrated per-node straggler rate;
    slowdowns sit between the 1.1x margin and the 3x threshold."""
    rng = np.random.default_rng(seed)
    times = poisson_times(rng, n_nodes * calib.slow_rate_per_node_s,
                          span_s)
    n = times.size
    nodes = rng.integers(0, n_nodes, size=n)
    slows = rng.uniform(*calib.slow_slowdown_range, size=n)
    durs = rng.uniform(*calib.slow_duration_range_s, size=n)
    events = [DegradationEvent(time=float(t), node=int(nd),
                               slowdown=float(s), duration_s=float(d))
              for t, nd, s, d in zip(times, nodes, slows, durs)]
    return ClusterScenario("calibrated_slow", n_nodes, gpus_per_node,
                           span_s, degradations=events, seed=seed)


def calibrated_bursts(*, n_nodes: int, span_s: float, seed: int,
                      gpus_per_node: int = 8,
                      calib: FleetCalibration = DEFAULT_CALIBRATION
                      ) -> ClusterScenario:
    """Correlated switch/PSU-domain bursts at the calibrated rate: a
    whole node group loses ``burst_hit_fraction`` of its members within
    two minutes and returns together.  Adjacent nodes failing together
    is precisely the replica-loss case the tier-aware cost model charges
    (the GEMINI ring neighbor is gone too)."""
    rng = np.random.default_rng(seed)
    groups = NodeGroups.contiguous(n_nodes, calib.burst_group_size)
    onsets = poisson_times(rng, n_nodes * calib.burst_rate_per_node_s,
                           span_s)
    events: List[FailureEvent] = []
    for onset in onsets:
        gi = int(rng.integers(0, len(groups.groups)))
        outage = float(rng.uniform(*calib.burst_repair_range_s))
        members = np.array(groups.groups[gi])
        hit = members[rng.random(members.size) < calib.burst_hit_fraction]
        offsets = rng.uniform(0, 120.0, size=hit.size)
        for node, off in zip(hit, offsets):
            t = float(onset + off)
            events.append(FailureEvent(
                time=t, node=int(node), kind=ErrorKind.LOST_CONNECTION,
                repair_s=max(float(onset) + outage - t, 60.0)))
    events.sort(key=lambda e: e.time)
    return ClusterScenario("calibrated_bursts", n_nodes, gpus_per_node,
                           span_s, failures=events, groups=groups,
                           seed=seed)


def calibrated_preemption(*, n_nodes: int, span_s: float, seed: int,
                          gpus_per_node: int = 8,
                          calib: FleetCalibration = DEFAULT_CALIBRATION
                          ) -> ClusterScenario:
    """Scheduler preemption waves at the calibrated fleet-level rate:
    each wave reclaims a calibrated fraction of the fleet at once."""
    rng = np.random.default_rng(seed)
    onsets = poisson_times(rng, calib.preempt_wave_rate_s, span_s)
    events: List[FailureEvent] = []
    for onset in onsets:
        frac = float(rng.uniform(*calib.preempt_fraction_range))
        k = max(1, int(round(frac * n_nodes)))
        nodes = rng.choice(n_nodes, size=k, replace=False)
        reprov = rng.uniform(*calib.preempt_outage_range_s, size=k)
        offsets = rng.uniform(0, 30.0, size=k)     # reclaim skew
        for node, off, rep in zip(nodes, offsets, reprov):
            events.append(FailureEvent(
                time=float(onset + off), node=int(node),
                kind=ErrorKind.LOST_CONNECTION, repair_s=float(rep)))
    events.sort(key=lambda e: e.time)
    return ClusterScenario("calibrated_preemption", n_nodes,
                           gpus_per_node, span_s, failures=events,
                           seed=seed)


def calibrated_fleet(*, n_nodes: int, span_s: float, seed: int,
                     gpus_per_node: int = 8, m_initial: int = 0,
                     candidates: Sequence[Task] = (),
                     n_arrivals: int = 0, n_finishes: int = 0,
                     calib: FleetCalibration = DEFAULT_CALIBRATION,
                     intensity: float = 1.0) -> ClusterScenario:
    """The calibrated 30-day workload: per-category failures, slow
    nodes, correlated bursts and preemption waves superimposed, all at
    the committed rates (``intensity`` scales every rate uniformly for
    stress/quick configurations; shares and ranges are untouched)."""
    if intensity != 1.0:
        calib = calib.scaled(intensity)
    out = calibrated_failures(
        n_nodes=n_nodes, span_s=span_s, seed=seed * 10 + 1,
        gpus_per_node=gpus_per_node, calib=calib)
    out = out.merged(calibrated_slow_nodes(
        n_nodes=n_nodes, span_s=span_s, seed=seed * 10 + 2,
        gpus_per_node=gpus_per_node, calib=calib))
    out = out.merged(calibrated_bursts(
        n_nodes=n_nodes, span_s=span_s, seed=seed * 10 + 3,
        gpus_per_node=gpus_per_node, calib=calib))
    out = out.merged(calibrated_preemption(
        n_nodes=n_nodes, span_s=span_s, seed=seed * 10 + 4,
        gpus_per_node=gpus_per_node, calib=calib))
    if m_initial and len(candidates) and (n_arrivals or n_finishes):
        out = out.merged(task_churn(
            span_s=span_s, seed=seed * 10 + 5, n_nodes=n_nodes,
            gpus_per_node=gpus_per_node, m_initial=m_initial,
            candidates=candidates, n_arrivals=n_arrivals,
            n_finishes=n_finishes))
    out.name, out.seed = "calibrated_fleet", seed
    return out


# ---- control-plane chaos schedules (core.chaos) ---------------------------

def chaos_schedule(*, seed: int, span_s: float, n_nodes: int,
                   drop_p: float = 0.15, delay_p: float = 0.3,
                   max_delay_s: float = 15.0, dup_p: float = 0.15,
                   n_partitions: int = 2,
                   partition_s: Tuple[float, float] = (10.0, 45.0),
                   n_crashes: int = 1,
                   avoid: Sequence[Tuple[float, float]] = ()
                   ) -> ChaosSchedule:
    """One seeded control-plane fault schedule.

    Injection stops at ``end_s = 0.6 * span_s`` so the trace tail is a
    quiescence window.  Partition windows are disjoint and sequential,
    padded with heal slack (max delay + outbox backoff cap) and placed
    outside the caller's ``avoid`` windows (typically
    ``chaos.world_windows(world)``): a partition that swallows a world
    event's delivery would turn a bounded-lag re-delivery into an
    unbounded one and make convergence against the chaos-free run
    undecidable.  Coordinator crashes are uniform over the injection
    span — crash placement needs no exclusion because recovery rebuilds
    identical coordinator state from the journal."""
    rng = np.random.default_rng(seed)
    end_s = 0.6 * span_s
    guard = max_delay_s + 30.0          # heal slack: delay + backoff cap
    parts: List[Tuple[int, float, float]] = []
    cursor = 0.05 * span_s
    for _ in range(n_partitions):
        dur = float(rng.uniform(*partition_s))
        if cursor + dur + guard >= end_s:
            break
        placed = None
        for _ in range(64):
            start = float(rng.uniform(cursor, end_s - dur - guard))
            lo, hi = start - guard, start + dur + guard
            if all(hi < a or lo > b for a, b in avoid):
                placed = start
                break
        if placed is None:
            break
        node = int(rng.integers(0, n_nodes))
        parts.append((node, placed, placed + dur))
        cursor = placed + dur + guard
    crashes = tuple(sorted(
        float(t) for t in rng.uniform(0.1 * span_s, end_s,
                                      size=n_crashes))) if n_crashes else ()
    return ChaosSchedule(seed=seed, drop_p=drop_p, delay_p=delay_p,
                         max_delay_s=max_delay_s, dup_p=dup_p,
                         partitions=tuple(parts), crash_times=crashes,
                         end_s=end_s)


def chaos_suite(*, seed: int, span_s: float, n_nodes: int,
                avoid: Sequence[Tuple[float, float]] = ()) -> dict:
    """One schedule per chaos class on the same cluster shape — the
    sweep ``bench_chaos`` and the soak test iterate: pure message drop,
    delay + duplication (reordering falls out of unequal delays),
    partitions, a lone coordinator crash, and everything at once."""
    base = dict(span_s=span_s, n_nodes=n_nodes, avoid=avoid)
    return {
        "drop": chaos_schedule(seed=seed * 10 + 1, drop_p=0.3,
                               delay_p=0.0, max_delay_s=0.0, dup_p=0.0,
                               n_partitions=0, n_crashes=0, **base),
        "delay_dup": chaos_schedule(seed=seed * 10 + 2, drop_p=0.0,
                                    delay_p=0.5, max_delay_s=20.0,
                                    dup_p=0.3, n_partitions=0,
                                    n_crashes=0, **base),
        "partition": chaos_schedule(seed=seed * 10 + 3, drop_p=0.1,
                                    delay_p=0.2, max_delay_s=10.0,
                                    dup_p=0.1, n_partitions=2,
                                    n_crashes=0, **base),
        "crash": chaos_schedule(seed=seed * 10 + 4, drop_p=0.0,
                                delay_p=0.0, max_delay_s=0.0, dup_p=0.0,
                                n_partitions=0, n_crashes=1, **base),
        "full": chaos_schedule(seed=seed * 10 + 5, **base),
    }
