"""Discrete-event cluster simulator (§7.5) driving the REAL Unicron code.

The simulator replaces wall-clock time and GPUs only: detection latencies
come from ``core.detection``, recovery decisions from the severity
workflow, reconfiguration plans from the real DP planner through
``UnicronCoordinator``, and transition durations from ``core.transition``.
Baselines are recovery *policies* with their published behaviours:

  megatron   restart-from-checkpoint + hot spare; 30-min watchdog
             detection for non-node-loss failures; reconfigures only the
             affected task (down-scales on node loss until repair).
  oobleck    dynamic reconfiguration (no checkpoint reload), pipeline
             templates; lower normal-case efficiency (Fig. 3a).
  bamboo     redundant computation: keeps running through failures but
             pays a constant throughput tax; lowest efficiency.
  varuna     job morphing + checkpoint restart; low efficiency.
  unicron    everything in this repo: in-band detection, lookup-table
             plans over ALL tasks, partial-result reuse.

Inputs are either a plain failure trace (``core.traces``) or a
:class:`~repro.core.scenarios.ClusterScenario`, which adds slow-node
degradation (§4.1 statistical monitor), correlated/preemption failures,
and task join/finish churn (Figure 7 triggers 5/6).

Two integrators share one decision engine:

* ``TraceSimulator`` — the scalar reference loop: per-event Python with
  piecewise-midpoint WAF integration and the eager, uncached coordinator.
* ``VectorSimulator`` — the cluster-scale engine: identical decisions
  (same handlers, plans float-identical via the lazy cached planner), but
  WAF is integrated as one numpy segment product and plan tables are
  chain-cached across rebuilds and Monte-Carlo seeds
  (``planner.PlannerCache``).  ``run_monte_carlo`` batches seeds over a
  shared cache; ``benchmarks/bench_cluster_sim.py`` asserts the >= 50x
  engine speedup and 1e-6 WAF agreement at (n=1024, m=32).

WAF is integrated over the trace (the Fig. 11 y-axis); ``accumulated``
at the end of the run is the Fig. 11b/d number.
"""
from __future__ import annotations

import heapq
import time as _time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core import costmodel, transition, waf as waf_mod
from repro.core.cluster import Cluster
from repro.core.coordinator import UnicronCoordinator
from repro.core.detection import (ErrorKind, OnlineStatMonitor, Severity,
                                  detection_time)
from repro.core.handling import Trigger
from repro.core.planner import PlannerCache
from repro.core.scenarios import (ClusterScenario, DegradationEvent,
                                  TaskArrival, TaskFinish)
from repro.core.traces import FailureEvent, trace_span
from repro.core.waf import Task

# Normal-case training efficiency relative to Megatron (Figure 3a: the
# resilience-first systems run at a fraction of Megatron's throughput).
EFFICIENCY = {
    "unicron": 1.00,        # inherits all Megatron optimizations
    "megatron": 1.00,
    "oobleck": 0.38,
    "bamboo": 0.30,         # includes the redundant-computation tax
    "varuna": 0.29,
}

# Megatron's deployment keeps hot-spare nodes that substitute for failed
# ones (paper §7.3 footnote 1): capacity is preserved while a spare is
# available, at the cost of idling the spare.  Unicron instead re-plans
# and uses every healthy node productively.
HOT_SPARES = {"megatron": 1}

Trace = Union[List[FailureEvent], ClusterScenario]


@dataclass
class SimTask:
    task: Task
    workers: int
    avg_iter_s: float = 30.0
    blocked_until: float = 0.0          # transitioning/restarting until t
    affected_first: bool = False        # baselines: reconfigure priority
    active: bool = True                 # False once the task finished
    # undetected slow-node windows: (start, end, iteration-time multiplier)
    slow: List[Tuple[float, float, float]] = field(default_factory=list)


@dataclass
class SimResult:
    policy: str
    accumulated_waf: float              # integral of WAF dt
    timeline: List[Tuple[float, float]]  # (t, cluster WAF) samples
    n_reconfigs: int
    downtime_s: float                   # total task-seconds blocked
    n_events: int = 0
    n_degraded_drains: int = 0          # slow nodes caught by the monitor


@dataclass
class MonteCarloResult:
    policy: str
    waf_mean: float
    waf_std: float
    per_seed: List[float]
    wall_s: float                       # engine wall-clock for all seeds
    n_reconfigs: int
    downtime_s: float


class TraceSimulator:
    """Scalar reference loop: per-event Python decisions + piecewise
    midpoint WAF integration (the baseline the vectorized engine must
    match to 1e-6 and beat by >= 50x)."""

    def __init__(self, tasks: List[Task], assignment: List[int],
                 policy: str, hw=costmodel.A800, n_nodes: int = 16,
                 gpus_per_node: int = 8, *,
                 plan_cache: Optional[PlannerCache] = None,
                 ablate_detection: bool = False,
                 ablate_transition: bool = False,
                 ablate_replan: bool = False):
        """``ablate_*``: component ablations for the unicron policy —
        swap one Unicron mechanism for its baseline counterpart to
        measure that component's contribution (benchmarks/bench_ablation).
        ``plan_cache``: share a ``PlannerCache`` across runs (lazy plan
        tables, chains reused across rebuilds; plans stay identical)."""
        self.policy = policy
        self.ablate_detection = ablate_detection
        self.ablate_transition = ablate_transition
        self.ablate_replan = ablate_replan
        self.hw = hw
        self.eff = EFFICIENCY[policy]
        # WAF timeline sampling reads F(t, ·) straight off the memoized
        # cost-model curves; one vector per distinct task for the whole run
        self._n_total = n_nodes * gpus_per_node
        self._waf_curves: Dict[Task, object] = {}
        self.cluster = Cluster(n_nodes, gpus_per_node)
        self.gpn = gpus_per_node
        self.tasks = [SimTask(task=t, workers=x)
                      for t, x in zip(tasks, assignment)]
        self.cluster.assign([t.workers for t in self.tasks])
        self.coord: Optional[UnicronCoordinator] = None
        if policy == "unicron":
            self.coord = UnicronCoordinator(
                tasks, assignment, hw, plan_cache=plan_cache,
                n_cluster_workers=self._n_total,
                workers_per_node=gpus_per_node)
        # coordinator entry index per simulator slot (diverges under churn)
        self._ci: List[Optional[int]] = list(range(len(self.tasks)))
        self.spares = HOT_SPARES.get(policy, 0)
        self.n_reconfigs = 0
        self.downtime = 0.0
        self.n_degraded_drains = 0
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self._span = float("inf")

    # ---- instantaneous cluster WAF ----------------------------------------

    def _waf(self, task: Task, x: int) -> float:
        """F(t, x) via the per-task curve (vector lookup; scalar fallback
        for worker counts beyond the cluster size)."""
        if 0 <= x <= self._n_total:
            F = self._waf_curves.get(task)
            if F is None:
                F = waf_mod.waf_curve(task, self._n_total, self.hw)
                self._waf_curves[task] = F
            return float(F[x])
        return waf_mod.waf(task, x, self.hw)

    @staticmethod
    def _slow_factor(st: SimTask, now: float) -> float:
        """Iteration-time multiplier from undetected slow nodes (the task
        runs at the pace of its slowest worker)."""
        s = 1.0
        for start, end, factor in st.slow:
            if start <= now < end and factor > s:
                s = factor
        return s

    def cluster_waf(self, now: float) -> float:
        total = 0.0
        for st in self.tasks:
            if not st.active or now < st.blocked_until or st.workers <= 0:
                continue
            total += (self._waf(st.task, st.workers) * self.eff
                      / self._slow_factor(st, now))
        return total

    # ---- policy behaviours -------------------------------------------------

    def _detect_s(self, kind: ErrorKind, avg_iter: float) -> float:
        unicron = self.policy == "unicron" and not self.ablate_detection
        return detection_time(kind, avg_iter, unicron=unicron)

    def _transition_s(self, st: SimTask, detect_s: float,
                      sev: Severity) -> float:
        state_bytes = 16.0 * st.task.model.n_params
        if self.policy == "unicron" and self.ablate_transition:
            c = transition.estimate_baseline(
                state_bytes, detect_s, dynamic_reconfig=False,
                ckpt_restart=True)
            return c.total
        if self.policy == "unicron":
            dp = max(st.workers // 8, 1)
            c = transition.estimate_unicron(
                state_bytes, st.avg_iter_s, dp_degree=dp, detect_s=detect_s,
                lookup_hit=True)
            return c.total
        if self.policy in ("megatron", "varuna"):
            c = transition.estimate_baseline(
                state_bytes, detect_s, dynamic_reconfig=False,
                ckpt_restart=True)
            return c.total
        # oobleck / bamboo: dynamic reconfiguration
        c = transition.estimate_baseline(
            state_bytes, detect_s, dynamic_reconfig=True, ckpt_restart=False)
        # bamboo's redundancy rides through SEV2/3 without interruption
        if self.policy == "bamboo" and sev is not Severity.SEV1:
            return 0.0
        return c.total

    def _use_planner(self) -> bool:
        return (self.policy == "unicron" and self.coord is not None
                and not self.ablate_replan)

    def _apply_unicron_plan(self) -> None:
        """Sync slot worker counts from the coordinator's entries."""
        for slot, ci in enumerate(self._ci):
            if ci is not None:
                self.tasks[slot].workers = self.coord.entries[ci].n_workers

    def _reconfigure(self, now: float, faulted_task: Optional[int]) -> None:
        """Node-count change: redistribute workers."""
        n_avail = self.cluster.healthy_workers()
        self.n_reconfigs += 1
        if self._use_planner():
            ft = self._ci[faulted_task] if faulted_task is not None else None
            self.coord.reconfigure(n_avail, ft)
            self._apply_unicron_plan()
        else:
            # baselines only touch the directly-affected task: it shrinks
            # to what is left after the others keep their nodes
            others = sum(st.workers for i, st in enumerate(self.tasks)
                         if i != faulted_task)
            if faulted_task is not None:
                st = self.tasks[faulted_task]
                st.workers = max(0, min(st.workers, n_avail - others))
                st.workers -= st.workers % self.gpn
                st.affected_first = True
        self.cluster.assign([t.workers for t in self.tasks])

    def _node_rejoin(self, now: float) -> None:
        n_avail = self.cluster.healthy_workers()
        self.n_reconfigs += 1
        if self._use_planner():
            self.coord.reconfigure(n_avail, None,
                                   trigger=Trigger.NODE_JOIN)
            self._apply_unicron_plan()
        else:
            # restore the first-affected task toward its original size
            assigned = sum(st.workers for st in self.tasks)
            spare = n_avail - assigned
            for st in self.tasks:
                if st.affected_first and spare >= self.gpn:
                    st.workers += self.gpn
                    spare -= self.gpn
                    st.affected_first = False
                    break
        self.cluster.assign([t.workers for t in self.tasks])

    # ---- event normalization ----------------------------------------------

    def _event_heap(self, trace: Trace,
                    span: float) -> List[Tuple[float, int, str, object]]:
        """(time, seq, kind, payload) heap: failure/repair entries first
        (preserving the historical same-time ordering), then degradations
        and churn; handlers may push synthetic events via ``_push``."""
        if isinstance(trace, ClusterScenario):
            failures, degradations, churn = (trace.failures,
                                             trace.degradations, trace.churn)
        else:
            failures, degradations, churn = trace, [], []
        entries: List[Tuple[float, int, str, object]] = []
        seq = 0
        for e in failures:
            if e.time <= span:
                entries.append((e.time, seq, "fail", e))
                seq += 1
        for e in failures:
            if e.repair_s is not None and e.time + e.repair_s <= span:
                entries.append((e.time + e.repair_s, seq, "repair", e))
                seq += 1
        for d in degradations:
            if d.time <= span:
                entries.append((d.time, seq, "degrade", d))
                seq += 1
        for c in churn:
            if c.time <= span:
                kind = "arrive" if isinstance(c, TaskArrival) else "finish"
                entries.append((c.time, seq, kind, c))
                seq += 1
        self._seq = seq
        heapq.heapify(entries)
        return entries

    def _push(self, t: float, kind: str, payload: object) -> None:
        if t <= self._span:
            self._seq += 1
            heapq.heappush(self._heap, (t, self._seq, kind, payload))

    def _dispatch(self, now: float, kind: str, ev: object) -> None:
        if kind == "fail":
            self._on_failure(now, ev)
        elif kind == "repair":
            self._on_repair(now, ev)
        elif kind == "degrade":
            self._on_degradation(now, ev)
        elif kind == "arrive":
            self._on_arrival(now, ev)
        elif kind == "finish":
            self._on_finish(now, ev)

    # ---- main loop ---------------------------------------------------------

    def _resolve_span(self, trace: Trace,
                      span_s: Optional[float]) -> float:
        if span_s is not None:
            return span_s
        if isinstance(trace, ClusterScenario):
            return trace.span_s
        return trace_span(trace)

    def _check_shape(self, trace: Trace) -> None:
        if isinstance(trace, ClusterScenario):
            assert (trace.n_nodes, trace.gpus_per_node) == \
                (len(self.cluster.nodes), self.gpn), (
                    f"scenario shaped for {trace.n_nodes}x"
                    f"{trace.gpus_per_node}, simulator is "
                    f"{len(self.cluster.nodes)}x{self.gpn}")

    def run(self, trace: Trace, span_s: Optional[float] = None) -> SimResult:
        self._check_shape(trace)
        span = self._span = self._resolve_span(trace, span_s)
        self._heap = heap = self._event_heap(trace, span)
        acc, last_t = 0.0, 0.0
        n_events = 0
        timeline: List[Tuple[float, float]] = [(0.0, self.cluster_waf(0.0))]
        while heap:
            t, _, kind, ev = heapq.heappop(heap)
            acc, last_t = self._integrate(acc, last_t, t)
            self._dispatch(t, kind, ev)
            n_events += 1
            timeline.append((t, self.cluster_waf(t)))
        acc, last_t = self._integrate(acc, last_t, span)
        timeline.append((span, self.cluster_waf(span)))
        return SimResult(self.policy, acc, timeline, self.n_reconfigs,
                         self.downtime, n_events, self.n_degraded_drains)

    def _integrate(self, acc: float, last_t: float,
                   t: float) -> Tuple[float, float]:
        """Integrate WAF piecewise up to t: block expiries and slow-window
        edges create breakpoints; each sub-segment is constant, so the
        midpoint sample is exact."""
        if t <= last_t:
            return acc, last_t
        breaks = {t}
        for st in self.tasks:
            if last_t < st.blocked_until < t:
                breaks.add(st.blocked_until)
            for start, end, _ in st.slow:
                if last_t < start < t:
                    breaks.add(start)
                if last_t < end < t:
                    breaks.add(end)
        for b in sorted(breaks):
            acc += self.cluster_waf((last_t + b) / 2) * (b - last_t)
            last_t = b
        return acc, last_t

    # ---- event handlers ----------------------------------------------------

    def _on_failure(self, now: float, ev: FailureEvent) -> None:
        node = ev.node % len(self.cluster.nodes)
        sev = ev.severity
        owner = self.cluster.placement.get(node)
        if owner is None:
            owners = [i for i, st in enumerate(self.tasks) if st.workers > 0]
            owner = owners[node % len(owners)] if owners else None
        if owner is None:
            return
        st = self.tasks[owner]
        detect = self._detect_s(ev.kind, st.avg_iter_s)
        trans = self._transition_s(st, detect, sev)
        if sev is Severity.SEV1:
            if self.spares > 0:
                # hot spare substitutes: capacity preserved, transition
                # (restart-from-checkpoint onto the spare) still paid
                self.spares -= 1
                st.blocked_until = max(st.blocked_until, now + trans)
                self.downtime += trans
                return
            self.cluster.fail_node(node, now + (ev.repair_s or 0.0))
            self._reconfigure(now, owner)
            st.blocked_until = max(st.blocked_until, now + trans)
            self.downtime += trans
        else:
            # SEV2/SEV3: restart/reattempt in place, no capacity change
            st.blocked_until = max(st.blocked_until, now + trans)
            self.downtime += trans

    def _on_repair(self, now: float, ev: FailureEvent) -> None:
        node = ev.node % len(self.cluster.nodes)
        if HOT_SPARES.get(self.policy, 0) and not any(
                st.affected_first for st in self.tasks):
            # no task was down-scaled: the repaired node refills
            # the spare pool instead of joining a task
            self.spares += 1
            return
        self.cluster.recover_node(node)
        self._node_rejoin(now)

    def _on_degradation(self, now: float, ev: DegradationEvent) -> None:
        """Slow node (§4.1): Unicron's statistical monitor flags anything
        past the 1.1x margin and drains the node through the real
        severity workflow (TASK_HANG -> failed restart -> SEV1); policies
        without in-band detection crawl at the slow worker's pace."""
        node = ev.node % len(self.cluster.nodes)
        owner = self.cluster.placement.get(node)
        if owner is None or not self.tasks[owner].active:
            return
        st = self.tasks[owner]
        monitor = OnlineStatMonitor.primed(st.avg_iter_s)
        status = monitor.status(ev.slowdown * st.avg_iter_s)
        in_band = self.policy == "unicron" and not self.ablate_detection
        if in_band and status != "ok":
            if self.coord is not None:
                case = f"degrade:{node}:{now}"
                self.coord.on_error(case, ErrorKind.TASK_HANG)
                self.coord.on_action_failed(case)   # restart can't fix slow
                self.coord.close_case(case)
            detect = self._detect_s(ErrorKind.TASK_HANG, st.avg_iter_s)
            trans = (self._transition_s(st, detect, Severity.SEV1)
                     + transition.RESPAWN_UNICRON_S)  # the failed restart
            self.cluster.fail_node(node, now + ev.duration_s)
            self._reconfigure(now, owner)
            st.blocked_until = max(st.blocked_until, now + trans)
            self.downtime += trans
            self.n_degraded_drains += 1
            self._push(now + ev.duration_s, "repair",
                       FailureEvent(time=now, node=node,
                                    kind=ErrorKind.LOST_CONNECTION,
                                    repair_s=ev.duration_s))
        else:
            st.slow.append((now, now + ev.duration_s, ev.slowdown))

    def _on_arrival(self, now: float, ev: TaskArrival) -> None:
        st = SimTask(task=ev.task, workers=0)
        self.tasks.append(st)
        if self._use_planner():
            self.coord.task_launched(ev.task,
                                     self.cluster.healthy_workers())
            self._ci.append(len(self.coord.entries) - 1)
            self._apply_unicron_plan()
            self.n_reconfigs += 1
        else:
            # baselines: grant from the free pool, node-granular, capped
            # at the task's worker ceiling (workers past it would idle)
            self._ci.append(None)
            assigned = sum(t.workers for t in self.tasks)
            free = max(self.cluster.healthy_workers() - assigned, 0)
            grant = min(ev.workers_hint, free)
            if ev.task.max_workers is not None:
                grant = min(grant, ev.task.max_workers)
            st.workers = grant - grant % self.gpn
        self.cluster.assign([t.workers for t in self.tasks])

    def _on_finish(self, now: float, ev: TaskFinish) -> None:
        if not 0 <= ev.slot < len(self.tasks):
            return
        st = self.tasks[ev.slot]
        if not st.active:
            return
        st.active = False
        st.workers = 0
        if self._use_planner():
            ci = self._ci[ev.slot]
            self._ci[ev.slot] = None
            self.coord.task_finished(ci, self.cluster.healthy_workers())
            for slot, other in enumerate(self._ci):
                if other is not None and other > ci:
                    self._ci[slot] = other - 1
            self._apply_unicron_plan()
            self.n_reconfigs += 1
        else:
            self._ci[ev.slot] = None
        self.cluster.assign([t.workers for t in self.tasks])


class VectorSimulator(TraceSimulator):
    """Cluster-scale engine: the same decision handlers (and, through the
    lazy cached planner, float-identical plans) as ``TraceSimulator``, but

    * WAF accumulation is one vectorized numpy pass over the recorded
      worker/blocked/slow step functions instead of per-breakpoint Python;
    * the coordinator runs on a ``PlannerCache`` — lazy plan tables whose
      reward rows and prefix/suffix DPs are reused across rebuilds and,
      when the cache is shared via ``run_monte_carlo``, across seeds.

    Accumulated WAF matches the scalar reference loop up to float
    reordering (rel. ~1e-12; the benchmark asserts 1e-6).
    """

    def __init__(self, tasks: List[Task], assignment: List[int],
                 policy: str, hw=costmodel.A800, n_nodes: int = 16,
                 gpus_per_node: int = 8, *,
                 plan_cache: Optional[PlannerCache] = None,
                 ablate_detection: bool = False,
                 ablate_transition: bool = False,
                 ablate_replan: bool = False):
        if policy == "unicron" and plan_cache is None:
            plan_cache = PlannerCache()
        super().__init__(tasks, assignment, policy, hw, n_nodes,
                         gpus_per_node, plan_cache=plan_cache,
                         ablate_detection=ablate_detection,
                         ablate_transition=ablate_transition,
                         ablate_replan=ablate_replan)

    def run(self, trace: Trace, span_s: Optional[float] = None) -> SimResult:
        self._check_shape(trace)
        span = self._span = self._resolve_span(trace, span_s)
        self._heap = heap = self._event_heap(trace, span)
        snap_t: List[float] = [0.0]
        snap_w: List[List[int]] = [[st.workers for st in self.tasks]]
        blocks: List[Tuple[int, float, float]] = []  # (slot, start, until)
        n_events = 0
        while heap:
            t, _, kind, ev = heapq.heappop(heap)
            before = [st.blocked_until for st in self.tasks]
            self._dispatch(t, kind, ev)
            n_events += 1
            for slot, prev in enumerate(before):
                if self.tasks[slot].blocked_until > prev:
                    blocks.append((slot, t,
                                   self.tasks[slot].blocked_until))
            snap_t.append(t)
            snap_w.append([st.workers for st in self.tasks])
        acc, timeline = self._integrate_vector(snap_t, snap_w, blocks, span)
        return SimResult(self.policy, acc, timeline, self.n_reconfigs,
                         self.downtime, n_events, self.n_degraded_drains)

    def _integrate_vector(self, snap_t: List[float],
                          snap_w: List[List[int]],
                          blocks: List[Tuple[int, float, float]],
                          span: float):
        """One numpy pass: segment boundaries from events + block expiries
        + slow-window edges; per-segment rates are a gather out of the
        (m, n+1) WAF matrix, masked by blocks, divided by slow factors."""
        m = len(self.tasks)
        edges = {0.0, span}
        edges.update(t for t in snap_t if 0.0 < t < span)
        for _, start, until in blocks:
            if start < span:
                edges.add(max(start, 0.0))
                if until < span:
                    edges.add(until)
        for st in self.tasks:
            for start, end, _ in st.slow:
                if 0.0 < start < span:
                    edges.add(start)
                if 0.0 < end < span:
                    edges.add(end)
        bounds = np.array(sorted(edges))
        dt = np.diff(bounds)
        # per-segment worker counts: latest snapshot at or before seg start
        st_arr = np.array(snap_t)
        idx = np.searchsorted(st_arr, bounds[:-1], side="right") - 1
        W = np.zeros((len(snap_t), m), dtype=np.int64)
        for r, w in enumerate(snap_w):
            W[r, :len(w)] = w
        Wseg = W[idx]                                   # (S, m)
        F = waf_mod.waf_matrix([st.task for st in self.tasks],
                               self._n_total, self.hw) * self.eff
        rate = F[np.arange(m)[None, :], Wseg]           # (S, m)
        scale = np.ones_like(rate)
        for slot, start, until in blocks:
            if start >= span:
                continue
            lo = np.searchsorted(bounds, start, side="left")
            hi = np.searchsorted(bounds, min(until, span), side="left")
            scale[lo:hi, slot] = 0.0
        for slot, st in enumerate(self.tasks):
            for start, end, factor in st.slow:
                if start >= span:
                    continue
                lo = np.searchsorted(bounds, max(start, 0.0), side="left")
                hi = np.searchsorted(bounds, min(end, span), side="left")
                seg = scale[lo:hi, slot]
                np.minimum(seg, 1.0 / factor,
                           where=seg > 0.0, out=seg)
        eff_rate = rate * scale
        acc = float(eff_rate @ np.ones(m) @ dt) if m else 0.0
        row = eff_rate.sum(axis=1) if m else np.zeros(len(dt))
        # timeline samples at event boundaries (rate of the segment that
        # starts there), matching the reference loop's post-event samples
        timeline = [(0.0, float(row[0]) if len(row) else 0.0)]
        for t in snap_t[1:]:
            si = min(np.searchsorted(bounds, t, side="left"), len(row) - 1)
            timeline.append((t, float(row[si])))
        timeline.append((span, float(row[-1]) if len(row) else 0.0))
        return acc, timeline


def run_policies(tasks: List[Task], assignment: List[int],
                 trace: Trace,
                 policies: Optional[List[str]] = None,
                 hw=costmodel.A800) -> Dict[str, SimResult]:
    out = {}
    for p in policies or list(EFFICIENCY):
        sim = TraceSimulator(tasks, list(assignment), p, hw)
        out[p] = sim.run(trace)
    return out


def run_monte_carlo(tasks: List[Task], assignment: List[int],
                    scenario_fn, seeds, policies: Optional[List[str]] = None,
                    hw=costmodel.A800, n_nodes: int = 16,
                    gpus_per_node: int = 8,
                    plan_cache: Optional[PlannerCache] = None,
                    threads: Optional[int] = None
                    ) -> Dict[str, MonteCarloResult]:
    """Batched Monte-Carlo sweep: ``scenario_fn(seed)`` generates one
    seeded ``ClusterScenario`` per seed, and every (policy, seed) run goes
    through the vectorized engine over ONE shared ``PlannerCache`` — a
    cluster state reached in any seed is never re-planned in another.

    Seeds of one policy run on a thread pool (numpy's convolutions
    release the GIL): results are deterministic regardless of scheduling
    because every cache entry is fully determined by its key."""
    cache = plan_cache if plan_cache is not None else PlannerCache()
    scenarios = [scenario_fn(s) for s in seeds]
    # sequential by default: on few-core hosts the GIL-held decision glue
    # plus duplicated cold builds outweigh the parallel convolutions
    n_threads = threads or 1
    out: Dict[str, MonteCarloResult] = {}

    def one(policy, scenario):
        sim = VectorSimulator(tasks, list(assignment), policy, hw,
                              n_nodes=n_nodes,
                              gpus_per_node=gpus_per_node,
                              plan_cache=cache)
        return sim.run(scenario)

    for p in policies or list(EFFICIENCY):
        t0 = _time.perf_counter()
        if n_threads > 1 and len(scenarios) > 1:
            with ThreadPoolExecutor(max_workers=n_threads) as pool:
                results = list(pool.map(lambda sc: one(p, sc), scenarios))
        else:
            results = [one(p, sc) for sc in scenarios]
        wall = _time.perf_counter() - t0
        wafs = [r.accumulated_waf for r in results]
        arr = np.array(wafs)
        out[p] = MonteCarloResult(p, float(arr.mean()), float(arr.std()),
                                  wafs, wall,
                                  sum(r.n_reconfigs for r in results),
                                  sum(r.downtime_s for r in results))
    return out
