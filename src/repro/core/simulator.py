"""Discrete-event cluster simulator (§7.5) driving the REAL Unicron code.

The simulator replaces wall-clock time and GPUs only: detection latencies
come from ``core.detection``, recovery decisions from the severity
workflow, reconfiguration plans from the real DP planner through
``UnicronCoordinator``, and transition durations from ``core.transition``.
Baselines are recovery *policies* with their published behaviours:

  megatron   restart-from-checkpoint + hot spare; 30-min watchdog
             detection for non-node-loss failures; reconfigures only the
             affected task (down-scales on node loss until repair).
  oobleck    dynamic reconfiguration (no checkpoint reload), pipeline
             templates; lower normal-case efficiency (Fig. 3a).
  bamboo     redundant computation: keeps running through failures but
             pays a constant throughput tax; lowest efficiency.
  varuna     job morphing + checkpoint restart; low efficiency.
  unicron    everything in this repo: in-band detection, lookup-table
             plans over ALL tasks, partial-result reuse.

Three modern recovery techniques (PAPERS.md: FFTrainer, GEMINI-style
tiered checkpointing, replication-based continuation) are policy peers
of the paper's five — the frontier ``benchmarks/bench_frontier.py``
sweeps:

  fftrainer          reserved hot-spare pool (``fftrainer_pool``): a
                     spare substitutes for a failed node in seconds with
                     state from the DP replica; the spares are capacity
                     no task may use, so the trade-off is standing WAF
                     for near-zero failover.  In-band detection.
  hierarchical_ckpt  tiered restore (in-memory ring, demoted to the
                     persistent store when a correlated burst also took
                     the ring neighbor); affected-task reconfiguration,
                     in-band detection, small standing efficiency tax
                     for the per-iteration snapshots.
  redundant          redundancy-based continuation: zero-cost
                     transitions (survivors absorb the work instantly)
                     paid for by the largest standing efficiency tax;
                     failures still shrink capacity until repair.

Inputs are either a plain failure trace (``core.traces``) or a
:class:`~repro.core.scenarios.ClusterScenario`, which adds slow-node
degradation (§4.1 statistical monitor), correlated/preemption failures,
and task join/finish churn (Figure 7 triggers 5/6).

Three integrators share one decision engine:

* ``TraceSimulator`` — the scalar reference loop: per-event Python with
  piecewise-midpoint WAF integration and the eager, uncached coordinator.
  One policy per run; the ground truth every other engine must match.
* ``VectorSimulator`` — the per-(policy, seed) cluster-scale engine:
  identical decisions (same handlers, plans float-identical via the lazy
  cached planner), but WAF is integrated as one numpy segment product and
  plan tables are chain-cached across rebuilds and Monte-Carlo seeds
  (``planner.PlannerCache``).  Still one policy per run — the measured
  baseline of the batched engine.
* ``BatchSimulator`` — the batched multi-policy engine: one event pass
  per trace carrying EVERY recovery policy as stacked numpy state
  (per-policy worker/blocked/placement matrices, downtime vectors, WAF
  accumulators).  Each event is decoded once; its per-policy consequences
  are one array op over the policy axis through the array-native models
  (``detection.detection_times``, ``transition.estimate_batch``,
  ``detection.FleetMonitor``), while planner-backed lanes drive the same
  ``UnicronCoordinator`` the scalar loop uses, so plans stay identical.

``run_monte_carlo(engine=...)`` batches seeds over a shared cache:
``"batched"`` (default) runs each seed once through ``BatchSimulator``;
``"vector"`` keeps the PR-2/3 per-(policy, seed) path as the measured
baseline.  ``benchmarks/bench_cluster_sim.py`` asserts the >= 50x
vector-vs-scalar and >= 3x batched-vs-vector engine speedups and 1e-6
WAF agreement at (n=1024, m=32).

WAF is integrated over the trace (the Fig. 11 y-axis); ``accumulated``
at the end of the run is the Fig. 11b/d number.
"""
from __future__ import annotations

import dataclasses
import heapq
import time as _time
from bisect import bisect_left, bisect_right
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core import costmodel, transition, waf as waf_mod
from repro.core.cluster import Cluster
from repro.core.coordinator import UnicronCoordinator
from repro.core.detection import (INBAND_POLICIES, ErrorKind, FleetMonitor,
                                  Severity, classify, detection_time,
                                  detection_times)
from repro.core.handling import Trigger
from repro.core.planner import PlannerCache
from repro.core.scenarios import (ClusterScenario, DegradationEvent,
                                  RateChangeEvent, TaskArrival, TaskFinish)
from repro.core.traces import FailureEvent, trace_span
from repro.core.waf import Task

# Normal-case training efficiency relative to Megatron (Figure 3a: the
# resilience-first systems run at a fraction of Megatron's throughput).
EFFICIENCY = {
    "unicron": 1.00,        # inherits all Megatron optimizations
    "megatron": 1.00,
    "oobleck": 0.38,
    "bamboo": 0.30,         # includes the redundant-computation tax
    "varuna": 0.29,
    "fftrainer": 1.00,      # spare cost is modeled as reserved capacity
    "hierarchical_ckpt": 0.98,   # per-iteration in-memory snapshots
    "redundant": 0.90,      # standing replication tax
}

# Megatron's deployment keeps hot-spare nodes that substitute for failed
# ones (paper §7.3 footnote 1): capacity is preserved while a spare is
# available, at the cost of idling the spare.  Unicron instead re-plans
# and uses every healthy node productively.
HOT_SPARES = {"megatron": 1}


def fftrainer_pool(n_nodes: int) -> int:
    """Reserved hot-spare pool size for the fftrainer policy: one spare
    per 16 nodes (at least one), never the whole fleet.  Unlike
    megatron's off-book spare, these are nodes the planner can never
    assign — the standing WAF cost of the near-zero failover."""
    if n_nodes <= 1:
        return 0
    return min(max(1, n_nodes // 16), n_nodes - 1)


def fit_assignment(assignment: List[int], capacity: int,
                   gpn: int) -> List[int]:
    """Trim an assignment to ``capacity`` workers by repeatedly shaving
    one node's worth off the largest task (deterministic: first max
    wins) — how the fftrainer lanes fund their reserved spares."""
    w = list(assignment)
    total = sum(w)
    while total > capacity:
        i = max(range(len(w)), key=lambda j: w[j])
        if w[i] < gpn:
            break
        w[i] -= gpn
        total -= gpn
    return w

Trace = Union[List[FailureEvent], ClusterScenario]


@dataclass
class SimTask:
    task: Task
    workers: int
    avg_iter_s: float = 30.0
    blocked_until: float = 0.0          # transitioning/restarting until t
    affected_first: bool = False        # baselines: reconfigure priority
    active: bool = True                 # False once the task finished
    # undetected slow-node windows: (start, end, iteration-time multiplier)
    slow: List[Tuple[float, float, float]] = field(default_factory=list)


@dataclass
class SimResult:
    policy: str
    accumulated_waf: float              # integral of WAF dt
    timeline: List[Tuple[float, float]]  # (t, cluster WAF) samples
    n_reconfigs: int
    downtime_s: float                   # total task-seconds blocked
    n_events: int = 0
    n_degraded_drains: int = 0          # slow nodes caught by the monitor


@dataclass
class MonteCarloResult:
    policy: str
    waf_mean: float
    waf_std: float
    per_seed: List[float]
    wall_s: float                       # engine wall-clock for all seeds
    n_reconfigs: int
    downtime_s: float


# ---------------------------------------------------------------------------
# Shared event normalization + segment integration (all engines)
# ---------------------------------------------------------------------------


def _resolve_trace_span(trace: Trace, span_s: Optional[float]) -> float:
    if span_s is not None:
        return span_s
    if isinstance(trace, ClusterScenario):
        return trace.span_s
    return trace_span(trace)


def _check_trace_shape(trace: Trace, n_nodes: int, gpn: int) -> None:
    if isinstance(trace, ClusterScenario):
        assert (trace.n_nodes, trace.gpus_per_node) == (n_nodes, gpn), (
            f"scenario shaped for {trace.n_nodes}x"
            f"{trace.gpus_per_node}, simulator is {n_nodes}x{gpn}")


def _event_entries(trace: Trace,
                   span: float) -> Tuple[List[Tuple[float, int, str, object]],
                                         int]:
    """(time, seq, kind, payload) entries + next seq: failure/repair first
    (preserving the historical same-time ordering), then degradations and
    churn; handlers may push synthetic events past these."""
    if isinstance(trace, ClusterScenario):
        failures, degradations, churn = (trace.failures,
                                         trace.degradations, trace.churn)
    else:
        failures, degradations, churn = trace, [], []
    entries: List[Tuple[float, int, str, object]] = []
    seq = 0
    for e in failures:
        if e.time <= span:
            entries.append((e.time, seq, "fail", e))
            seq += 1
    for e in failures:
        if e.repair_s is not None and e.time + e.repair_s <= span:
            entries.append((e.time + e.repair_s, seq, "repair", e))
            seq += 1
    for d in degradations:
        if d.time <= span:
            entries.append((d.time, seq, "degrade", d))
            seq += 1
    for c in churn:
        if c.time <= span:
            if isinstance(c, TaskArrival):
                kind = "arrive"
            elif isinstance(c, RateChangeEvent):
                kind = "rate"
            else:
                kind = "finish"
            entries.append((c.time, seq, kind, c))
            seq += 1
    return entries, seq


def _rate_epoch_stack(tasks: List[Task],
                      rate_log: List[Tuple[float, int, Task, Task]],
                      n: int, hw) -> Tuple[np.ndarray, np.ndarray]:
    """Per-epoch WAF matrices for a trace whose tasks swapped objectives
    mid-span (``RateChangeEvent``): returns ``(epoch_t, F)`` where
    ``epoch_t[e]`` is when epoch ``e`` begins and ``F[e]`` is its
    (m, n+1) reward matrix.  ``tasks`` is the FINAL task list;
    ``rate_log`` holds (time, slot, old, new) entries in dispatch order
    and is rewound to recover each epoch's task list."""
    cur = list(tasks)
    for _, slot, old, _new in reversed(rate_log):
        cur[slot] = old
    epoch_t = [0.0]
    lists = [list(cur)]
    for t, slot, _old, new in rate_log:
        cur = list(cur)
        cur[slot] = new
        epoch_t.append(t)
        lists.append(cur)
    F = np.stack([waf_mod.waf_matrix(ts, n, hw) for ts in lists])
    return np.asarray(epoch_t), F


def _integrate_segments(snap_t: List[float], snap_w: List[List[int]],
                        blocks: List[Tuple[int, float, float]],
                        slows: List[List[Tuple[float, float, float]]],
                        span: float, F: np.ndarray,
                        epoch_t: Optional[np.ndarray] = None):
    """One numpy pass over one policy's recorded step functions: segment
    boundaries from events + block expiries + slow-window edges; rates are
    a gather out of the eff-scaled (m, n+1) WAF matrix ``F``, masked by
    blocks, divided by slow factors.  With ``epoch_t``, ``F`` is an
    (E, m, n+1) epoch stack (reward rows changed mid-trace via rate
    events) and each segment gathers from the epoch holding its start.
    Returns (accumulated, timeline)."""
    m = F.shape[-2]
    edges = {0.0, span}
    edges.update(t for t in snap_t if 0.0 < t < span)
    if epoch_t is not None:
        edges.update(float(t) for t in epoch_t if 0.0 < t < span)
    for _, start, until in blocks:
        if start < span:
            edges.add(max(start, 0.0))
            if until < span:
                edges.add(until)
    for wins in slows:
        for start, end, _ in wins:
            if 0.0 < start < span:
                edges.add(start)
            if 0.0 < end < span:
                edges.add(end)
    bounds = np.array(sorted(edges))
    dt = np.diff(bounds)
    # per-segment worker counts: latest snapshot at or before seg start
    st_arr = np.array(snap_t)
    idx = np.searchsorted(st_arr, bounds[:-1], side="right") - 1
    W = np.zeros((len(snap_t), m), dtype=np.int64)
    for r, w in enumerate(snap_w):
        W[r, :len(w)] = w
    Wseg = W[idx]                                   # (S, m)
    if epoch_t is None:
        rate = F[np.arange(m)[None, :], Wseg]       # (S, m)
    else:
        eidx = np.searchsorted(epoch_t, bounds[:-1], side="right") - 1
        rate = F[eidx[:, None], np.arange(m)[None, :], Wseg]
    scale = np.ones_like(rate)
    for slot, start, until in blocks:
        if start >= span:
            continue
        lo = np.searchsorted(bounds, start, side="left")
        hi = np.searchsorted(bounds, min(until, span), side="left")
        scale[lo:hi, slot] = 0.0
    for slot, wins in enumerate(slows):
        for start, end, factor in wins:
            if start >= span:
                continue
            lo = np.searchsorted(bounds, max(start, 0.0), side="left")
            hi = np.searchsorted(bounds, min(end, span), side="left")
            seg = scale[lo:hi, slot]
            np.minimum(seg, 1.0 / factor,
                       where=seg > 0.0, out=seg)
    eff_rate = rate * scale
    acc = float(eff_rate @ np.ones(m) @ dt) if m else 0.0
    row = eff_rate.sum(axis=1) if m else np.zeros(len(dt))
    # timeline samples at event boundaries (rate of the segment that
    # starts there), matching the reference loop's post-event samples
    timeline = [(0.0, float(row[0]) if len(row) else 0.0)]
    for t in snap_t[1:]:
        si = min(np.searchsorted(bounds, t, side="left"), len(row) - 1)
        timeline.append((t, float(row[si])))
    timeline.append((span, float(row[-1]) if len(row) else 0.0))
    return acc, timeline


def _integrate_policies(snap_t: List[float], snaps: List[np.ndarray],
                        blocks, slows, span: float, F: np.ndarray,
                        effs: np.ndarray,
                        timeline_t: Optional[List[float]] = None,
                        epoch_t: Optional[np.ndarray] = None):
    """The multi-policy counterpart of ``_integrate_segments``: one shared
    edge set (the union of every policy's breakpoints — extra edges only
    split constant segments, so totals agree with the per-policy pass to
    float reordering), one (S, P, m) gather, per-policy block/slow masks.
    ``blocks[p]`` is a (slots, starts, untils) triple of parallel lists.
    With ``epoch_t``, ``F`` is an (E, m, n+1) rate-epoch stack (see
    ``_integrate_segments``).  Returns (accs (P,), timelines per policy)."""
    P, m = effs.size, F.shape[-2]
    st_arr = np.array(snap_t)
    parts = [st_arr, np.array((0.0, span))]
    if epoch_t is not None:
        parts.append(epoch_t[(epoch_t > 0.0) & (epoch_t < span)])
    barrs = []
    for p in range(P):
        bslots, bstarts, buntils = blocks[p]
        sl = np.array(bslots, dtype=np.int64)
        st = np.array(bstarts)
        un = np.array(buntils)
        barrs.append((sl, st, un))
        if sl.size:
            parts.append(np.maximum(st, 0.0))
            parts.append(un[un < span])
        for slot, wins in enumerate(slows[p]):
            for start, end, _ in wins:
                parts.append(np.array((max(start, 0.0), min(end, span))))
    bounds = np.unique(np.concatenate(parts))
    bounds = bounds[(bounds >= 0.0) & (bounds <= span)]
    dt = np.diff(bounds)
    idx = np.searchsorted(st_arr, bounds[:-1], side="right") - 1
    W = np.zeros((len(snap_t), P, m), dtype=np.int64)
    for r, w in enumerate(snaps):
        W[r, :, :w.shape[1]] = w
    Wseg = W[idx]                                   # (S, P, m)
    if epoch_t is None:
        rate = F[np.arange(m)[None, None, :], Wseg] * effs[None, :, None]
    else:
        eidx = np.searchsorted(epoch_t, bounds[:-1], side="right") - 1
        rate = (F[eidx[:, None, None], np.arange(m)[None, None, :], Wseg]
                * effs[None, :, None])
    scale = np.ones_like(rate)
    for p in range(P):
        sl, st, un = barrs[p]
        if sl.size:
            lo_a = np.searchsorted(bounds, st, side="left")
            hi_a = np.searchsorted(bounds, np.minimum(un, span),
                                   side="left")
            live = st < span
            for slot, lo, hi in zip(sl[live].tolist(), lo_a[live].tolist(),
                                    hi_a[live].tolist()):
                scale[lo:hi, p, slot] = 0.0
        for slot, wins in enumerate(slows[p]):
            for start, end, factor in wins:
                if start >= span:
                    continue
                lo = np.searchsorted(bounds, max(start, 0.0), side="left")
                hi = np.searchsorted(bounds, min(end, span), side="left")
                seg = scale[lo:hi, p, slot]
                np.minimum(seg, 1.0 / factor,
                           where=seg > 0.0, out=seg)
    rate *= scale
    rows = rate.sum(axis=2)                         # (S, P)
    accs = rows.T @ dt if m else np.zeros(P)
    # timeline samples at event times (the rate of the segment holding or
    # starting at each sample), shared across policies
    samples = snap_t[1:] if timeline_t is None else timeline_t
    sis = (np.clip(np.searchsorted(bounds, samples, side="right") - 1,
                   0, len(dt) - 1)
           if len(dt) else np.zeros(0, dtype=int))
    timelines = []
    for p in range(P):
        row = rows[:, p]
        first = float(row[0]) if len(row) else 0.0
        last = float(row[-1]) if len(row) else 0.0
        timeline = [(0.0, first)]
        timeline += [(t, float(row[si]))
                     for t, si in zip(samples, sis)]
        timeline.append((span, last))
        timelines.append(timeline)
    return accs, timelines


class TraceSimulator:
    """Scalar reference loop: per-event Python decisions + piecewise
    midpoint WAF integration (the baseline the vectorized engine must
    match to 1e-6 and beat by >= 50x)."""

    def __init__(self, tasks: List[Task], assignment: List[int],
                 policy: str, hw=costmodel.A800, n_nodes: int = 16,
                 gpus_per_node: int = 8, *,
                 plan_cache: Optional[PlannerCache] = None,
                 plan_engine: str = "batched",
                 ablate_detection: bool = False,
                 ablate_transition: bool = False,
                 ablate_replan: bool = False,
                 chaos=None):
        """``ablate_*``: component ablations for the unicron policy —
        swap one Unicron mechanism for its baseline counterpart to
        measure that component's contribution (benchmarks/bench_ablation).
        ``plan_cache``: share a ``PlannerCache`` across runs (lazy plan
        tables, chains reused across rebuilds; plans stay identical).
        ``plan_engine``: the coordinator's incremental PlanTable engine
        (``"batched"`` default; ``"segtree"``/``"chain"`` are the
        measured baselines — all three produce float-identical plans).
        ``chaos``: a ``chaos.ChaosSchedule`` (duck-typed: only
        ``crash_times`` is read) — each listed time becomes a
        ``coord_crash`` event that kills the unicron coordinator
        mid-trace and rebuilds a successor from its ``/coord/journal/*``
        keys via ``UnicronCoordinator.recover``.  Message-level chaos
        (drop/delay/duplication/partitions) lives in ``chaos.ChaosHarness``,
        which drives the real agent->KV->control-loop path; this engine's
        event stream bypasses message transport, so only the crash
        component of a schedule applies here."""
        self.policy = policy
        self.ablate_detection = ablate_detection
        self.ablate_transition = ablate_transition
        self.ablate_replan = ablate_replan
        self._chaos = chaos
        self._plan_cache = plan_cache
        self._plan_engine = plan_engine
        self.hw = hw
        self.eff = EFFICIENCY[policy]
        # WAF timeline sampling reads F(t, ·) straight off the memoized
        # cost-model curves; one vector per distinct task for the whole run
        self._n_total = n_nodes * gpus_per_node
        self._waf_curves: Dict[Task, object] = {}
        self.cluster = Cluster(n_nodes, gpus_per_node)
        self.gpn = gpus_per_node
        if policy == "fftrainer":
            # the reserved spare pool is funded up front: the initial
            # assignment is trimmed to the capacity that remains
            pool = fftrainer_pool(n_nodes)
            assignment = fit_assignment(
                list(assignment), (n_nodes - pool) * gpus_per_node,
                gpus_per_node)
        self.tasks = [SimTask(task=t, workers=x)
                      for t, x in zip(tasks, assignment)]
        # §4.1 statistical monitor: one primed ring-buffer row per task
        # (replaces the per-event OnlineStatMonitor deques; same status)
        self._fleet = FleetMonitor.primed([t.avg_iter_s
                                           for t in self.tasks])
        self.cluster.assign([t.workers for t in self.tasks])
        self.coord: Optional[UnicronCoordinator] = None
        if policy == "unicron":
            self.coord = UnicronCoordinator(
                tasks, assignment, hw, plan_cache=plan_cache,
                n_cluster_workers=self._n_total,
                workers_per_node=gpus_per_node,
                plan_engine=plan_engine)
        # coordinator entry index per simulator slot (diverges under churn)
        self._ci: List[Optional[int]] = list(range(len(self.tasks)))
        self.spares = (fftrainer_pool(n_nodes) if policy == "fftrainer"
                       else HOT_SPARES.get(policy, 0))
        self.n_reconfigs = 0
        self.downtime = 0.0
        self.n_degraded_drains = 0
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self._span = float("inf")
        # objective swaps applied so far: (time, slot, old_task, new_task)
        self._rate_log: List[Tuple[float, int, Task, Task]] = []

    # ---- instantaneous cluster WAF ----------------------------------------

    def _waf(self, task: Task, x: int) -> float:
        """F(t, x) via the per-task curve (vector lookup; scalar fallback
        for worker counts beyond the cluster size)."""
        if 0 <= x <= self._n_total:
            F = self._waf_curves.get(task)
            if F is None:
                F = waf_mod.waf_curve(task, self._n_total, self.hw)
                self._waf_curves[task] = F
            return float(F[x])
        return waf_mod.waf(task, x, self.hw)

    @staticmethod
    def _slow_factor(st: SimTask, now: float) -> float:
        """Iteration-time multiplier from undetected slow nodes (the task
        runs at the pace of its slowest worker)."""
        s = 1.0
        for start, end, factor in st.slow:
            if start <= now < end and factor > s:
                s = factor
        return s

    def cluster_waf(self, now: float) -> float:
        total = 0.0
        for st in self.tasks:
            if not st.active or now < st.blocked_until or st.workers <= 0:
                continue
            total += (self._waf(st.task, st.workers) * self.eff
                      / self._slow_factor(st, now))
        return total

    # ---- policy behaviours -------------------------------------------------

    def _detect_s(self, kind: ErrorKind, avg_iter: float) -> float:
        unicron = (self.policy in INBAND_POLICIES
                   and not self.ablate_detection)
        return detection_time(kind, avg_iter, unicron=unicron)

    def _transition_s(self, st: SimTask, detect_s: float,
                      sev: Severity, replica_lost: bool = False) -> float:
        state_bytes = waf_mod.state_bytes(st.task)
        if self.policy == "unicron" and self.ablate_transition:
            c = transition.estimate_baseline(
                state_bytes, detect_s, dynamic_reconfig=False,
                ckpt_restart=True)
            return c.total
        if self.policy == "unicron":
            dp = max(st.workers // 8, 1)
            c = transition.estimate_unicron(
                state_bytes, st.avg_iter_s, dp_degree=dp, detect_s=detect_s,
                lookup_hit=True, replica_lost=replica_lost)
            return c.total
        if self.policy == "fftrainer":
            return transition.estimate_fftrainer(
                state_bytes, st.avg_iter_s, detect_s).total
        if self.policy == "hierarchical_ckpt":
            return transition.estimate_hierarchical(
                state_bytes, st.avg_iter_s, detect_s,
                replica_lost=replica_lost).total
        if self.policy == "redundant":
            # continuation: survivors absorb the work with zero stoppage
            return transition.estimate_redundant().total
        if self.policy in ("megatron", "varuna"):
            c = transition.estimate_baseline(
                state_bytes, detect_s, dynamic_reconfig=False,
                ckpt_restart=True)
            return c.total
        # oobleck / bamboo: dynamic reconfiguration
        c = transition.estimate_baseline(
            state_bytes, detect_s, dynamic_reconfig=True, ckpt_restart=False)
        # bamboo's redundancy rides through SEV2/3 without interruption
        if self.policy == "bamboo" and sev is not Severity.SEV1:
            return 0.0
        return c.total

    def _use_planner(self) -> bool:
        return (self.policy == "unicron" and self.coord is not None
                and not self.ablate_replan)

    def _avail_workers(self) -> int:
        """Workers the policy may assign: healthy capacity minus the
        fftrainer spare pool (reserved nodes no task can use)."""
        avail = self.cluster.healthy_workers()
        if self.policy == "fftrainer":
            avail -= self.spares * self.gpn
        return avail

    def _apply_unicron_plan(self) -> None:
        """Sync slot worker counts from the coordinator's entries."""
        for slot, ci in enumerate(self._ci):
            if ci is not None:
                self.tasks[slot].workers = self.coord.entries[ci].n_workers

    def _reconfigure(self, now: float, faulted_task: Optional[int]) -> None:
        """Node-count change: redistribute workers."""
        n_avail = self._avail_workers()
        self.n_reconfigs += 1
        if self._use_planner():
            ft = self._ci[faulted_task] if faulted_task is not None else None
            self.coord.reconfigure(n_avail, ft)
            self._apply_unicron_plan()
        else:
            # baselines only touch the directly-affected task: it shrinks
            # to what is left after the others keep their nodes
            others = sum(st.workers for i, st in enumerate(self.tasks)
                         if i != faulted_task)
            if faulted_task is not None:
                st = self.tasks[faulted_task]
                st.workers = max(0, min(st.workers, n_avail - others))
                st.workers -= st.workers % self.gpn
                st.affected_first = True
        self.cluster.assign([t.workers for t in self.tasks])

    def _node_rejoin(self, now: float) -> None:
        n_avail = self._avail_workers()
        self.n_reconfigs += 1
        if self._use_planner():
            self.coord.reconfigure(n_avail, None,
                                   trigger=Trigger.NODE_JOIN)
            self._apply_unicron_plan()
        else:
            # restore the first-affected task toward its original size
            assigned = sum(st.workers for st in self.tasks)
            spare = n_avail - assigned
            for st in self.tasks:
                if st.affected_first and spare >= self.gpn:
                    st.workers += self.gpn
                    spare -= self.gpn
                    st.affected_first = False
                    break
        self.cluster.assign([t.workers for t in self.tasks])

    # ---- event normalization ----------------------------------------------

    def _event_heap(self, trace: Trace,
                    span: float) -> List[Tuple[float, int, str, object]]:
        """(time, seq, kind, payload) heap (``_event_entries``); handlers
        may push synthetic events via ``_push``."""
        entries, self._seq = _event_entries(trace, span)
        heapq.heapify(entries)
        return entries

    def _push(self, t: float, kind: str, payload: object) -> None:
        if t <= self._span:
            self._seq += 1
            heapq.heappush(self._heap, (t, self._seq, kind, payload))

    def _dispatch(self, now: float, kind: str, ev: object) -> None:
        if kind == "fail":
            self._on_failure(now, ev)
        elif kind == "repair":
            self._on_repair(now, ev)
        elif kind == "degrade":
            self._on_degradation(now, ev)
        elif kind == "arrive":
            self._on_arrival(now, ev)
        elif kind == "finish":
            self._on_finish(now, ev)
        elif kind == "rate":
            self._on_rate(now, ev)
        elif kind == "coord_crash":
            self._on_coord_crash(now)

    def _push_crash_events(self) -> None:
        """Schedule the chaos plan's coordinator crashes as heap events
        (after the heap for a run exists)."""
        if self._chaos is not None and self.coord is not None:
            for ct in getattr(self._chaos, "crash_times", ()):
                self._push(float(ct), "coord_crash", None)

    def _on_coord_crash(self, now: float) -> None:
        """The coordinator process dies; a successor rebuilds itself from
        the ``/coord/journal/*`` keys.  The journal carries the complete
        planner-relevant state, so the successor's plans — and therefore
        the trace outcome — are identical to the crash-free run; the old
        incarnation is fenced out should it ever wake up."""
        if self.coord is None:
            return
        self.coord = UnicronCoordinator.recover(
            self.coord.kv, self.hw, plan_cache=self._plan_cache,
            n_cluster_workers=self._n_total, workers_per_node=self.gpn,
            plan_engine=self._plan_engine)

    # ---- main loop ---------------------------------------------------------

    def _resolve_span(self, trace: Trace,
                      span_s: Optional[float]) -> float:
        return _resolve_trace_span(trace, span_s)

    def _check_shape(self, trace: Trace) -> None:
        _check_trace_shape(trace, len(self.cluster.nodes), self.gpn)

    def run(self, trace: Trace, span_s: Optional[float] = None) -> SimResult:
        self._check_shape(trace)
        span = self._span = self._resolve_span(trace, span_s)
        self._heap = heap = self._event_heap(trace, span)
        self._push_crash_events()
        acc, last_t = 0.0, 0.0
        n_events = 0
        timeline: List[Tuple[float, float]] = [(0.0, self.cluster_waf(0.0))]
        while heap:
            t, _, kind, ev = heapq.heappop(heap)
            acc, last_t = self._integrate(acc, last_t, t)
            self._dispatch(t, kind, ev)
            n_events += 1
            timeline.append((t, self.cluster_waf(t)))
        acc, last_t = self._integrate(acc, last_t, span)
        timeline.append((span, self.cluster_waf(span)))
        return SimResult(self.policy, acc, timeline, self.n_reconfigs,
                         self.downtime, n_events, self.n_degraded_drains)

    def _integrate(self, acc: float, last_t: float,
                   t: float) -> Tuple[float, float]:
        """Integrate WAF piecewise up to t: block expiries and slow-window
        edges create breakpoints; each sub-segment is constant, so the
        midpoint sample is exact."""
        if t <= last_t:
            return acc, last_t
        breaks = {t}
        for st in self.tasks:
            if last_t < st.blocked_until < t:
                breaks.add(st.blocked_until)
            for start, end, _ in st.slow:
                if last_t < start < t:
                    breaks.add(start)
                if last_t < end < t:
                    breaks.add(end)
        for b in sorted(breaks):
            acc += self.cluster_waf((last_t + b) / 2) * (b - last_t)
            last_t = b
        return acc, last_t

    # ---- event handlers ----------------------------------------------------

    def _on_failure(self, now: float, ev: FailureEvent) -> None:
        node = ev.node % len(self.cluster.nodes)
        sev = ev.severity
        owner = self.cluster.placement.get(node)
        if owner is None:
            owners = [i for i, st in enumerate(self.tasks) if st.workers > 0]
            owner = owners[node % len(owners)] if owners else None
        if owner is None:
            return
        st = self.tasks[owner]
        detect = self._detect_s(ev.kind, st.avg_iter_s)
        # replica loss (SEV1 only): a correlated burst already took the
        # failed node's in-memory ring neighbor, so tier-aware restores
        # (unicron at dp==1, hierarchical_ckpt) demote to persistent
        replica_lost = False
        if sev is Severity.SEV1:
            nb = (node + 1) % len(self.cluster.nodes)
            replica_lost = not self.cluster.nodes[nb].healthy
        trans = self._transition_s(st, detect, sev,
                                   replica_lost=replica_lost)
        if sev is Severity.SEV1:
            if self.policy == "fftrainer":
                # the node is really lost, but a reserved spare (if any)
                # substitutes: capacity is constant (healthy-1, pool-1)
                # and the task keeps its workers; with the pool dry the
                # affected task shrinks like any baseline
                self.cluster.fail_node(node, now + (ev.repair_s or 0.0))
                if self.spares > 0:
                    self.spares -= 1
                    self.cluster.assign([t.workers for t in self.tasks])
                else:
                    self._reconfigure(now, owner)
                st.blocked_until = max(st.blocked_until, now + trans)
                self.downtime += trans
                return
            if self.spares > 0:
                # hot spare substitutes: capacity preserved, transition
                # (restart-from-checkpoint onto the spare) still paid
                self.spares -= 1
                st.blocked_until = max(st.blocked_until, now + trans)
                self.downtime += trans
                return
            self.cluster.fail_node(node, now + (ev.repair_s or 0.0))
            self._reconfigure(now, owner)
            st.blocked_until = max(st.blocked_until, now + trans)
            self.downtime += trans
        else:
            # SEV2/SEV3: restart/reattempt in place, no capacity change
            st.blocked_until = max(st.blocked_until, now + trans)
            self.downtime += trans

    def _on_repair(self, now: float, ev: FailureEvent) -> None:
        node = ev.node % len(self.cluster.nodes)
        if self.policy == "fftrainer":
            # the node really failed (unlike megatron's off-book spare):
            # recover it, then either refill the pool (capacity constant
            # again) or fund the down-scaled task's restore
            self.cluster.recover_node(node)
            if not any(st.affected_first for st in self.tasks):
                self.spares += 1
                self.cluster.assign([t.workers for t in self.tasks])
            else:
                self._node_rejoin(now)
            return
        if HOT_SPARES.get(self.policy, 0) and not any(
                st.affected_first for st in self.tasks):
            # no task was down-scaled: the repaired node refills
            # the spare pool instead of joining a task
            self.spares += 1
            return
        self.cluster.recover_node(node)
        self._node_rejoin(now)

    def _on_degradation(self, now: float, ev: DegradationEvent) -> None:
        """Slow node (§4.1): Unicron's statistical monitor flags anything
        past the 1.1x margin and drains the node through the real
        severity workflow (TASK_HANG -> failed restart -> SEV1); policies
        without in-band detection crawl at the slow worker's pace."""
        node = ev.node % len(self.cluster.nodes)
        owner = self.cluster.placement.get(node)
        if owner is None or not self.tasks[owner].active:
            return
        st = self.tasks[owner]
        flagged = int(self._fleet.statuses([owner],
                                           ev.slowdown * st.avg_iter_s)[0])
        in_band = self.policy == "unicron" and not self.ablate_detection
        if in_band and flagged:
            if self.coord is not None:
                case = f"degrade:{node}:{now}"
                self.coord.on_error(case, ErrorKind.TASK_HANG)
                self.coord.on_action_failed(case)   # restart can't fix slow
                self.coord.close_case(case)
            detect = self._detect_s(ErrorKind.TASK_HANG, st.avg_iter_s)
            trans = (self._transition_s(st, detect, Severity.SEV1)
                     + transition.RESPAWN_UNICRON_S)  # the failed restart
            self.cluster.fail_node(node, now + ev.duration_s)
            self._reconfigure(now, owner)
            st.blocked_until = max(st.blocked_until, now + trans)
            self.downtime += trans
            self.n_degraded_drains += 1
            self._push(now + ev.duration_s, "repair",
                       FailureEvent(time=now, node=node,
                                    kind=ErrorKind.LOST_CONNECTION,
                                    repair_s=ev.duration_s))
        else:
            st.slow.append((now, now + ev.duration_s, ev.slowdown))

    def _on_arrival(self, now: float, ev: TaskArrival) -> None:
        st = SimTask(task=ev.task, workers=0,
                     avg_iter_s=getattr(ev, "avg_iter_s", 30.0))
        self.tasks.append(st)
        self._fleet.grow(st.avg_iter_s)
        if self._use_planner():
            self.coord.task_launched(ev.task,
                                     self.cluster.healthy_workers())
            self._ci.append(len(self.coord.entries) - 1)
            self._apply_unicron_plan()
            self.n_reconfigs += 1
        else:
            # baselines: grant from the free pool, node-granular, capped
            # at the task's worker ceiling (workers past it would idle)
            self._ci.append(None)
            assigned = sum(t.workers for t in self.tasks)
            free = max(self._avail_workers() - assigned, 0)
            grant = min(ev.workers_hint, free)
            if ev.task.max_workers is not None:
                grant = min(grant, ev.task.max_workers)
            st.workers = grant - grant % self.gpn
        self.cluster.assign([t.workers for t in self.tasks])

    def _on_rate(self, now: float, ev: RateChangeEvent) -> None:
        """Reward-only objective swap (serving rate step): no workers
        move and no transition is charged — the slot's task is replaced
        so sampling/integration read the new reward rows, and the
        coordinator's lookahead tables refresh so the NEXT failure's
        replan trades against the current offered load."""
        if not 0 <= ev.slot < len(self.tasks):
            return
        st = self.tasks[ev.slot]
        if not st.active:
            return
        old = st.task
        new = dataclasses.replace(old, objective=ev.objective)
        if new == old:
            return
        st.task = new
        self._rate_log.append((now, ev.slot, old, new))
        if self._use_planner():
            ci = self._ci[ev.slot]
            if ci is not None:
                self.coord.task_updated(ci, new)

    def _on_finish(self, now: float, ev: TaskFinish) -> None:
        if not 0 <= ev.slot < len(self.tasks):
            return
        st = self.tasks[ev.slot]
        if not st.active:
            return
        st.active = False
        st.workers = 0
        if self._use_planner():
            ci = self._ci[ev.slot]
            self._ci[ev.slot] = None
            self.coord.task_finished(ci, self.cluster.healthy_workers())
            for slot, other in enumerate(self._ci):
                if other is not None and other > ci:
                    self._ci[slot] = other - 1
            self._apply_unicron_plan()
            self.n_reconfigs += 1
        else:
            self._ci[ev.slot] = None
        self.cluster.assign([t.workers for t in self.tasks])


class VectorSimulator(TraceSimulator):
    """Cluster-scale engine: the same decision handlers (and, through the
    lazy cached planner, float-identical plans) as ``TraceSimulator``, but

    * WAF accumulation is one vectorized numpy pass over the recorded
      worker/blocked/slow step functions instead of per-breakpoint Python;
    * the coordinator runs on a ``PlannerCache`` — lazy plan tables whose
      reward rows and prefix/suffix DPs are reused across rebuilds and,
      when the cache is shared via ``run_monte_carlo``, across seeds.

    Accumulated WAF matches the scalar reference loop up to float
    reordering (rel. ~1e-12; the benchmark asserts 1e-6).
    """

    def __init__(self, tasks: List[Task], assignment: List[int],
                 policy: str, hw=costmodel.A800, n_nodes: int = 16,
                 gpus_per_node: int = 8, *,
                 plan_cache: Optional[PlannerCache] = None,
                 plan_engine: str = "batched",
                 ablate_detection: bool = False,
                 ablate_transition: bool = False,
                 ablate_replan: bool = False,
                 chaos=None):
        if policy == "unicron" and plan_cache is None:
            plan_cache = PlannerCache()
        super().__init__(tasks, assignment, policy, hw, n_nodes,
                         gpus_per_node, plan_cache=plan_cache,
                         plan_engine=plan_engine,
                         ablate_detection=ablate_detection,
                         ablate_transition=ablate_transition,
                         ablate_replan=ablate_replan,
                         chaos=chaos)

    def run(self, trace: Trace, span_s: Optional[float] = None) -> SimResult:
        self._check_shape(trace)
        span = self._span = self._resolve_span(trace, span_s)
        self._heap = heap = self._event_heap(trace, span)
        self._push_crash_events()
        snap_t: List[float] = [0.0]
        snap_w: List[List[int]] = [[st.workers for st in self.tasks]]
        blocks: List[Tuple[int, float, float]] = []  # (slot, start, until)
        n_events = 0
        while heap:
            t, _, kind, ev = heapq.heappop(heap)
            before = [st.blocked_until for st in self.tasks]
            was_active = ([st.active for st in self.tasks]
                          if kind == "finish" else None)
            self._dispatch(t, kind, ev)
            n_events += 1
            for slot, prev in enumerate(before):
                if self.tasks[slot].blocked_until > prev:
                    blocks.append((slot, t,
                                   self.tasks[slot].blocked_until))
            if was_active is not None:
                # a finished task produces no WAF ever again, even if a
                # later baseline rejoin hands its slot idle workers (the
                # scalar loop skips inactive tasks at sampling time)
                for slot, prev in enumerate(was_active):
                    if prev and not self.tasks[slot].active:
                        blocks.append((slot, t, float("inf")))
            snap_t.append(t)
            snap_w.append([st.workers for st in self.tasks])
        acc, timeline = self._integrate_vector(snap_t, snap_w, blocks, span)
        return SimResult(self.policy, acc, timeline, self.n_reconfigs,
                         self.downtime, n_events, self.n_degraded_drains)

    def _integrate_vector(self, snap_t: List[float],
                          snap_w: List[List[int]],
                          blocks: List[Tuple[int, float, float]],
                          span: float):
        """One numpy pass: segment boundaries from events + block expiries
        + slow-window edges; per-segment rates are a gather out of the
        (m, n+1) WAF matrix, masked by blocks, divided by slow factors.
        Rate events promote the matrix to an (E, m, n+1) epoch stack."""
        slows = [st.slow for st in self.tasks]
        if self._rate_log:
            epoch_t, F = _rate_epoch_stack(
                [st.task for st in self.tasks], self._rate_log,
                self._n_total, self.hw)
            return _integrate_segments(snap_t, snap_w, blocks, slows,
                                       span, F * self.eff, epoch_t=epoch_t)
        F = waf_mod.waf_matrix([st.task for st in self.tasks],
                               self._n_total, self.hw) * self.eff
        return _integrate_segments(snap_t, snap_w, blocks, slows, span, F)


class BatchSimulator:
    """Batched multi-policy engine: ONE event pass per trace carrying every
    recovery policy as stacked numpy state.

    Per-policy worker matrices, downtime vectors, blocked-until windows,
    spare pools, node-health/placement maps and WAF accumulators advance
    together: each event is decoded once, detection latencies come from
    the (kinds x policies) ``detection.detection_times`` lookup, transition
    durations from the (policy x component) ``transition.estimate_batch``
    matrix, slow-node checks from the ``detection.FleetMonitor`` ring
    buffer, and consequences land as array ops over the policy axis.
    Planner-backed lanes (``"unicron"``) drive the same lazily-cached
    ``UnicronCoordinator`` call sequence as the scalar reference loop, so
    plans — and therefore per-policy decisions — are identical to a
    per-policy ``TraceSimulator``/``VectorSimulator`` run; accumulated WAF
    agrees to float reordering (~1e-12; the benchmark asserts 1e-6).

    Component ablations stay on the per-policy engines — a lane here is a
    published policy, not an ablation variant."""

    def __init__(self, tasks: List[Task], assignment: List[int],
                 policies: Optional[List[str]] = None, hw=costmodel.A800,
                 n_nodes: int = 16, gpus_per_node: int = 8, *,
                 plan_cache: Optional[PlannerCache] = None,
                 plan_engine: str = "batched",
                 model_cache: Optional[Dict] = None):
        """``model_cache``: share memoized detection/transition model rows
        across simulators (``run_monte_carlo`` passes one per sweep) —
        entries are keyed by task identity, kind and DP degree, so they
        are scenario-independent.  ``plan_engine``: the planner lanes'
        incremental PlanTable engine (see ``TraceSimulator``)."""
        self.policies = list(policies or EFFICIENCY)
        P = len(self.policies)
        self.hw = hw
        self.n_nodes = n_nodes
        self.gpn = gpus_per_node
        self._n_total = n_nodes * gpus_per_node
        self._effs = np.array([EFFICIENCY[p] for p in self.policies])
        self._planner_lane = np.array([p == "unicron"
                                       for p in self.policies])
        self._planner_idx = [p for p, pol in enumerate(self.policies)
                             if pol == "unicron"]
        self._bamboo_lane = np.array([p == "bamboo"
                                      for p in self.policies])
        self._ckpt_lane = np.array(
            [p in transition.CKPT_RESTART_POLICIES for p in self.policies])
        self._fft_lane = np.array([p == "fftrainer"
                                   for p in self.policies])
        self._fft_set = {p for p, pol in enumerate(self.policies)
                         if pol == "fftrainer"}
        self._hier_lane = np.array([p == "hierarchical_ckpt"
                                    for p in self.policies])
        self._hier_idx = [p for p, pol in enumerate(self.policies)
                          if pol == "hierarchical_ckpt"]
        self._red_lane = np.array([p == "redundant"
                                   for p in self.policies])
        self._has_spares = [p in HOT_SPARES for p in self.policies]
        self._spares = [fftrainer_pool(n_nodes) if p == "fftrainer"
                        else HOT_SPARES.get(p, 0) for p in self.policies]
        self._tasks: List[Task] = list(tasks)
        M = len(self._tasks)
        self._avg = np.full(M, 30.0)              # SimTask.avg_iter_s
        self._sbytes = np.array([waf_mod.state_bytes(t)
                                 for t in self._tasks])
        self._workers = np.tile(np.asarray(assignment, dtype=np.int64),
                                (P, 1))
        for p in self._fft_set:
            # fftrainer lanes fund their reserved spare pool up front
            self._workers[p] = fit_assignment(
                list(assignment),
                (n_nodes - self._spares[p]) * gpus_per_node,
                gpus_per_node)
        self._blocked = [[0.0] * M for _ in range(P)]
        self._active = np.ones(M, dtype=bool)
        self._affected = np.zeros((P, M), dtype=bool)
        self._health = np.ones((P, n_nodes), dtype=bool)
        self._slows = [[[] for _ in range(M)] for _ in range(P)]
        # per lane: parallel (slots, starts, untils) lists of block windows
        self._blocks = [([], [], []) for _ in range(P)]
        self.n_reconfigs = np.zeros(P, dtype=np.int64)
        self._downtime = [0.0] * P
        self.n_degraded_drains = np.zeros(P, dtype=np.int64)
        self.n_events = np.zeros(P, dtype=np.int64)
        self._fleet = FleetMonitor.primed(self._avg)
        self._coords: Dict[int, UnicronCoordinator] = {}
        self._cis: Dict[int, List[Optional[int]]] = {}
        cache = plan_cache
        for p, pol in enumerate(self.policies):
            if pol != "unicron":
                continue
            if cache is None:
                cache = PlannerCache()
            self._coords[p] = UnicronCoordinator(
                list(tasks), list(assignment), hw, plan_cache=cache,
                n_cluster_workers=self._n_total,
                workers_per_node=gpus_per_node,
                plan_engine=plan_engine)
            self._cis[p] = list(range(M))
        P_range = list(range(P))
        self._all_list = P_range
        self._all_lanes = np.ones(P, dtype=bool)
        self._n_healthy = [n_nodes] * P          # healthy-node counters
        self._healthy_ids: List[Optional[np.ndarray]] = [None] * P
        self._cums: List[Optional[np.ndarray]] = [None] * P
        self._assigned = [int(self._workers[p].sum()) for p in range(P)]
        self._aff_count = [0] * P
        self._reconfigs = [0] * P
        self._kind_T: Dict[ErrorKind, np.ndarray] = {}
        shared = model_cache if model_cache is not None else {}
        self._uni_cache = shared.setdefault("uni", {})
        self._class_cache = shared.setdefault("class", {})
        # intern tasks once: model-cache keys hash small ints per event,
        # not task dataclasses (a Task hash cascades through its model)
        sigs = shared.setdefault("task_ids", {})
        self._tids = [sigs.setdefault(t, len(sigs)) for t in self._tasks]
        self._task_sigs = sigs
        self._heap: List[tuple] = []
        self._seq = 0
        self._span = float("inf")
        self._mutated = False
        # objective swaps applied so far: (time, slot, old_task, new_task)
        self._rate_log: List[Tuple[float, int, Task, Task]] = []

    # ---- per-lane cluster state -------------------------------------------

    def _healthy_workers(self, p: int) -> int:
        return self._n_healthy[p] * self.gpn

    def _avail_lane(self, p: int) -> int:
        """Assignable capacity: healthy workers minus the lane's
        reserved fftrainer spare pool (scalar ``_avail_workers``)."""
        avail = self._n_healthy[p] * self.gpn
        if p in self._fft_set:
            avail -= self._spares[p] * self.gpn
        return avail

    def _fail_node(self, p: int, node: int) -> None:
        if self._health[p, node]:
            self._health[p, node] = False
            self._n_healthy[p] -= 1
            ids = self._healthy_ids[p]
            if ids is not None:
                ids.pop(bisect_left(ids, node))

    def _recover_node(self, p: int, node: int) -> None:
        if not self._health[p, node]:
            self._health[p, node] = True
            self._n_healthy[p] += 1
            ids = self._healthy_ids[p]
            if ids is not None:
                ids.insert(bisect_left(ids, node), node)

    def _owner_list(self, node: int) -> List[int]:
        """Per-policy owner of ``node`` (-1 = free/unhealthy), computed by
        rank instead of materializing placement maps: ``Cluster.assign``
        packs tasks in index order onto healthy nodes in id order, so the
        owner of the node at healthy-rank r is the first task whose
        cumulative node need exceeds r."""
        out = []
        for p in self._all_list:
            ids = self._healthy_ids[p]
            if ids is None:
                ids = self._healthy_ids[p] = \
                    np.flatnonzero(self._health[p]).tolist()
            r = bisect_left(ids, node)
            if r >= len(ids) or ids[r] != node:
                out.append(-1)                  # unhealthy: no owner
                continue
            cums = self._cums[p]
            if cums is None:
                acc, cums = 0, []
                for x in self._workers[p].tolist():
                    acc += x // self.gpn
                    cums.append(acc)
                self._cums[p] = cums
            if not cums or r >= cums[-1]:
                out.append(-1)                  # past the assigned span
            else:
                out.append(bisect_right(cums, r))
        return out

    def _apply_plan(self, p: int) -> None:
        coord, cis = self._coords[p], self._cis[p]
        w = self._workers[p]
        entries = coord.entries
        vals = np.array([-1 if ci is None else entries[ci].n_workers
                         for ci in cis], dtype=np.int64)
        upd = vals >= 0
        w[upd] = vals[upd]
        self._assigned[p] = int(w.sum())
        self._cums[p] = None
        self._mutated = True

    def _reconfigure_lane(self, p: int, faulted: Optional[int]) -> None:
        n_avail = self._avail_lane(p)
        self._reconfigs[p] += 1
        if p in self._coords:
            ft = self._cis[p][faulted] if faulted is not None else None
            self._coords[p].reconfigure(n_avail, ft)
            self._apply_plan(p)
        elif faulted is not None:
            # baselines only touch the directly-affected task
            w = self._workers[p]
            old = int(w[faulted])
            grant = max(0, min(old, n_avail - (self._assigned[p] - old)))
            grant -= grant % self.gpn
            w[faulted] = grant
            self._assigned[p] += grant - old
            self._cums[p] = None
            self._mutated = True
            if not self._affected[p, faulted]:
                self._affected[p, faulted] = True
                self._aff_count[p] += 1

    def _rejoin_lane(self, p: int) -> None:
        n_avail = self._avail_lane(p)
        self._reconfigs[p] += 1
        if p in self._coords:
            self._coords[p].reconfigure(n_avail, None,
                                        trigger=Trigger.NODE_JOIN)
            self._apply_plan(p)
        elif self._aff_count[p] and n_avail - self._assigned[p] >= self.gpn:
            # restore the first-affected task toward its original size
            aff = self._affected[p]
            slot = int(aff.argmax())
            self._workers[p, slot] += self.gpn
            self._assigned[p] += self.gpn
            self._cums[p] = None
            self._mutated = True
            aff[slot] = False
            self._aff_count[p] -= 1

    # ---- array-native per-event models ------------------------------------

    def _class_matrix(self, kind: ErrorKind) -> np.ndarray:
        """(policy, task) transition-total matrix for one error kind,
        built lazily from one ``estimate_batch`` call per recovery class
        over the task axis (policies of one class share every formula
        input except the owner task) and cached per (kind, task,
        avg_iter_s) in the shared model cache — the iteration time is in
        the key because the same task may be re-admitted with a
        different hint, and the in-band rows scale with it — so churn
        only computes the admitted task's column.  Planner-lane rows are placeholders — their totals depend
        on the live DP degree and are overwritten per event by
        ``_trans_row``."""
        T = self._kind_T.get(kind)
        if T is None:
            M = len(self._tasks)
            cache = self._class_cache
            missing = [i for i in range(M)
                       if (kind, self._tids[i], float(self._avg[i]))
                       not in cache]
            if missing:
                k = len(missing)
                sb = self._sbytes[missing]
                avg = self._avg[missing]
                det = detection_times([kind], avg,
                                      np.zeros(k, dtype=bool))[0]
                det_in = detection_times([kind], avg,
                                         np.ones(k, dtype=bool))[0]
                ckpt = transition.batch_total(transition.estimate_batch(
                    ["megatron"] * k, sb, avg, 1, det))
                dyn = transition.batch_total(transition.estimate_batch(
                    ["oobleck"] * k, sb, avg, 1, det))
                fft = transition.batch_total(transition.estimate_batch(
                    ["fftrainer"] * k, sb, avg, 1, det_in))
                hier = transition.batch_total(transition.estimate_batch(
                    ["hierarchical_ckpt"] * k, sb, avg, 1, det_in))
                hier_l = transition.batch_total(transition.estimate_batch(
                    ["hierarchical_ckpt"] * k, sb, avg, 1, det_in,
                    replica_lost=True))
                for j, i in enumerate(missing):
                    cache[(kind, self._tids[i], float(avg[j]))] = (
                        float(ckpt[j]), float(dyn[j]), float(fft[j]),
                        float(hier[j]), float(hier_l[j]))
            vals = [cache[(kind, tid, float(a))]
                    for tid, a in zip(self._tids, self._avg)]
            ckpt_v = np.array([v[0] for v in vals])
            dyn_v = np.array([v[1] for v in vals])
            fft_v = np.array([v[2] for v in vals])
            hier_v = np.array([v[3] for v in vals])
            if classify(kind)[1] is not Severity.SEV1:
                # bamboo's redundancy rides through SEV2/3 failures
                dyn_bam = np.zeros(M)
            else:
                dyn_bam = dyn_v
            # hierarchical rows bake replica_lost=False; ``_trans_row``
            # overrides a lane from the cache's tier-demoted totals when
            # the event really took the ring neighbor too.  redundant
            # rows are identically zero (continuation).
            T = np.where(
                self._ckpt_lane[:, None], ckpt_v[None, :],
                np.where(self._bamboo_lane[:, None], dyn_bam[None, :],
                         np.where(self._fft_lane[:, None], fft_v[None, :],
                                  np.where(self._hier_lane[:, None],
                                           hier_v[None, :],
                                           np.where(self._red_lane[:, None],
                                                    0.0,
                                                    dyn_v[None, :])))))
            self._kind_T[kind] = T
        return T

    def _trans_row(self, kind: ErrorKind, owners: List[int],
                   rl: Optional[np.ndarray] = None) -> List[float]:
        """Detection + transition totals per policy: one gather out of the
        per-kind (policy, task) class matrix, with planner lanes filled
        from a (kind, owner, dp, replica_lost)-memoized
        ``estimate_unicron`` total — state sizes and iteration times are
        fixed per task, so those keys pin every input of the scalar
        formulas.  ``rl`` is the per-lane replica-loss vector (SEV1
        events only): hierarchical lanes swap to the cache's
        tier-demoted totals, planner lanes carry it into the key."""
        T = self._class_matrix(kind)
        tot = [T[p, o if o >= 0 else 0] for p, o in enumerate(owners)]
        if rl is not None:
            for p in self._hier_idx:
                if rl[p]:
                    o = owners[p] if owners[p] >= 0 else 0
                    tot[p] = self._class_cache[
                        (kind, self._tids[o], float(self._avg[o]))][4]
        for p in self._planner_idx:
            o = owners[p]
            if o < 0:
                o = 0
            dp = int(self._workers[p, o]) // 8
            rl_p = bool(rl[p]) if rl is not None else False
            # the key carries the slot's iteration time too: the same Task
            # may be admitted with different avg_iter_s hints, and both
            # detection and recompute scale with it
            ukey = (kind, self._tids[o], dp, float(self._avg[o]), rl_p)
            val = self._uni_cache.get(ukey)
            if val is None:
                det = detection_time(kind, float(self._avg[o]),
                                     unicron=True)
                val = transition.estimate_unicron(
                    float(self._sbytes[o]), float(self._avg[o]),
                    dp_degree=max(dp, 1), detect_s=det,
                    lookup_hit=True, replica_lost=rl_p).total
                self._uni_cache[ukey] = val
            tot[p] = val
        return tot

    def _block_and_charge(self, now: float, lanes: List[int],
                          owners: List[int],
                          trans: List[float]) -> None:
        downtime = self._downtime
        for p in lanes:
            slot = owners[p]
            tr = trans[p]
            row = self._blocked[p]
            until = now + tr
            if until > row[slot]:
                row[slot] = until
                bs, bt, bu = self._blocks[p]
                bs.append(slot)
                bt.append(now)
                bu.append(until)
            downtime[p] += tr

    # ---- event handlers ----------------------------------------------------

    def _on_failure(self, now: float, ev: FailureEvent,
                    mask: np.ndarray) -> None:
        node = ev.node % self.n_nodes
        owners = self._owner_list(node)
        if -1 in owners:
            # unplaced node: round-robin over tasks with workers
            for p in self._all_list:
                if owners[p] < 0 and mask[p]:
                    cand = np.flatnonzero(self._workers[p] > 0)
                    owners[p] = (int(cand[node % cand.size])
                                 if cand.size else -1)
        if mask is self._all_lanes:
            valid = [p for p in self._all_list if owners[p] >= 0]
        else:
            valid = [p for p in self._all_list
                     if mask[p] and owners[p] >= 0]
        if not valid:
            return
        rl = None
        if ev.severity is Severity.SEV1:
            # replica loss per lane: the in-memory ring neighbor of the
            # failed node is already unhealthy (read BEFORE this event's
            # fail lands, matching the scalar reference)
            nb = (node + 1) % self.n_nodes
            rl = ~self._health[:, nb]
        trans = self._trans_row(ev.kind, owners, rl)
        if ev.severity is Severity.SEV1:
            # hot spare substitutes: capacity preserved, transition still
            # paid; everyone else drains the node and replans.  fftrainer
            # really loses the node and burns a reserved spare (healthy-1,
            # pool-1: assignable capacity constant) until the pool is dry
            spares = self._spares
            for p in valid:
                if p in self._fft_set:
                    self._fail_node(p, node)
                    if spares[p] > 0:
                        spares[p] -= 1
                    else:
                        self._reconfigure_lane(p, owners[p])
                elif spares[p] > 0:
                    spares[p] -= 1
                else:
                    self._fail_node(p, node)
                    self._reconfigure_lane(p, owners[p])
        self._block_and_charge(now, valid, owners, trans)

    def _on_repair(self, now: float, ev: FailureEvent,
                   mask: np.ndarray) -> None:
        node = ev.node % self.n_nodes
        lanes = (self._all_list if mask is self._all_lanes
                 else np.flatnonzero(mask).tolist())
        for p in lanes:
            if p in self._fft_set:
                # the node really failed: recover it, then refill the
                # pool (capacity constant) or fund the affected task
                self._recover_node(p, node)
                if not self._aff_count[p]:
                    self._spares[p] += 1
                else:
                    self._rejoin_lane(p)
                continue
            if self._has_spares[p] and not self._aff_count[p]:
                # no task was down-scaled: the repaired node refills
                # the spare pool instead of joining a task
                self._spares[p] += 1
                continue
            self._recover_node(p, node)
            self._rejoin_lane(p)

    def _on_degradation(self, now: float, ev: DegradationEvent,
                        mask: np.ndarray) -> None:
        node = ev.node % self.n_nodes
        owners = self._owner_list(node)
        valid = [p for p in self._all_list
                 if mask[p] and owners[p] >= 0 and self._active[owners[p]]]
        if not valid:
            return
        o_arr = np.array([owners[p] for p in valid])
        codes = self._fleet.statuses(o_arr, ev.slowdown * self._avg[o_arr])
        drain = set()
        for i, p in enumerate(valid):
            if codes[i] and self._planner_lane[p]:
                drain.add(p)
        for p in drain:
            owner = owners[p]
            coord = self._coords[p]
            case = f"degrade:{node}:{now}"
            coord.on_error(case, ErrorKind.TASK_HANG)
            coord.on_action_failed(case)       # restart can't fix slow
            coord.close_case(case)
            avg = float(self._avg[owner])
            det = detection_time(ErrorKind.TASK_HANG, avg, unicron=True)
            dp = max(int(self._workers[p, owner]) // 8, 1)
            cost = transition.estimate_batch(
                ["unicron"], self._sbytes[owner], avg, dp, det)
            trans = (float(transition.batch_total(cost)[0])
                     + transition.RESPAWN_UNICRON_S)  # the failed restart
            self._fail_node(p, node)
            self._reconfigure_lane(p, owner)
            tr = [0.0] * len(self.policies)
            tr[p] = trans
            self._block_and_charge(now, [p], owners, tr)
            self.n_degraded_drains[p] += 1
            one = np.zeros(len(self.policies), dtype=bool)
            one[p] = True
            self._push(now + ev.duration_s, "repair",
                       FailureEvent(time=now, node=node,
                                    kind=ErrorKind.LOST_CONNECTION,
                                    repair_s=ev.duration_s), one)
        for p in valid:
            if p not in drain:
                self._slows[p][owners[p]].append(
                    (now, now + ev.duration_s, ev.slowdown))

    def _on_arrival(self, now: float, ev: TaskArrival,
                    mask: np.ndarray) -> None:
        P = len(self.policies)
        avg = getattr(ev, "avg_iter_s", 30.0)
        self._tasks.append(ev.task)
        self._avg = np.append(self._avg, avg)
        self._sbytes = np.append(self._sbytes,
                                 waf_mod.state_bytes(ev.task))
        self._active = np.append(self._active, True)
        self._workers = np.concatenate(
            [self._workers, np.zeros((P, 1), dtype=np.int64)], axis=1)
        for row in self._blocked:
            row.append(0.0)
        self._affected = np.concatenate(
            [self._affected, np.zeros((P, 1), dtype=bool)], axis=1)
        for p in range(P):
            self._slows[p].append([])
        self._fleet.grow(avg)
        self._tids.append(self._task_sigs.setdefault(ev.task,
                                                     len(self._task_sigs)))
        self._kind_T.clear()                   # task axis grew a column
        slot = len(self._tasks) - 1
        lanes = (self._all_list if mask is self._all_lanes
                 else np.flatnonzero(mask).tolist())
        for p, coord in self._coords.items():
            if p not in lanes:
                continue
            coord.task_launched(ev.task, self._healthy_workers(p),
                                avg_iter_s=avg)
            self._cis[p].append(len(coord.entries) - 1)
            self._apply_plan(p)
            self._reconfigs[p] += 1
        blane_list = [p for p in lanes if not self._planner_lane[p]]
        if blane_list:
            # baselines: grant from the free pool, node-granular, capped
            assigned = np.array([self._assigned[p] for p in blane_list])
            avail = np.array([self._avail_lane(p) for p in blane_list])
            grant = np.minimum(ev.workers_hint,
                               np.maximum(avail - assigned, 0))
            if ev.task.max_workers is not None:
                grant = np.minimum(grant, ev.task.max_workers)
            grant -= grant % self.gpn
            self._workers[blane_list, slot] = grant
            for p, g in zip(blane_list, grant):
                self._assigned[p] += int(g)
        for p in self._all_list:
            self._cums[p] = None          # the task axis grew a slot
        self._mutated = True

    def _on_finish(self, now: float, ev: TaskFinish,
                   mask: np.ndarray) -> None:
        if not 0 <= ev.slot < len(self._tasks):
            return
        if not self._active[ev.slot]:
            return
        self._active[ev.slot] = False
        lanes = (self._all_list if mask is self._all_lanes
                 else np.flatnonzero(mask).tolist())
        old = self._workers[:, ev.slot]
        for p in lanes:
            self._assigned[p] -= int(old[p])
            self._cums[p] = None
            # finished tasks produce no WAF ever again, even if a later
            # baseline rejoin hands the slot idle workers (scalar skips
            # inactive tasks at sampling time)
            bs, bt, bu = self._blocks[p]
            bs.append(ev.slot)
            bt.append(now)
            bu.append(float("inf"))
        self._workers[lanes, ev.slot] = 0
        self._mutated = True
        for p, coord in self._coords.items():
            if p not in lanes:
                continue
            cis = self._cis[p]
            ci = cis[ev.slot]
            cis[ev.slot] = None
            coord.task_finished(ci, self._healthy_workers(p))
            for s, other in enumerate(cis):
                if other is not None and other > ci:
                    cis[s] = other - 1
            self._apply_plan(p)
            self._reconfigs[p] += 1

    def _on_rate(self, now: float, ev: RateChangeEvent,
                 mask: np.ndarray) -> None:
        """Reward-only objective swap (see ``TraceSimulator._on_rate``).
        The task list is shared across lanes, so a rate step always
        applies fleet-wide; only planner lanes carry extra state (their
        coordinators' lookahead tables refresh for the next replan)."""
        if not 0 <= ev.slot < len(self._tasks):
            return
        if not self._active[ev.slot]:
            return
        old = self._tasks[ev.slot]
        new = dataclasses.replace(old, objective=ev.objective)
        if new == old:
            return
        self._tasks[ev.slot] = new
        self._sbytes[ev.slot] = waf_mod.state_bytes(new)
        self._tids[ev.slot] = self._task_sigs.setdefault(
            new, len(self._task_sigs))
        self._kind_T.clear()               # transition column changed
        self._rate_log.append((now, ev.slot, old, new))
        lanes = (self._all_list if mask is self._all_lanes
                 else np.flatnonzero(mask).tolist())
        for p, coord in self._coords.items():
            if p not in lanes:
                continue
            ci = self._cis[p][ev.slot]
            if ci is not None:
                coord.task_updated(ci, new)

    # ---- main loop ---------------------------------------------------------

    def _push(self, t: float, kind: str, payload: object,
              lanes: np.ndarray) -> None:
        if t <= self._span:
            self._seq += 1
            heapq.heappush(self._heap, (t, self._seq, kind, payload, lanes))

    def _dispatch(self, now: float, kind: str, ev: object,
                  mask: np.ndarray) -> None:
        if kind == "fail":
            self._on_failure(now, ev, mask)
        elif kind == "repair":
            self._on_repair(now, ev, mask)
        elif kind == "degrade":
            self._on_degradation(now, ev, mask)
        elif kind == "arrive":
            self._on_arrival(now, ev, mask)
        elif kind == "finish":
            self._on_finish(now, ev, mask)
        elif kind == "rate":
            self._on_rate(now, ev, mask)

    def run(self, trace: Trace,
            span_s: Optional[float] = None) -> Dict[str, SimResult]:
        _check_trace_shape(trace, self.n_nodes, self.gpn)
        span = self._span = _resolve_trace_span(trace, span_s)
        entries, self._seq = _event_entries(trace, span)
        self._heap = [(t, s, k, p, None) for t, s, k, p in entries]
        heapq.heapify(self._heap)
        all_lanes = self._all_lanes
        n_shared = 0
        snap_t: List[float] = [0.0]
        snaps: List[np.ndarray] = [self._workers.copy()]
        event_t: List[float] = []
        while self._heap:
            t, _, kind, ev, lanes = heapq.heappop(self._heap)
            if lanes is None:
                self._dispatch(t, kind, ev, all_lanes)
                n_shared += 1
            else:
                self._dispatch(t, kind, ev, lanes)
                self.n_events += lanes
            event_t.append(t)
            if self._mutated:               # workers changed: new step
                snap_t.append(t)
                snaps.append(self._workers.copy())
                self._mutated = False
        self.n_events += n_shared
        self.n_reconfigs = np.array(self._reconfigs, dtype=np.int64)
        self.downtime = np.array(self._downtime)
        if self._rate_log:
            epoch_t, F = _rate_epoch_stack(self._tasks, self._rate_log,
                                           self._n_total, self.hw)
        else:
            epoch_t = None
            F = waf_mod.waf_matrix(self._tasks, self._n_total, self.hw)
        accs, timelines = _integrate_policies(snap_t, snaps, self._blocks,
                                              self._slows, span, F,
                                              self._effs, event_t,
                                              epoch_t=epoch_t)
        return {pol: SimResult(pol, float(accs[p]), timelines[p],
                               self._reconfigs[p],
                               self._downtime[p],
                               int(self.n_events[p]),
                               int(self.n_degraded_drains[p]))
                for p, pol in enumerate(self.policies)}


def run_policies(tasks: List[Task], assignment: List[int],
                 trace: Trace,
                 policies: Optional[List[str]] = None,
                 hw=costmodel.A800) -> Dict[str, SimResult]:
    out = {}
    for p in policies or list(EFFICIENCY):
        sim = TraceSimulator(tasks, list(assignment), p, hw)
        out[p] = sim.run(trace)
    return out


def _mc_result(policy: str, results: List[SimResult],
               wall: float) -> MonteCarloResult:
    wafs = [r.accumulated_waf for r in results]
    arr = np.array(wafs)
    return MonteCarloResult(policy, float(arr.mean()), float(arr.std()),
                            wafs, wall,
                            sum(r.n_reconfigs for r in results),
                            sum(r.downtime_s for r in results))


def run_monte_carlo(tasks: List[Task], assignment: List[int],
                    scenario_fn, seeds, policies: Optional[List[str]] = None,
                    hw=costmodel.A800, n_nodes: int = 16,
                    gpus_per_node: int = 8,
                    plan_cache: Optional[PlannerCache] = None,
                    threads: Optional[int] = None,
                    engine: str = "batched",
                    plan_engine: str = "batched"
                    ) -> Dict[str, MonteCarloResult]:
    """Batched Monte-Carlo sweep: ``scenario_fn(seed)`` generates one
    seeded ``ClusterScenario`` per seed; all runs share ONE
    ``PlannerCache`` — a cluster state reached in any seed is never
    re-planned in another.

    ``engine="batched"`` (default) runs each seed ONCE through
    ``BatchSimulator`` with every policy stacked on the policy axis; each
    policy's ``wall_s`` is its even share of the joint pass, so suite
    totals still sum correctly.  ``engine="vector"`` keeps the PR-2/3
    per-(policy, seed) ``VectorSimulator`` path — the measured baseline
    of the batched engine.  Both produce identical decisions (shared
    planner) and WAF totals equal to float reordering.

    ``threads`` applies to the vector engine only — with
    ``engine="vector"``, seeds of one policy may run on a thread pool
    (numpy's convolutions release the GIL): results are deterministic
    regardless of scheduling because every cache entry is fully
    determined by its key.  The batched engine is one sequential pass
    per seed and ignores ``threads``.

    ``plan_engine`` selects the planner lanes' incremental PlanTable
    engine (``"batched"`` default — level-synchronous stacked merges
    with lazy traceback, the cold-path win ``bench_cluster_sim``'s
    ``cold_*_wall_s`` columns measure; ``"segtree"``/``"chain"`` keep
    the per-merge baselines).  Plans are float-identical across
    engines, so WAF totals do not depend on the choice."""
    if engine not in ("batched", "vector"):
        raise ValueError(f"unknown Monte-Carlo engine {engine!r}")
    cache = plan_cache if plan_cache is not None else PlannerCache()
    scenarios = [scenario_fn(s) for s in seeds]
    pols = list(policies or EFFICIENCY)
    out: Dict[str, MonteCarloResult] = {}

    if engine == "batched":
        per_policy: Dict[str, List[SimResult]] = {p: [] for p in pols}
        model_cache: Dict = {}
        t0 = _time.perf_counter()
        for sc in scenarios:
            sim = BatchSimulator(tasks, list(assignment), pols, hw,
                                 n_nodes=n_nodes,
                                 gpus_per_node=gpus_per_node,
                                 plan_cache=cache,
                                 plan_engine=plan_engine,
                                 model_cache=model_cache)
            for p, res in sim.run(sc).items():
                per_policy[p].append(res)
        share = (_time.perf_counter() - t0) / max(len(pols), 1)
        return {p: _mc_result(p, per_policy[p], share) for p in pols}

    # engine == "vector": per-(policy, seed) runs over the shared cache.
    # Sequential by default: on few-core hosts the GIL-held decision glue
    # plus duplicated cold builds outweigh the parallel convolutions.
    n_threads = threads or 1

    def one(policy, scenario):
        sim = VectorSimulator(tasks, list(assignment), policy, hw,
                              n_nodes=n_nodes,
                              gpus_per_node=gpus_per_node,
                              plan_cache=cache,
                              plan_engine=plan_engine)
        return sim.run(scenario)

    for p in pols:
        t0 = _time.perf_counter()
        if n_threads > 1 and len(scenarios) > 1:
            with ThreadPoolExecutor(max_workers=n_threads) as pool:
                results = list(pool.map(lambda sc: one(p, sc), scenarios))
        else:
            results = [one(p, sc) for sc in scenarios]
        out[p] = _mc_result(p, results, _time.perf_counter() - t0)
    return out
