"""Discrete-event cluster simulator (§7.5) driving the REAL Unicron code.

The simulator replaces wall-clock time and GPUs only: detection latencies
come from ``core.detection``, recovery decisions from the severity
workflow, reconfiguration plans from the real DP planner through
``UnicronCoordinator``, and transition durations from ``core.transition``.
Baselines are recovery *policies* with their published behaviours:

  megatron   restart-from-checkpoint + hot spare; 30-min watchdog
             detection for non-node-loss failures; reconfigures only the
             affected task (down-scales on node loss until repair).
  oobleck    dynamic reconfiguration (no checkpoint reload), pipeline
             templates; lower normal-case efficiency (Fig. 3a).
  bamboo     redundant computation: keeps running through failures but
             pays a constant throughput tax; lowest efficiency.
  varuna     job morphing + checkpoint restart; low efficiency.
  unicron    everything in this repo: in-band detection, lookup-table
             plans over ALL tasks, partial-result reuse.

WAF is integrated over the trace (the Fig. 11 y-axis); ``accumulated``
at the end of the run is the Fig. 11b/d number.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import costmodel, transition, waf as waf_mod
from repro.core.cluster import Cluster
from repro.core.coordinator import UnicronCoordinator
from repro.core.detection import ErrorKind, Severity, classify, detection_time
from repro.core.traces import FailureEvent, trace_span
from repro.core.waf import Task

# Normal-case training efficiency relative to Megatron (Figure 3a: the
# resilience-first systems run at a fraction of Megatron's throughput).
EFFICIENCY = {
    "unicron": 1.00,        # inherits all Megatron optimizations
    "megatron": 1.00,
    "oobleck": 0.38,
    "bamboo": 0.30,         # includes the redundant-computation tax
    "varuna": 0.29,
}

# Megatron's deployment keeps hot-spare nodes that substitute for failed
# ones (paper §7.3 footnote 1): capacity is preserved while a spare is
# available, at the cost of idling the spare.  Unicron instead re-plans
# and uses every healthy node productively.
HOT_SPARES = {"megatron": 1}


@dataclass
class SimTask:
    task: Task
    workers: int
    avg_iter_s: float = 30.0
    blocked_until: float = 0.0          # transitioning/restarting until t
    affected_first: bool = False        # baselines: reconfigure priority


@dataclass
class SimResult:
    policy: str
    accumulated_waf: float              # integral of WAF dt
    timeline: List[Tuple[float, float]]  # (t, cluster WAF) samples
    n_reconfigs: int
    downtime_s: float                   # total task-seconds blocked


class TraceSimulator:
    def __init__(self, tasks: List[Task], assignment: List[int],
                 policy: str, hw=costmodel.A800, n_nodes: int = 16,
                 gpus_per_node: int = 8, *,
                 ablate_detection: bool = False,
                 ablate_transition: bool = False,
                 ablate_replan: bool = False):
        """``ablate_*``: component ablations for the unicron policy —
        swap one Unicron mechanism for its baseline counterpart to
        measure that component's contribution (benchmarks/bench_ablation).
        """
        self.policy = policy
        self.ablate_detection = ablate_detection
        self.ablate_transition = ablate_transition
        self.ablate_replan = ablate_replan
        self.hw = hw
        self.eff = EFFICIENCY[policy]
        # WAF timeline sampling reads F(t, ·) straight off the memoized
        # cost-model curves; one vector per distinct task for the whole run
        self._n_total = n_nodes * gpus_per_node
        self._waf_curves: Dict[Task, object] = {}
        self.cluster = Cluster(n_nodes, gpus_per_node)
        self.gpn = gpus_per_node
        self.tasks = [SimTask(task=t, workers=x)
                      for t, x in zip(tasks, assignment)]
        self.cluster.assign([t.workers for t in self.tasks])
        self.coord: Optional[UnicronCoordinator] = None
        if policy == "unicron":
            self.coord = UnicronCoordinator(tasks, assignment, hw)
        self.spares = HOT_SPARES.get(policy, 0)
        self.n_reconfigs = 0
        self.downtime = 0.0

    # ---- instantaneous cluster WAF ----------------------------------------

    def _waf(self, task: Task, x: int) -> float:
        """F(t, x) via the per-task curve (vector lookup; scalar fallback
        for worker counts beyond the cluster size)."""
        if 0 <= x <= self._n_total:
            F = self._waf_curves.get(task)
            if F is None:
                F = waf_mod.waf_curve(task, self._n_total, self.hw)
                self._waf_curves[task] = F
            return float(F[x])
        return waf_mod.waf(task, x, self.hw)

    def cluster_waf(self, now: float) -> float:
        total = 0.0
        for st in self.tasks:
            if now < st.blocked_until or st.workers <= 0:
                continue
            total += self._waf(st.task, st.workers) * self.eff
        return total

    # ---- policy behaviours -------------------------------------------------

    def _detect_s(self, kind: ErrorKind, avg_iter: float) -> float:
        unicron = self.policy == "unicron" and not self.ablate_detection
        return detection_time(kind, avg_iter, unicron=unicron)

    def _transition_s(self, st: SimTask, detect_s: float,
                      sev: Severity) -> float:
        state_bytes = 16.0 * st.task.model.n_params
        if self.policy == "unicron" and self.ablate_transition:
            c = transition.estimate_baseline(
                state_bytes, detect_s, dynamic_reconfig=False,
                ckpt_restart=True)
            return c.total
        if self.policy == "unicron":
            dp = max(st.workers // 8, 1)
            c = transition.estimate_unicron(
                state_bytes, st.avg_iter_s, dp_degree=dp, detect_s=detect_s,
                lookup_hit=True)
            return c.total
        if self.policy in ("megatron", "varuna"):
            c = transition.estimate_baseline(
                state_bytes, detect_s, dynamic_reconfig=False,
                ckpt_restart=True)
            return c.total
        # oobleck / bamboo: dynamic reconfiguration
        c = transition.estimate_baseline(
            state_bytes, detect_s, dynamic_reconfig=True, ckpt_restart=False)
        # bamboo's redundancy rides through SEV2/3 without interruption
        if self.policy == "bamboo" and sev is not Severity.SEV1:
            return 0.0
        return c.total

    def _reconfigure(self, now: float, faulted_task: Optional[int]) -> None:
        """Node-count change: redistribute workers."""
        n_avail = self.cluster.healthy_workers()
        self.n_reconfigs += 1
        if self.policy == "unicron" and not self.ablate_replan:
            plan = self.coord.reconfigure(n_avail, faulted_task)
            for st, x in zip(self.tasks, plan.assignment):
                st.workers = x
        else:
            # baselines only touch the directly-affected task: it shrinks
            # to what is left after the others keep their nodes
            others = sum(st.workers for i, st in enumerate(self.tasks)
                         if i != faulted_task)
            if faulted_task is not None:
                st = self.tasks[faulted_task]
                st.workers = max(0, min(st.workers, n_avail - others))
                st.workers -= st.workers % self.gpn
                st.affected_first = True
        self.cluster.assign([t.workers for t in self.tasks])

    def _node_rejoin(self, now: float) -> None:
        n_avail = self.cluster.healthy_workers()
        self.n_reconfigs += 1
        if self.policy == "unicron" and not self.ablate_replan:
            plan = self.coord.reconfigure(n_avail, None)
            for st, x in zip(self.tasks, plan.assignment):
                st.workers = x
        else:
            # restore the first-affected task toward its original size
            assigned = sum(st.workers for st in self.tasks)
            spare = n_avail - assigned
            for st in self.tasks:
                if st.affected_first and spare >= self.gpn:
                    st.workers += self.gpn
                    spare -= self.gpn
                    st.affected_first = False
                    break
        self.cluster.assign([t.workers for t in self.tasks])

    # ---- main loop -----------------------------------------------------------

    def run(self, trace: List[FailureEvent],
            span_s: Optional[float] = None) -> SimResult:
        span = span_s or trace_span(trace)
        events: List[Tuple[float, str, object]] = [
            (e.time, "fail", e) for e in trace if e.time <= span]
        for e in trace:
            if e.repair_s is not None and e.time + e.repair_s <= span:
                events.append((e.time + e.repair_s, "repair", e))
        events.sort(key=lambda x: x[0])

        acc, last_t = 0.0, 0.0
        timeline: List[Tuple[float, float]] = [(0.0, self.cluster_waf(0.0))]
        for t, kind, ev in events:
            # integrate WAF piecewise (block expiries create breakpoints)
            breaks = sorted({st.blocked_until for st in self.tasks
                             if last_t < st.blocked_until < t} | {t})
            for b in breaks:
                acc += self.cluster_waf((last_t + b) / 2) * (b - last_t)
                last_t = b
            if kind == "fail":
                self._on_failure(t, ev)
            else:
                node = ev.node % len(self.cluster.nodes)
                if HOT_SPARES.get(self.policy, 0) and not any(
                        st.affected_first for st in self.tasks):
                    # no task was down-scaled: the repaired node refills
                    # the spare pool instead of joining a task
                    self.spares += 1
                    continue
                self.cluster.recover_node(node)
                self._node_rejoin(t)
            timeline.append((t, self.cluster_waf(t)))
        # tail
        breaks = sorted({st.blocked_until for st in self.tasks
                         if last_t < st.blocked_until < span} | {span})
        for b in breaks:
            acc += self.cluster_waf((last_t + b) / 2) * (b - last_t)
            last_t = b
        timeline.append((span, self.cluster_waf(span)))
        return SimResult(self.policy, acc, timeline, self.n_reconfigs,
                         self.downtime)

    def _on_failure(self, now: float, ev: FailureEvent) -> None:
        node = ev.node % len(self.cluster.nodes)
        sev = ev.severity
        owner = self.cluster.placement.get(node)
        if owner is None:
            owners = [i for i, st in enumerate(self.tasks) if st.workers > 0]
            owner = owners[node % len(owners)] if owners else None
        if owner is None:
            return
        st = self.tasks[owner]
        detect = self._detect_s(ev.kind, st.avg_iter_s)
        trans = self._transition_s(st, detect, sev)
        if sev is Severity.SEV1:
            if self.spares > 0:
                # hot spare substitutes: capacity preserved, transition
                # (restart-from-checkpoint onto the spare) still paid
                self.spares -= 1
                st.blocked_until = max(st.blocked_until, now + trans)
                self.downtime += trans
                return
            self.cluster.fail_node(node, now + (ev.repair_s or 0.0))
            self._reconfigure(now, owner)
            st.blocked_until = max(st.blocked_until, now + trans)
            self.downtime += trans
        else:
            # SEV2/SEV3: restart/reattempt in place, no capacity change
            st.blocked_until = max(st.blocked_until, now + trans)
            self.downtime += trans


def run_policies(tasks: List[Task], assignment: List[int],
                 trace: List[FailureEvent],
                 policies: Optional[List[str]] = None,
                 hw=costmodel.A800) -> Dict[str, SimResult]:
    out = {}
    for p in policies or list(EFFICIENCY):
        sim = TraceSimulator(tasks, list(assignment), p, hw)
        out[p] = sim.run(trace)
    return out
