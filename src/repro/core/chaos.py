"""Chaos-engineering layer for the coordinator<->agent control plane.

The status monitor in ``kvstore.py`` is a perfect in-process store; real
fleets (ByteDance's robust-training report, Meta's reliability study —
PAPERS.md) see lost heartbeats, delayed/duplicated reports, switch
partitions and coordinator restarts as the *norm*.  This module injects
exactly those faults from a seeded :class:`ChaosSchedule` so the
hardened protocol (at-least-once publish, idempotent consume, journal +
incarnation fencing — see the ``kvstore.py`` docstring) can be driven to
its convergence property: after the chaos horizon passes, the cluster
assignment and WAF must equal the chaos-free run's within 1e-6.

Fault model
-----------

* **drop / delay / duplicate** apply per message to node-*bound* clients
  (``ChaosKVStore.bind``) — the agent report path.  Delayed messages sit
  in a delivery heap pumped by ``advance``/``expire`` and land out of
  send order, which is how *reordering* arises.  Heartbeat keys
  (``/nodes/``) are exempt from per-message faults: the lease keepalive
  channel retries below this abstraction, and its failure mode is the
  partition.
* **partitions** are per-node windows during which every operation of
  that node's bound client raises ``KVUnavailable`` — heartbeats
  included, so the coordinator's lease expiry (correctly) raises
  LOST_CONNECTION and later revokes it when the node reappears.
* **coordinator crashes** (``crash_times``) discard the coordinator and
  control-loop process state; recovery goes through
  ``UnicronCoordinator.recover`` + the KV-backed consumption markers.

Unbound operations (the co-located coordinator / control loop) are
always faithful — chaos models the agent->monitor network, not the
monitor's own storage.

Convergence invariants (enforced by ``scenarios.chaos_schedule`` for
generated schedules, documented here for hand-built ones):

* world events are spaced further apart than the worst-case delivery lag
  (max delay + partition span + retry backoff cap + detection latency),
  so chaos shifts *when* each decision fires, never its inputs;
* partition windows are disjoint and avoid churn/failure event windows,
  so a false-positive drain is always revoked by the exact pre-drain
  assignment (no epoch or capacity drift in between);
* the control loop's marker retention exceeds max delay + partition
  span, so late duplicates always meet their processed marker.
"""
from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.agent import UnicronAgent, heartbeat_cohort
from repro.core.cluster import Cluster
from repro.core.controlloop import ControlLoop
from repro.core.coordinator import UnicronCoordinator
from repro.core.detection import ErrorKind
from repro.core.kvstore import KVStore, KVUnavailable, PLAN_EPOCH_KEY
from repro.core.waf import Task


@dataclass(frozen=True)
class ChaosSchedule:
    """One seeded chaos trace for the control plane.

    ``end_s`` is the injection horizon: no drop/delay/dup after it (the
    settle window the convergence property needs).  Partitions and
    crashes carry their own times and may end later than ``end_s``; the
    overall quiet point is :meth:`horizon`."""
    seed: int = 0
    drop_p: float = 0.0
    delay_p: float = 0.0
    max_delay_s: float = 0.0
    dup_p: float = 0.0
    # (node, start_s, end_s) windows; generators keep them disjoint
    partitions: Tuple[Tuple[int, float, float], ...] = ()
    crash_times: Tuple[float, ...] = ()
    end_s: float = 0.0

    def horizon(self) -> float:
        """Last instant any injection can still be active."""
        h = self.end_s + self.max_delay_s
        for _, _, end in self.partitions:
            h = max(h, end)
        for t in self.crash_times:
            h = max(h, t)
        return h


class _ChaosClient:
    """A node's view of the status monitor: same interface as
    ``KVStore`` for the ops agents use, with the schedule applied."""

    def __init__(self, store: "ChaosKVStore", node_id: int):
        self._store = store
        self.node_id = node_id

    def put(self, key, value, *, ttl=None, now=0.0):
        self._store.chaotic_put(self.node_id, key, value, ttl=ttl, now=now)

    def get(self, key, default=None):
        self._store.check_link(self.node_id, self._store.clock)
        return self._store.get(key, default)

    def prefix(self, pre):
        self._store.check_link(self.node_id, self._store.clock)
        return self._store.prefix(pre)

    def delete(self, key):
        self._store.check_link(self.node_id, self._store.clock)
        self._store.delete(key)

    def cas(self, key, expect, value):
        self._store.check_link(self.node_id, self._store.clock)
        return self._store.cas(key, expect, value)


class ChaosKVStore(KVStore):
    """``KVStore`` whose node-bound clients traverse a chaotic network.

    The store itself (unbound access) is faithful; ``bind(node)``
    returns the client agents must use.  ``advance(now)`` delivers
    matured delayed/duplicated messages and is folded into ``expire`` so
    the control loop's normal tick pumps the network."""

    def __init__(self, schedule: ChaosSchedule):
        super().__init__()
        self.schedule = schedule
        self._rng = random.Random(schedule.seed)
        self._pending: List[Tuple[float, int, str, object,
                                  Optional[float], float]] = []
        self._pseq = 0
        self.clock = 0.0                   # last time seen by advance()
        self.stats = {"dropped": 0, "delayed": 0, "duplicated": 0,
                      "rejected": 0, "delivered": 0}

    # ---- topology ----------------------------------------------------------

    def bind(self, node_id: int) -> _ChaosClient:
        return _ChaosClient(self, node_id)

    def partitioned(self, node_id: int, now: float) -> bool:
        return any(n == node_id and start <= now < end
                   for n, start, end in self.schedule.partitions)

    def check_link(self, node_id: int, now: float) -> None:
        if self.partitioned(node_id, now):
            self.stats["rejected"] += 1
            raise KVUnavailable(f"node {node_id} partitioned at {now:.1f}")

    # ---- chaotic write path ------------------------------------------------

    def chaotic_put(self, node_id: int, key: str, value, *,
                    ttl=None, now: float = 0.0) -> None:
        self.clock = max(self.clock, now)
        self.check_link(node_id, now)
        s, rng = self.schedule, self._rng
        # heartbeats only face the partition (lease keepalives retry
        # below this layer); everything else gets the full treatment
        inject = now < s.end_s and not key.startswith("/nodes/")
        if inject and s.drop_p and rng.random() < s.drop_p:
            self.stats["dropped"] += 1
            return
        deliver_at = now
        if inject and s.delay_p and rng.random() < s.delay_p:
            deliver_at = now + rng.uniform(0.0, s.max_delay_s)
            self.stats["delayed"] += 1
        if inject and s.dup_p and rng.random() < s.dup_p:
            echo_at = now + rng.uniform(0.0, max(s.max_delay_s, 1.0))
            self._pseq += 1
            heapq.heappush(self._pending,
                           (echo_at, self._pseq, key, value, ttl, now))
            self.stats["duplicated"] += 1
        if deliver_at <= now:
            super().put(key, value, ttl=ttl, now=now)
            self.stats["delivered"] += 1
        else:
            self._pseq += 1
            heapq.heappush(self._pending,
                           (deliver_at, self._pseq, key, value, ttl, now))

    def advance(self, now: float) -> int:
        """Deliver matured in-flight messages; returns how many."""
        self.clock = max(self.clock, now)
        n = 0
        while self._pending and self._pending[0][0] <= now:
            _, _, key, value, ttl, sent = heapq.heappop(self._pending)
            super().put(key, value, ttl=ttl, now=sent)
            self.stats["delivered"] += 1
            n += 1
        return n

    def expire(self, now: float):
        self.advance(now)
        return super().expire(now)

    @property
    def in_flight(self) -> int:
        return len(self._pending)


# ---------------------------------------------------------------------------
# Scripted world + convergence harness
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorldEvent:
    """One scripted ground-truth event the harness feeds the agents.

    kinds: ``error`` (in-band report of ``error`` on ``node``), ``kill``
    (node dies, heartbeats stop), ``repair`` (ops crew finishes fixing
    ``node``), ``finish`` (the workload owner declares ``task`` done),
    ``launch`` (a new ``task`` asks for admission)."""
    time: float
    kind: str
    node: int = 0
    error: Optional[ErrorKind] = None
    task: Optional[Task] = None
    avg_iter_s: float = 30.0


def demo_world(finish_task: Task, launch_task: Task, *, t0: float = 40.0,
               spacing: float = 180.0) -> List[WorldEvent]:
    """The standard convergence script: an in-band SEV2, a node loss, a
    task finish, a task launch, and the repair — each ``spacing`` apart
    so chaos can shift fire times without reordering decisions."""
    t = [t0 + i * spacing for i in range(5)]
    return [
        WorldEvent(t[0], "error", node=1, error=ErrorKind.CUDA_ERROR),
        WorldEvent(t[1], "kill", node=2),
        WorldEvent(t[2], "finish", task=finish_task),
        WorldEvent(t[3], "launch", task=launch_task, avg_iter_s=12.0),
        WorldEvent(t[4], "repair", node=2),
    ]


def world_windows(world: Sequence[WorldEvent],
                  lag_s: float = 150.0) -> List[Tuple[float, float]]:
    """Exclusion windows around world events for partition placement:
    [t - 10, t + lag] covers the worst-case delivery+decision lag."""
    return [(ev.time - 10.0, ev.time + lag_s) for ev in world]


@dataclass
class HarnessResult:
    assignment: Dict[str, int]         # task label -> workers
    waf: float
    healthy_workers: int
    last_event_t: float
    n_crashes: int
    n_events: int
    chaos_stats: Optional[Dict[str, int]] = None


@dataclass
class ChaosHarness:
    """Tick-driven closed world: agents + chaotic status monitor +
    control loop + coordinator, fed a scripted ``WorldEvent`` list.

    The harness plays the roles outside the control plane: the workload
    owner (announcing finish/launch intents through an agent until the
    coordinator's task set reflects them — the application-level
    re-announcement the epoch staleness guard requires), the ops crew
    (scheduled repairs), and the fault injector (scheduled coordinator
    crashes, recovered via ``UnicronCoordinator.recover`` plus a fresh
    ``ControlLoop`` whose consumption state comes from the KV markers).
    The shared ``Cluster`` object is the physical ground truth."""

    tasks: List[Task]
    assignment: List[int]
    hw: object
    n_nodes: int = 6
    gpus_per_node: int = 4
    schedule: Optional[ChaosSchedule] = None
    tick_s: float = 2.0
    marker_retention_s: float = 600.0
    seed: int = 0
    labels: Optional[Dict[int, str]] = None
    events: List[object] = field(default_factory=list)
    n_crashes: int = 0
    last_event_t: float = 0.0
    # chaos-free store override (e.g. kvstore.LegacyKVStore for the
    # legacy-vs-sharded equivalence suite); chaos runs always use
    # ChaosKVStore, which wraps the sharded store
    kv_factory: Optional[object] = None

    def __post_init__(self):
        self.kv = (ChaosKVStore(self.schedule) if self.schedule
                   else (self.kv_factory() if self.kv_factory
                         else KVStore()))
        self.coord = UnicronCoordinator(
            list(self.tasks), list(self.assignment), self.hw, kv=self.kv,
            n_cluster_workers=self.n_nodes * self.gpus_per_node,
            workers_per_node=self.gpus_per_node)
        self.cluster = Cluster(self.n_nodes, self.gpus_per_node)
        self.cluster.assign(list(self.assignment))
        chaotic = isinstance(self.kv, ChaosKVStore)
        self.agents = {
            i: UnicronAgent(i, self.kv.bind(i) if chaotic else self.kv,
                            n_gpus=self.gpus_per_node,
                            seed=self.seed * 1000 + i)
            for i in range(self.n_nodes)}
        self.loop = ControlLoop(self.coord, self.cluster, self.agents,
                                marker_retention_s=self.marker_retention_s)
        if self.labels is None:
            self.labels = {}
        for t in self.tasks:
            self._label(t)
        self._crashes = sorted(self.schedule.crash_times) \
            if self.schedule else []
        self._pending_repairs: Dict[int, float] = {}
        self._finish_intents: List[Task] = []
        self._launch_intents: List[Tuple[Task, float]] = []

    def _label(self, task: Task) -> str:
        return self.labels.setdefault(id(task),
                                      f"task{len(self.labels)}")

    # ---- world-side actors -------------------------------------------------

    def _fire_world(self, ev: WorldEvent, now: float) -> None:
        if ev.kind == "error":
            self.agents[ev.node].report(ev.error, now)
        elif ev.kind == "kill":
            self.agents[ev.node].kill()
        elif ev.kind == "repair":
            self._pending_repairs[ev.node] = ev.time
        elif ev.kind == "finish":
            self._label(ev.task)
            self._finish_intents.append(ev.task)
        elif ev.kind == "launch":
            self._label(ev.task)
            self._launch_intents.append((ev.task, ev.avg_iter_s))
        else:
            raise ValueError(f"unknown world event kind {ev.kind!r}")

    def _repair_crew(self, now: float) -> None:
        for node, due in list(self._pending_repairs.items()):
            n = self.cluster.nodes[node]
            if due <= now and not n.healthy:
                n.repair_done_at = now     # hardware fixed; loop rejoins
                del self._pending_repairs[node]

    def _reporter(self) -> Optional[UnicronAgent]:
        """First alive agent with a working link (any worker of a task
        may announce churn; the choice only affects key names)."""
        for nid in sorted(self.agents):
            a = self.agents[nid]
            if not a.alive:
                continue
            try:
                a.kv.get(PLAN_EPOCH_KEY)
            except KVUnavailable:
                continue
            return a
        return None

    def _announce_intents(self, now: float) -> None:
        """Re-announce unsatisfied churn intents against the current
        epoch — the submitter side of the staleness guard: a record
        consumed-without-firing (stale epoch) is simply announced again
        until the coordinator's task set reflects the intent."""
        a = self._reporter()
        if a is None:
            return
        epoch = a.kv.get(PLAN_EPOCH_KEY, 0)
        live = {id(e.task): i for i, e in enumerate(self.coord.entries)}
        for t in list(self._finish_intents):
            idx = live.get(id(t))
            if idx is None:                        # satisfied
                self._finish_intents.remove(t)
                continue
            a.report_task_finished(idx, now, epoch)
        for t, avg in list(self._launch_intents):
            if id(t) in live:                      # satisfied
                self._launch_intents.remove((t, avg))
                continue
            a.request_task_launch(t, now, epoch, avg_iter_s=avg)

    def _crash_coordinator(self) -> None:
        """Coordinator + control-loop process dies; everything in-memory
        is lost.  Recovery: journal -> entries/epoch/cases, KV markers ->
        consumption state, incarnation fence deposes the old process."""
        self.events += self.loop.events
        self.coord = UnicronCoordinator.recover(
            self.kv, self.hw,
            n_cluster_workers=self.n_nodes * self.gpus_per_node,
            workers_per_node=self.gpus_per_node)
        self.loop = ControlLoop(self.coord, self.cluster, self.agents,
                                marker_retention_s=self.marker_retention_s)
        self.n_crashes += 1

    # ---- main loop ---------------------------------------------------------

    def run(self, world: Sequence[WorldEvent],
            until: float) -> HarnessResult:
        script = sorted(world, key=lambda e: e.time)
        wi = 0
        t = 0.0
        while t <= until:
            while self._crashes and self._crashes[0] <= t:
                self._crashes.pop(0)
                self._crash_coordinator()
            while wi < len(script) and script[wi].time <= t:
                self._fire_world(script[wi], t)
                wi += 1
            self._repair_crew(t)
            heartbeat_cohort(self.agents, t)
            for a in self.agents.values():
                a.flush_outbox(t)
            self._announce_intents(t)
            if self.loop.tick(t):
                self.last_event_t = t
            t += self.tick_s
        self.events += self.loop.events
        return self.result()

    def result(self) -> HarnessResult:
        assign = {self._label(e.task): e.n_workers
                  for e in self.coord.entries}
        stats = dict(self.kv.stats) \
            if isinstance(self.kv, ChaosKVStore) else None
        return HarnessResult(
            assignment=assign, waf=self.coord.cluster_waf(),
            healthy_workers=self.cluster.healthy_workers(),
            last_event_t=self.last_event_t, n_crashes=self.n_crashes,
            n_events=len(self.events), chaos_stats=stats)

    def quiesced(self) -> bool:
        """No unacknowledged publishes, no in-flight deliveries."""
        if any(a.outbox_size for a in self.agents.values()):
            return False
        if isinstance(self.kv, ChaosKVStore) and self.kv.in_flight:
            return False
        return not self._finish_intents and not self._launch_intents
