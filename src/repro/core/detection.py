"""In-band error detection (§4.1) — four methods, severity levels (Table 1),
and the online statistical monitor with the 3x-average failure threshold
and 1.1x degradation margin (Figure 6).

Scalar entry points (``detection_time``, ``OnlineStatMonitor``) are the
reference semantics; the array-native counterparts (``detection_times``,
``FleetMonitor``) vectorize the Table-1/Table-2 lookup over
(kinds x policies) and the per-task iteration history over a whole fleet,
which is what the batched multi-policy simulator consumes.
"""
from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Optional, Sequence, Tuple

import numpy as np


class Severity(enum.IntEnum):
    SEV1 = 1          # most severe: node lost / must drain
    SEV2 = 2          # process restart required
    SEV3 = 3          # transient; reattempt in place


class Method(enum.Enum):
    NODE_HEALTH = "node_health_monitoring"
    PROCESS = "process_supervision"
    EXCEPTION = "exception_propagation"
    STATISTICAL = "online_statistical_monitoring"


class ErrorKind(enum.Enum):
    LOST_CONNECTION = "lost_connection"
    EXITED_ABNORMALLY = "exited_abnormally"
    CONNECTION_REFUSED = "connection_refused_reset"
    ILLEGAL_MEMORY_ACCESS = "illegal_memory_access"
    ECC_ERROR = "ecc_error"
    INVALID_DMA_MAPPING = "invalid_dma_mapping"
    CUDA_ERROR = "cuda_error"
    NVLINK_ERROR = "nvlink_error"
    GPU_DRIVER_ERROR = "gpu_driver_error"
    OTHER_NETWORK_ERROR = "other_network_error"
    OTHER_SOFTWARE_ERROR = "other_software_error"
    NCCL_TIMEOUT = "nccl_timeout"
    LINK_FLAPPING = "link_flapping"
    TASK_HANG = "task_hang"


# Table 1: detection method and severity per error status.
ERROR_TABLE: Dict[ErrorKind, Tuple[Method, Severity]] = {
    ErrorKind.LOST_CONNECTION: (Method.NODE_HEALTH, Severity.SEV1),
    ErrorKind.EXITED_ABNORMALLY: (Method.PROCESS, Severity.SEV2),
    ErrorKind.CONNECTION_REFUSED: (Method.PROCESS, Severity.SEV3),
    ErrorKind.ILLEGAL_MEMORY_ACCESS: (Method.PROCESS, Severity.SEV2),
    ErrorKind.ECC_ERROR: (Method.EXCEPTION, Severity.SEV1),
    ErrorKind.INVALID_DMA_MAPPING: (Method.EXCEPTION, Severity.SEV1),
    ErrorKind.CUDA_ERROR: (Method.EXCEPTION, Severity.SEV2),
    ErrorKind.NVLINK_ERROR: (Method.EXCEPTION, Severity.SEV1),
    ErrorKind.GPU_DRIVER_ERROR: (Method.EXCEPTION, Severity.SEV1),
    ErrorKind.OTHER_NETWORK_ERROR: (Method.EXCEPTION, Severity.SEV3),
    ErrorKind.OTHER_SOFTWARE_ERROR: (Method.EXCEPTION, Severity.SEV2),
    ErrorKind.NCCL_TIMEOUT: (Method.STATISTICAL, Severity.SEV3),
    ErrorKind.LINK_FLAPPING: (Method.STATISTICAL, Severity.SEV3),
    ErrorKind.TASK_HANG: (Method.STATISTICAL, Severity.SEV2),
}


def classify(kind: ErrorKind) -> Tuple[Method, Severity]:
    return ERROR_TABLE[kind]


# ---------------------------------------------------------------------------
# Detection latency model (Table 2)
# ---------------------------------------------------------------------------

HEARTBEAT_DETECT_S = 5.6        # Unicron node-health (persistent conn)
PROCESS_DETECT_S = 1.8          # per-GPU monitor thread notices exit
EXCEPTION_DETECT_S = 0.3        # exception propagation
STAT_MULTIPLIER = 3.0           # statistical: 3 x avg iteration time
DEGRADE_MARGIN = 1.1            # Fig. 6 blue line

BASELINE_HEARTBEAT_S = 5.7      # w/o Unicron: scheduler notices node loss
BASELINE_TIMEOUT_S = 30 * 60.0  # Megatron/NCCL default watchdog

# recovery policies that run an in-band detection stack (Table-2 Unicron
# column): unicron itself plus the modern-recovery peers, all of which
# ship agent-side monitors; the paper's four baselines rely on scheduler
# heartbeats / collective timeouts
INBAND_POLICIES = frozenset({
    "unicron", "fftrainer", "hierarchical_ckpt", "redundant",
})


def detection_time(kind: ErrorKind, avg_iter_s: float,
                   unicron: bool = True) -> float:
    """Seconds from fault occurrence to detection (Table 2)."""
    method, _ = classify(kind)
    if not unicron:
        if method is Method.NODE_HEALTH:
            return BASELINE_HEARTBEAT_S
        return BASELINE_TIMEOUT_S
    return {
        Method.NODE_HEALTH: HEARTBEAT_DETECT_S,
        Method.PROCESS: PROCESS_DETECT_S,
        Method.EXCEPTION: EXCEPTION_DETECT_S,
        Method.STATISTICAL: STAT_MULTIPLIER * avg_iter_s,
    }[method]


# ---------------------------------------------------------------------------
# Array-native detection model: the Table-1/Table-2 lookup vectorized over
# (kinds x policies).  Same floats as ``detection_time`` at every cell.
# ---------------------------------------------------------------------------

_KINDS: Tuple[ErrorKind, ...] = tuple(ErrorKind)
KIND_INDEX: Dict[ErrorKind, int] = {k: i for i, k in enumerate(_KINDS)}
_METHODS: Tuple[Method, ...] = (Method.NODE_HEALTH, Method.PROCESS,
                                Method.EXCEPTION, Method.STATISTICAL)
_METHOD_INDEX = {m: i for i, m in enumerate(_METHODS)}
_STAT_CODE = _METHOD_INDEX[Method.STATISTICAL]
# per-kind method code and severity int, indexable by KIND_INDEX
KIND_METHOD = np.array([_METHOD_INDEX[ERROR_TABLE[k][0]] for k in _KINDS])
KIND_SEVERITY = np.array([int(ERROR_TABLE[k][1]) for k in _KINDS])
# per-method fixed latencies; the statistical entry is a placeholder (its
# latency scales with the average iteration time, filled in per query)
_UNICRON_BY_METHOD = np.array([HEARTBEAT_DETECT_S, PROCESS_DETECT_S,
                               EXCEPTION_DETECT_S, 0.0])
_BASELINE_BY_METHOD = np.array([BASELINE_HEARTBEAT_S, BASELINE_TIMEOUT_S,
                                BASELINE_TIMEOUT_S, BASELINE_TIMEOUT_S])


def detection_times(kinds: Sequence[ErrorKind], avg_iter_s,
                    unicron) -> np.ndarray:
    """Detection latencies for every (kind, policy) pair as one
    (len(kinds), len(unicron)) matrix (Table 2 vectorized).

    ``unicron`` is a boolean vector over the policy axis (True = in-band
    Unicron detection); ``avg_iter_s`` is a scalar or broadcastable to
    (len(kinds), len(unicron)) — statistical detection is
    ``STAT_MULTIPLIER * avg_iter_s`` per cell, exactly the scalar
    ``detection_time`` arithmetic, so every cell equals the scalar call."""
    ki = np.array([KIND_INDEX[k] for k in kinds])
    uni = np.asarray(unicron, dtype=bool)
    method = KIND_METHOD[ki][:, None]                      # (K, 1)
    avg = np.broadcast_to(np.asarray(avg_iter_s, dtype=float),
                          (ki.size, uni.size))
    uni_t = np.where(method == _STAT_CODE, STAT_MULTIPLIER * avg,
                     _UNICRON_BY_METHOD[method])
    return np.where(uni[None, :], uni_t, _BASELINE_BY_METHOD[method])


@dataclass
class OnlineStatMonitor:
    """Rolling-average iteration monitor (Fig. 6).

    ``observe`` records a completed iteration; ``check_waiting`` asks
    whether an in-flight iteration that has been running ``waited_s``
    should be flagged (degraded at 1.1x, failed at 3x the average).
    """
    window: int = 64
    _hist: Deque[float] = field(default_factory=deque)

    @classmethod
    def primed(cls, avg_iter_s: float, window: int = 64,
               n_obs: Optional[int] = None) -> "OnlineStatMonitor":
        """A monitor warmed with a steady-state iteration history, as a
        task that has been training for a while would have — the simulator
        and the scenario tests use this to ask whether a slow-node event
        trips the 1.1x degradation margin (Fig. 6)."""
        mon = cls(window=window)
        for _ in range(n_obs if n_obs is not None else window):
            mon.observe(avg_iter_s)
        return mon

    def observe(self, iter_s: float) -> None:
        self._hist.append(iter_s)
        if len(self._hist) > self.window:
            self._hist.popleft()

    @property
    def average(self) -> Optional[float]:
        if not self._hist:
            return None
        return sum(self._hist) / len(self._hist)

    def status(self, waited_s: float) -> str:
        """'ok' | 'degraded' | 'failed' for an in-flight iteration."""
        avg = self.average
        if avg is None:
            return "ok"
        if waited_s > STAT_MULTIPLIER * avg:
            return "failed"
        if waited_s > DEGRADE_MARGIN * avg:
            return "degraded"
        return "ok"


class HeartbeatTable:
    """Array-native heartbeat liveness — the ``FleetMonitor`` ring-buffer
    idiom extended from per-task statistics to per-shard lease state.

    The status monitor's hot liveness path at fleet scale is not a dict
    of lease objects: beats and lease deadlines live in numpy arrays
    sharded by node group (``node_id // group_size``), so

    * a single beat is two array element writes,
    * a whole agent cohort's beats (``beat_batch``) are one fancy-index
      scatter per touched group, and
    * lease expiry (``expired``) is one vectorized ``deadline <= now``
      comparison + argwhere per group instead of a per-node Python scan.

    Semantics match a plain KV lease table: a beat overwrites the value
    and re-arms the deadline, ``pop`` revokes, expiry drops the node and
    reports it exactly once.  Groups materialize lazily, so a sparse id
    space costs only the groups actually inhabited."""

    __slots__ = ("group_size", "_groups")

    def __init__(self, group_size: int = 1024):
        self.group_size = group_size
        # gid -> [beat values, lease deadlines, presence mask]
        self._groups: Dict[int, Tuple[np.ndarray, np.ndarray,
                                      np.ndarray]] = {}

    def _group(self, gid: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        g = self._groups.get(gid)
        if g is None:
            size = self.group_size
            g = self._groups[gid] = (np.full(size, np.nan),
                                     np.full(size, np.inf),
                                     np.zeros(size, dtype=bool))
        return g

    def __len__(self) -> int:
        return sum(int(g[2].sum()) for g in self._groups.values())

    def beat(self, node: int, value: float, deadline: float) -> None:
        gid, off = divmod(int(node), self.group_size)
        beats, deadlines, present = self._group(gid)
        beats[off] = value
        deadlines[off] = deadline
        present[off] = True

    def beat_batch(self, nodes, value: float, deadline: float) -> None:
        """One cohort, one scatter per touched group.  The cohort is
        sorted once so each group's offsets are a contiguous slice — no
        per-group masking pass over the whole cohort."""
        ids = np.asarray(nodes, dtype=np.int64)
        if ids.size == 0:
            return
        if ids.size > 1 and np.any(ids[1:] < ids[:-1]):
            ids = np.sort(ids)
        gids = ids // self.group_size
        offs = ids % self.group_size
        uniq, starts = np.unique(gids, return_index=True)
        ends = np.append(starts[1:], ids.size)
        for gid, lo, hi in zip(uniq, starts, ends):
            beats, deadlines, present = self._group(int(gid))
            sel = offs[lo:hi]
            beats[sel] = value
            deadlines[sel] = deadline
            present[sel] = True

    def get(self, node: int, default=None):
        gid, off = divmod(int(node), self.group_size)
        g = self._groups.get(gid)
        if g is None or not g[2][off]:
            return default
        return float(g[0][off])

    def pop(self, node: int) -> bool:
        """Revoke a node's lease; True if it was present."""
        gid, off = divmod(int(node), self.group_size)
        g = self._groups.get(gid)
        if g is None or not g[2][off]:
            return False
        g[0][off] = np.nan
        g[1][off] = np.inf
        g[2][off] = False
        return True

    def cas(self, node: int, expect, value) -> bool:
        """Swap the beat value only — the lease deadline survives, the
        KV-level cas-preserves-lease contract."""
        gid, off = divmod(int(node), self.group_size)
        g = self._groups.get(gid)
        current = float(g[0][off]) if g is not None and g[2][off] else None
        if current == expect:
            self._group(gid)[0][off] = value
            self._groups[gid][2][off] = True
            return True
        return False

    def items(self):
        """(node, beat value) pairs for all present nodes, id order."""
        for gid in sorted(self._groups):
            beats, _, present = self._groups[gid]
            for off in np.nonzero(present)[0]:
                yield gid * self.group_size + int(off), float(beats[off])

    def expired(self, now: float) -> list:
        """Drop lapsed leases; node ids in ascending order — one
        vectorized comparison + argwhere per inhabited group."""
        out = []
        for gid in sorted(self._groups):
            beats, deadlines, present = self._groups[gid]
            hits = np.nonzero(present & (deadlines <= now))[0]
            if hits.size == 0:
                continue
            beats[hits] = np.nan
            deadlines[hits] = np.inf
            present[hits] = False
            base = gid * self.group_size
            out.extend(base + int(off) for off in hits)
        return out


class FleetMonitor:
    """Array-native §4.1 statistical monitor: one (tasks, window) float
    ring buffer replacing per-task ``OnlineStatMonitor`` deques inside the
    simulation engines.

    Rows hold the rolling iteration history of one task each; ``observe``
    is a vectorized scatter, ``averages``/``statuses`` are masked row
    reductions.  A row primed with a constant history reports exactly the
    scalar monitor's average (the window is a power of two, so the mean of
    identical values is exact), which is the only regime the engines
    consult — ``OnlineStatMonitor`` stays the scalar reference the
    property tests compare against."""

    def __init__(self, n_tasks: int, window: int = 64):
        self.window = window
        self._n = n_tasks
        cap = max(1, n_tasks)
        self._buf = np.zeros((cap, window))
        self._pos = np.zeros(cap, dtype=np.int64)
        self._count = np.zeros(cap, dtype=np.int64)

    @classmethod
    def primed(cls, avg_iter_s: Sequence[float],
               window: int = 64) -> "FleetMonitor":
        """One row per task, each warmed with a full window of its
        steady-state iteration time (``OnlineStatMonitor.primed`` for a
        whole fleet)."""
        avg = np.asarray(avg_iter_s, dtype=float)
        mon = cls(avg.size, window=window)
        mon._buf[:mon._n] = avg[:, None]
        mon._count[:mon._n] = window
        return mon

    @property
    def n_tasks(self) -> int:
        return self._n

    @property
    def capacity(self) -> int:
        return self._buf.shape[0]

    def grow(self, avg_iter_s: float) -> int:
        """Admit one task (churn): returns its row index, primed.

        Reallocation is amortized: the ring buffer doubles geometrically
        when full, so a churn-heavy trace admitting k tasks costs O(k)
        total row copies instead of O(k^2) per-admit reallocs."""
        if self._n == self._buf.shape[0]:
            cap = max(8, 2 * self._buf.shape[0])
            buf = np.zeros((cap, self.window))
            pos = np.zeros(cap, dtype=np.int64)
            count = np.zeros(cap, dtype=np.int64)
            buf[:self._n] = self._buf
            pos[:self._n] = self._pos
            count[:self._n] = self._count
            self._buf, self._pos, self._count = buf, pos, count
        row = self._n
        self._n += 1
        self._buf[row] = float(avg_iter_s)
        self._pos[row] = 0
        self._count[row] = self.window
        return row

    def observe(self, tasks: Sequence[int], iter_s) -> None:
        """Record one completed iteration per task (vectorized scatter)."""
        ti = np.asarray(tasks, dtype=np.int64)
        self._buf[ti, self._pos[ti]] = np.asarray(iter_s, dtype=float)
        self._pos[ti] = (self._pos[ti] + 1) % self.window
        self._count[ti] = np.minimum(self._count[ti] + 1, self.window)

    def averages(self, tasks: Optional[Sequence[int]] = None) -> np.ndarray:
        """Rolling averages per task; NaN where a row has no history."""
        ti = (np.arange(self.n_tasks) if tasks is None
              else np.asarray(tasks, dtype=np.int64))
        count = self._count[ti]
        with np.errstate(invalid="ignore", divide="ignore"):
            return np.where(count > 0,
                            self._buf[ti].sum(axis=1) / count, np.nan)

    def statuses(self, tasks: Sequence[int], waited_s) -> np.ndarray:
        """Status codes per (task, waited) pair: 0 ok / 1 degraded /
        2 failed — the Fig. 6 thresholds, vectorized."""
        avg = self.averages(tasks)
        waited = np.broadcast_to(np.asarray(waited_s, dtype=float),
                                 avg.shape)
        out = np.zeros(avg.shape, dtype=np.int64)
        with np.errstate(invalid="ignore"):
            out[waited > DEGRADE_MARGIN * avg] = 1
            out[waited > STAT_MULTIPLIER * avg] = 2
        return out
