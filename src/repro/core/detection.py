"""In-band error detection (§4.1) — four methods, severity levels (Table 1),
and the online statistical monitor with the 3x-average failure threshold
and 1.1x degradation margin (Figure 6).
"""
from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Optional, Tuple


class Severity(enum.IntEnum):
    SEV1 = 1          # most severe: node lost / must drain
    SEV2 = 2          # process restart required
    SEV3 = 3          # transient; reattempt in place


class Method(enum.Enum):
    NODE_HEALTH = "node_health_monitoring"
    PROCESS = "process_supervision"
    EXCEPTION = "exception_propagation"
    STATISTICAL = "online_statistical_monitoring"


class ErrorKind(enum.Enum):
    LOST_CONNECTION = "lost_connection"
    EXITED_ABNORMALLY = "exited_abnormally"
    CONNECTION_REFUSED = "connection_refused_reset"
    ILLEGAL_MEMORY_ACCESS = "illegal_memory_access"
    ECC_ERROR = "ecc_error"
    INVALID_DMA_MAPPING = "invalid_dma_mapping"
    CUDA_ERROR = "cuda_error"
    NVLINK_ERROR = "nvlink_error"
    GPU_DRIVER_ERROR = "gpu_driver_error"
    OTHER_NETWORK_ERROR = "other_network_error"
    OTHER_SOFTWARE_ERROR = "other_software_error"
    NCCL_TIMEOUT = "nccl_timeout"
    LINK_FLAPPING = "link_flapping"
    TASK_HANG = "task_hang"


# Table 1: detection method and severity per error status.
ERROR_TABLE: Dict[ErrorKind, Tuple[Method, Severity]] = {
    ErrorKind.LOST_CONNECTION: (Method.NODE_HEALTH, Severity.SEV1),
    ErrorKind.EXITED_ABNORMALLY: (Method.PROCESS, Severity.SEV2),
    ErrorKind.CONNECTION_REFUSED: (Method.PROCESS, Severity.SEV3),
    ErrorKind.ILLEGAL_MEMORY_ACCESS: (Method.PROCESS, Severity.SEV2),
    ErrorKind.ECC_ERROR: (Method.EXCEPTION, Severity.SEV1),
    ErrorKind.INVALID_DMA_MAPPING: (Method.EXCEPTION, Severity.SEV1),
    ErrorKind.CUDA_ERROR: (Method.EXCEPTION, Severity.SEV2),
    ErrorKind.NVLINK_ERROR: (Method.EXCEPTION, Severity.SEV1),
    ErrorKind.GPU_DRIVER_ERROR: (Method.EXCEPTION, Severity.SEV1),
    ErrorKind.OTHER_NETWORK_ERROR: (Method.EXCEPTION, Severity.SEV3),
    ErrorKind.OTHER_SOFTWARE_ERROR: (Method.EXCEPTION, Severity.SEV2),
    ErrorKind.NCCL_TIMEOUT: (Method.STATISTICAL, Severity.SEV3),
    ErrorKind.LINK_FLAPPING: (Method.STATISTICAL, Severity.SEV3),
    ErrorKind.TASK_HANG: (Method.STATISTICAL, Severity.SEV2),
}


def classify(kind: ErrorKind) -> Tuple[Method, Severity]:
    return ERROR_TABLE[kind]


# ---------------------------------------------------------------------------
# Detection latency model (Table 2)
# ---------------------------------------------------------------------------

HEARTBEAT_DETECT_S = 5.6        # Unicron node-health (persistent conn)
PROCESS_DETECT_S = 1.8          # per-GPU monitor thread notices exit
EXCEPTION_DETECT_S = 0.3        # exception propagation
STAT_MULTIPLIER = 3.0           # statistical: 3 x avg iteration time
DEGRADE_MARGIN = 1.1            # Fig. 6 blue line

BASELINE_HEARTBEAT_S = 5.7      # w/o Unicron: scheduler notices node loss
BASELINE_TIMEOUT_S = 30 * 60.0  # Megatron/NCCL default watchdog


def detection_time(kind: ErrorKind, avg_iter_s: float,
                   unicron: bool = True) -> float:
    """Seconds from fault occurrence to detection (Table 2)."""
    method, _ = classify(kind)
    if not unicron:
        if method is Method.NODE_HEALTH:
            return BASELINE_HEARTBEAT_S
        return BASELINE_TIMEOUT_S
    return {
        Method.NODE_HEALTH: HEARTBEAT_DETECT_S,
        Method.PROCESS: PROCESS_DETECT_S,
        Method.EXCEPTION: EXCEPTION_DETECT_S,
        Method.STATISTICAL: STAT_MULTIPLIER * avg_iter_s,
    }[method]


@dataclass
class OnlineStatMonitor:
    """Rolling-average iteration monitor (Fig. 6).

    ``observe`` records a completed iteration; ``check_waiting`` asks
    whether an in-flight iteration that has been running ``waited_s``
    should be flagged (degraded at 1.1x, failed at 3x the average).
    """
    window: int = 64
    _hist: Deque[float] = field(default_factory=deque)

    @classmethod
    def primed(cls, avg_iter_s: float, window: int = 64,
               n_obs: Optional[int] = None) -> "OnlineStatMonitor":
        """A monitor warmed with a steady-state iteration history, as a
        task that has been training for a while would have — the simulator
        and the scenario tests use this to ask whether a slow-node event
        trips the 1.1x degradation margin (Fig. 6)."""
        mon = cls(window=window)
        for _ in range(n_obs if n_obs is not None else window):
            mon.observe(avg_iter_s)
        return mon

    def observe(self, iter_s: float) -> None:
        self._hist.append(iter_s)
        if len(self._hist) > self.window:
            self._hist.popleft()

    @property
    def average(self) -> Optional[float]:
        if not self._hist:
            return None
        return sum(self._hist) / len(self._hist)

    def status(self, waited_s: float) -> str:
        """'ok' | 'degraded' | 'failed' for an in-flight iteration."""
        avg = self.average
        if avg is None:
            return "ok"
        if waited_s > STAT_MULTIPLIER * avg:
            return "failed"
        if waited_s > DEGRADE_MARGIN * avg:
            return "degraded"
        return "ok"
