"""Error handling workflow (§4.2, Figure 7).

Severity-driven actions with escalation:

  SEV3 (1) -> reattempt in place; on failure escalate to SEV2
  SEV2 (2) -> restart training process, same config; on failure -> SEV1
  SEV1 (3) -> isolate node + reconfigure cluster

Plus the non-failure triggers that also enter reconfiguration: node join
(4), task finished (5), task launched (6).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

from repro.core.detection import ErrorKind, Severity, classify


class Action(enum.Enum):
    REATTEMPT = "reattempt_in_place"       # (1) SEV3
    RESTART = "restart_process"            # (2) SEV2
    RECONFIGURE = "reconfigure_cluster"    # (3) SEV1
    RESUME = "resume_training"             # reattempt succeeded


class Trigger(enum.Enum):
    ERROR = "error"
    NODE_JOIN = "node_join"                # (4)
    TASK_FINISHED = "task_finished"        # (5)
    TASK_LAUNCHED = "task_launched"        # (6)


def action_for(severity: Severity) -> Action:
    return {
        Severity.SEV3: Action.REATTEMPT,
        Severity.SEV2: Action.RESTART,
        Severity.SEV1: Action.RECONFIGURE,
    }[severity]


def escalate(severity: Severity) -> Severity:
    """SEV3 -> SEV2 -> SEV1 (SEV1 has no further escalation)."""
    return Severity(max(1, int(severity) - 1))


@dataclass
class FailureCase:
    """One failure instance moving through the workflow."""
    kind: ErrorKind
    severity: Severity
    attempts: int = 0

    @classmethod
    def from_kind(cls, kind: ErrorKind) -> "FailureCase":
        return cls(kind=kind, severity=classify(kind)[1])

    def next_action(self) -> Action:
        return action_for(self.severity)

    def record_failure(self) -> Action:
        """The last action did not resolve the issue: escalate."""
        self.attempts += 1
        self.severity = escalate(self.severity)
        return self.next_action()


@dataclass
class HandlingDecision:
    action: Action
    severity: Severity
    isolate_node: bool                 # SEV1: drain the faulty node
    replan_all_tasks: bool             # Unicron replans the whole cluster


def decide(case: FailureCase, *, multi_task: bool = True) -> HandlingDecision:
    act = case.next_action()
    return HandlingDecision(
        action=act,
        severity=case.severity,
        isolate_node=(act is Action.RECONFIGURE),
        replan_all_tasks=(act is Action.RECONFIGURE and multi_task),
    )
