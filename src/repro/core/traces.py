"""Failure traces (§7.5).

*trace-a*: 8 weeks on a 16-node (128 GPU) cluster — 10 SEV1 node faults
plus 33 SEV2/SEV3 failures; node repair time uniform in [1, 7] days.

*trace-b*: trace-a's frequency amplified 20x, compressed to 7 days —
26 SEV1 + 80 other failures, Poisson arrivals; repaired nodes rejoin at a
similar rate (repair uniform in [2, 12] hours) to keep the pool stable.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.detection import ErrorKind, Severity, classify

DAY = 86400.0
WEEK = 7 * DAY

# §2.2: 73% of failures are transient (restart suffices).  Within the
# non-SEV1 population we mix process/exception/statistical kinds.
NON_SEV1_KINDS = [
    (ErrorKind.CUDA_ERROR, 0.22),
    (ErrorKind.EXITED_ABNORMALLY, 0.18),
    (ErrorKind.ILLEGAL_MEMORY_ACCESS, 0.10),
    (ErrorKind.OTHER_SOFTWARE_ERROR, 0.12),
    (ErrorKind.NCCL_TIMEOUT, 0.14),
    (ErrorKind.CONNECTION_REFUSED, 0.10),
    (ErrorKind.LINK_FLAPPING, 0.06),
    (ErrorKind.TASK_HANG, 0.08),
]
SEV1_KINDS = [
    (ErrorKind.LOST_CONNECTION, 0.5),
    (ErrorKind.ECC_ERROR, 0.2),
    (ErrorKind.NVLINK_ERROR, 0.15),
    (ErrorKind.GPU_DRIVER_ERROR, 0.15),
]


@dataclass(frozen=True)
class FailureEvent:
    time: float                 # seconds from trace start
    node: int
    kind: ErrorKind
    repair_s: Optional[float]   # SEV1 only: node returns after this long

    @property
    def severity(self) -> Severity:
        return classify(self.kind)[1]


def sample_kinds(rng: np.random.Generator,
                 weighted: Sequence[Tuple[ErrorKind, float]],
                 size: int) -> List[ErrorKind]:
    """Vectorized weighted kind draw (the numpy counterpart of ``_pick``,
    used by the seeded generators in ``core.scenarios``)."""
    kinds = [k for k, _ in weighted]
    w = np.array([p for _, p in weighted], dtype=float)
    idx = rng.choice(len(kinds), size=size, p=w / w.sum())
    return [kinds[i] for i in idx]


def poisson_times(rng: np.random.Generator, rate_per_s: float,
                  span_s: float) -> np.ndarray:
    """Sorted Poisson-process arrival times on [0, span): exponential
    inter-arrivals drawn in one vectorized batch (over-sample by 4 sigma,
    extend in the rare shortfall), clipped to the span."""
    if rate_per_s <= 0.0 or span_s <= 0.0:
        return np.empty(0)
    expect = rate_per_s * span_s
    n_draw = int(expect + 4.0 * np.sqrt(expect) + 16)
    gaps = rng.exponential(1.0 / rate_per_s, size=n_draw)
    t = np.cumsum(gaps)
    while t[-1] < span_s:                      # astronomically rare
        extra = rng.exponential(1.0 / rate_per_s, size=n_draw)
        t = np.concatenate([t, t[-1] + np.cumsum(extra)])
    return t[t < span_s]


def _pick(rng: random.Random, weighted) -> ErrorKind:
    r = rng.random() * sum(w for _, w in weighted)
    acc = 0.0
    for kind, w in weighted:
        acc += w
        if r <= acc:
            return kind
    return weighted[-1][0]


def _make_trace(*, span_s: float, n_sev1: int, n_other: int, n_nodes: int,
                repair_lo: float, repair_hi: float, seed: int,
                poisson: bool) -> List[FailureEvent]:
    rng = random.Random(seed)
    events: List[FailureEvent] = []

    def times(n: int) -> List[float]:
        if poisson:
            # exponential inter-arrival, rescaled to span
            gaps = [rng.expovariate(1.0) for _ in range(n)]
            total = sum(gaps)
            acc, out = 0.0, []
            for g in gaps:
                acc += g
                out.append(acc / total * span_s * rng.uniform(0.9, 1.0))
            return sorted(out)
        return sorted(rng.uniform(0, span_s) for _ in range(n))

    for t in times(n_sev1):
        events.append(FailureEvent(
            time=t, node=rng.randrange(n_nodes),
            kind=_pick(rng, SEV1_KINDS),
            repair_s=rng.uniform(repair_lo, repair_hi)))
    for t in times(n_other):
        events.append(FailureEvent(
            time=t, node=rng.randrange(n_nodes),
            kind=_pick(rng, NON_SEV1_KINDS), repair_s=None))
    return sorted(events, key=lambda e: e.time)


def trace_a(n_nodes: int = 16, seed: int = 7) -> List[FailureEvent]:
    return _make_trace(span_s=8 * WEEK, n_sev1=10, n_other=33,
                       n_nodes=n_nodes, repair_lo=1 * DAY, repair_hi=7 * DAY,
                       seed=seed, poisson=False)


def trace_b(n_nodes: int = 16, seed: int = 11) -> List[FailureEvent]:
    return _make_trace(span_s=7 * DAY, n_sev1=26, n_other=80,
                       n_nodes=n_nodes, repair_lo=2 * 3600.0,
                       repair_hi=12 * 3600.0, seed=seed, poisson=True)


def trace_span(trace: List[FailureEvent]) -> float:
    """Nominal span for WAF integration."""
    if not trace:
        return 0.0
    return 8 * WEEK if trace[-1].time > 8 * DAY else 7 * DAY
