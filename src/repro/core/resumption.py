"""Resuming from a failed iteration (§6.2) — exact-semantics recovery.

A global batch of B micro-batches is partitioned over DP ranks (k = B/DP
each).  Gradients accumulate per rank until the end-of-iteration
all-reduce (Eq. 6).  On a rank failure:

* **Scenario #1** (before the all-reduce): the failed rank's accumulated
  gradients are lost; its k micro-batches are *redistributed round-robin*
  to the surviving ranks, which recompute them and fold them into their
  own accumulators (Eq. 7).  Survivors' partial results are reused — no
  global recompute.

* **Scenario #2** (all-reduce already started): the reduction proceeds in
  buckets (layer segments).  Buckets reduced *before* the failure already
  contain the failed rank's contribution and must not be overwritten;
  only the unreduced buckets take the redistributed recomputation.

Because micro-batches are deterministic functions of (step, index) — see
data.pipeline — recomputation is bit-identical, so the recovered gradient
equals the fault-free gradient.  tests/test_resumption.py asserts this.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax

from repro.train.step import accumulate


# ---------------------------------------------------------------------------
# Micro-batch bookkeeping (the coordinator's iteration scheduler)
# ---------------------------------------------------------------------------


@dataclass
class MicroBatchIteration:
    """Tracks ownership and progress of the micro-batches of ONE global
    batch iteration across DP ranks."""

    n_ranks: int
    n_micro: int
    owners: Dict[int, List[int]] = field(default_factory=dict)
    done: Dict[int, List[int]] = field(default_factory=dict)
    failed_ranks: List[int] = field(default_factory=list)

    def __post_init__(self):
        if not self.owners:
            k, r = divmod(self.n_micro, self.n_ranks)
            idx = 0
            for rank in range(self.n_ranks):
                take = k + (1 if rank < r else 0)
                self.owners[rank] = list(range(idx, idx + take))
                idx += take
        for rank in range(self.n_ranks):
            self.done.setdefault(rank, [])

    def live_ranks(self) -> List[int]:
        return [r for r in range(self.n_ranks) if r not in self.failed_ranks]

    def complete(self, rank: int, mb: int) -> None:
        assert mb in self.owners[rank], (rank, mb)
        self.done[rank].append(mb)

    def pending(self, rank: int) -> List[int]:
        return [m for m in self.owners[rank] if m not in self.done[rank]]

    def fail_rank(self, rank: int) -> List[int]:
        """Mark ``rank`` failed and redistribute ALL of its micro-batches
        (its accumulator is lost) round-robin to survivors (Eq. 7).
        Returns the redistributed micro-batch ids."""
        assert rank not in self.failed_ranks
        self.failed_ranks.append(rank)
        orphans = list(self.owners[rank])
        self.owners[rank] = []
        self.done[rank] = []
        live = self.live_ranks()
        if not live:
            raise RuntimeError("all DP ranks failed; checkpoint restore "
                               "required")
        for i, mb in enumerate(orphans):
            self.owners[live[i % len(live)]].append(mb)
        return orphans

    def all_done(self) -> bool:
        return all(set(self.done[r]) == set(self.owners[r])
                   for r in self.live_ranks())


# ---------------------------------------------------------------------------
# Scenario #1: failure before the all-reduce
# ---------------------------------------------------------------------------


def run_iteration_with_failure(grad_fn: Callable, params,
                               microbatch_of: Callable[[int], dict],
                               n_ranks: int, n_micro: int,
                               fail_rank: Optional[int] = None,
                               fail_after_mb: int = 0):
    """Execute one gradient-accumulation iteration with an optional DP-rank
    failure after the failed rank completed ``fail_after_mb`` micro-batches.

    Single-host simulation of the distributed algebra: each rank's
    accumulator is a separate pytree; the final all-reduce is the sum over
    rank accumulators.  Returns (grad_sum, n_micro) ready for
    train.finalize_step.
    """
    it = MicroBatchIteration(n_ranks=n_ranks, n_micro=n_micro)
    acc: Dict[int, Optional[dict]] = {r: None for r in range(n_ranks)}

    # 1) ranks run until the failure point
    if fail_rank is not None:
        for mb in it.owners[fail_rank][:fail_after_mb]:
            g, _ = grad_fn(params, microbatch_of(mb))
            acc[fail_rank] = accumulate(acc[fail_rank], g)
            it.complete(fail_rank, mb)
        # 2) failure: pause, re-establish comms, redistribute (Eq. 7)
        it.fail_rank(fail_rank)
        acc[fail_rank] = None        # accumulator lost with the rank

    # 3) all surviving ranks finish their (possibly grown) assignments
    for rank in it.live_ranks():
        for mb in it.pending(rank):
            g, _ = grad_fn(params, microbatch_of(mb))
            acc[rank] = accumulate(acc[rank], g)
            it.complete(rank, mb)
    assert it.all_done()

    # 4) all-reduce over live ranks
    total = None
    for rank in it.live_ranks():
        if acc[rank] is not None:
            total = accumulate(total, acc[rank]) if total is not None \
                else acc[rank]
    return total, n_micro


# ---------------------------------------------------------------------------
# Scenario #2: failure after the all-reduce started (bucketed reduction)
# ---------------------------------------------------------------------------


def bucket_masks(params, n_buckets: int) -> List[List[bool]]:
    """Split the flattened param leaves into ``n_buckets`` contiguous
    buckets (layer segments in Megatron terms)."""
    leaves = jax.tree.leaves(params)
    n = len(leaves)
    masks = []
    per = -(-n // n_buckets)
    for b in range(n_buckets):
        masks.append([per * b <= i < per * (b + 1) for i in range(n)])
    return masks


def merge_partial_reduce(treedef, reduced_full: List, survivor_sum: List,
                         recomputed: List, reduced_mask: Sequence[bool]):
    """Combine per-leaf:  already-reduced buckets keep the full sum
    (includes the failed rank); unreduced buckets take survivors' sums plus
    the redistributed recomputation.  All args are leaf lists."""
    out = []
    for i, is_reduced in enumerate(reduced_mask):
        if is_reduced:
            out.append(reduced_full[i])
        else:
            out.append(survivor_sum[i] + recomputed[i])
    return jax.tree.unflatten(treedef, out)


def run_scenario2(grad_fn: Callable, params,
                  microbatch_of: Callable[[int], dict],
                  n_ranks: int, n_micro: int, fail_rank: int,
                  n_buckets: int, buckets_reduced: int):
    """Failure after ``buckets_reduced`` of ``n_buckets`` gradient buckets
    were already all-reduced.  Returns (grad_sum, n_micro)."""
    it = MicroBatchIteration(n_ranks=n_ranks, n_micro=n_micro)
    acc: Dict[int, Optional[dict]] = {r: None for r in range(n_ranks)}
    # every rank finished its compute (all-reduce phase)
    for rank in range(n_ranks):
        for mb in it.owners[rank]:
            g, _ = grad_fn(params, microbatch_of(mb))
            acc[rank] = accumulate(acc[rank], g)
            it.complete(rank, mb)

    masks = bucket_masks(params, n_buckets)
    reduced_mask = [any(masks[b][i] for b in range(buckets_reduced))
                    for i in range(len(jax.tree.leaves(params)))]

    treedef = jax.tree.structure(params)
    full_sum = None
    for rank in range(n_ranks):
        full_sum = accumulate(full_sum, acc[rank]) if full_sum is not None \
            else acc[rank]
    full_leaves = jax.tree.leaves(full_sum)

    if buckets_reduced >= n_buckets:
        # failed worker's gradients fully reduced: proceed uninterrupted
        return full_sum, n_micro

    # survivors' sums for unreduced buckets
    survivor_sum = None
    for rank in range(n_ranks):
        if rank == fail_rank:
            continue
        survivor_sum = accumulate(survivor_sum, acc[rank]) \
            if survivor_sum is not None else acc[rank]
    # redistribute the failed rank's micro-batches; recompute them
    orphans = it.owners[fail_rank]
    recomputed = None
    for mb in orphans:
        g, _ = grad_fn(params, microbatch_of(mb))
        recomputed = accumulate(recomputed, g) if recomputed is not None \
            else accumulate(None, g)
    merged = merge_partial_reduce(
        treedef, full_leaves, jax.tree.leaves(survivor_sum),
        jax.tree.leaves(recomputed), reduced_mask)
    return merged, n_micro
