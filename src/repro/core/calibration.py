"""Fleet failure calibration tables — committed parameter data.

The synthetic scenario generators in ``core/scenarios.py`` draw event
times from exponential/Poisson processes; this module pins their *rates*
to published datacenter characterizations so the calibrated family
(``scenarios.calibrated_*``) reproduces real per-category failure rates
and the MTTF-vs-fleet-size scaling:

* "Characterization of Large Language Model Development in the
  Datacenter" (arXiv 2403.07648, PAPERS.md) — the Acme fleet study:
  per-category infrastructure/software failure shares, NVLink/ECC
  hardware fault taxonomy, and the observation that most interruptions
  are software or transient-network, not node-fatal hardware.
* "Revisiting Reliability in Large-Scale Machine Learning Research
  Clusters" (arXiv 2410.21680, PAPERS.md) — the Meta study: job MTTF of
  roughly 7.9 hours at 1024-GPU scale, which with 8-GPU nodes anchors a
  per-node MTBF of ~42 days, and MTTF scaling inversely with the number
  of nodes (independent Poisson superposition).

Numbers here are the single source of truth: the generators read them,
``tests/test_calibration.py`` statistically asserts the generated event
streams match them (Poisson counts, category shares, exponential
inter-arrival KS, 1/n MTTF scaling), and ``benchmarks/bench_frontier.py``
drives the recovery-policy frontier over traces drawn from them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.detection import ErrorKind

DAY = 24 * 3600.0

# ---------------------------------------------------------------------------
# Per-category failure taxonomy (Acme Table 3 / Meta §4, collapsed onto
# the repo's ErrorKind vocabulary).  ``share`` is the fraction of all
# failure interruptions attributed to the category; shares sum to 1.
# SEV1 categories (node-fatal hardware / lost nodes) carry a repair-time
# range; software/transient categories release the node immediately.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FailureCategory:
    name: str
    share: float                              # fraction of all failures
    kinds: Tuple[ErrorKind, ...]              # ErrorKinds drawn uniformly
    repair_range_s: Optional[Tuple[float, float]] = None  # SEV1 only


CATEGORIES: Tuple[FailureCategory, ...] = (
    # -- node-fatal hardware (SEV1), ~31% in total: Acme attributes ~30%
    #    of failures to infrastructure, dominated by NVLink/ECC/network
    FailureCategory("nvlink", 0.09, (ErrorKind.NVLINK_ERROR,),
                    repair_range_s=(4 * 3600.0, 24 * 3600.0)),
    FailureCategory("ecc", 0.06, (ErrorKind.ECC_ERROR,),
                    repair_range_s=(2 * 3600.0, 12 * 3600.0)),
    FailureCategory("network_sev1", 0.12,
                    (ErrorKind.LOST_CONNECTION,
                     ErrorKind.INVALID_DMA_MAPPING),
                    repair_range_s=(1 * 3600.0, 8 * 3600.0)),
    FailureCategory("gpu_driver", 0.04, (ErrorKind.GPU_DRIVER_ERROR,),
                    repair_range_s=(1 * 3600.0, 6 * 3600.0)),
    # -- software crashes (SEV2-ish), the plurality of interruptions
    FailureCategory("software", 0.45,
                    (ErrorKind.CUDA_ERROR,
                     ErrorKind.OTHER_SOFTWARE_ERROR,
                     ErrorKind.EXITED_ABNORMALLY,
                     ErrorKind.ILLEGAL_MEMORY_ACCESS)),
    # -- transient network blips (SEV3)
    FailureCategory("network_transient", 0.16,
                    (ErrorKind.OTHER_NETWORK_ERROR,
                     ErrorKind.CONNECTION_REFUSED,
                     ErrorKind.LINK_FLAPPING)),
    # -- hangs caught by the statistical monitor
    FailureCategory("hang", 0.08,
                    (ErrorKind.NCCL_TIMEOUT, ErrorKind.TASK_HANG)),
)


@dataclass(frozen=True)
class FleetCalibration:
    """Rate table for the calibrated generators.

    ``node_mtbf_s`` anchors everything: Meta reports a ~7.9 h MTTF for
    1024-GPU (128-node) jobs; independent per-node Poisson failures give
    fleet MTTF = node_mtbf / n, so node_mtbf = 128 * 7.9 h ~ 42 days.
    """
    node_mtbf_s: float = 42.0 * DAY
    categories: Tuple[FailureCategory, ...] = CATEGORIES
    # slow-node degradation (stragglers): Acme's performance-degradation
    # anomalies; per-node rate, window length range
    slow_rate_per_node_s: float = 1.0 / (120.0 * DAY)
    slow_duration_range_s: Tuple[float, float] = (600.0, 7200.0)
    # iteration-time multiplier: above the 1.1x degradation margin,
    # below the 3x failure threshold (Fig. 6)
    slow_slowdown_range: Tuple[float, float] = (1.15, 2.5)
    # correlated bursts (switch/PSU domain): a group of nodes lost at
    # once — the replica-loss driver for tier-aware restores
    burst_rate_per_node_s: float = 1.0 / (1280.0 * DAY)
    burst_group_size: int = 8
    burst_hit_fraction: float = 0.75
    burst_repair_range_s: Tuple[float, float] = (1 * 3600.0, 6 * 3600.0)
    # preemption waves (cluster scheduler reclaims capacity): fleet-level
    # rate, fraction of nodes reclaimed per wave
    preempt_wave_rate_s: float = 1.0 / (30.0 * DAY)
    preempt_fraction_range: Tuple[float, float] = (0.1, 0.2)
    preempt_outage_range_s: Tuple[float, float] = (900.0, 3600.0)

    def failure_rate_s(self, n_nodes: int) -> float:
        """Fleet-level failure event rate (events/second)."""
        return float(n_nodes) / self.node_mtbf_s

    def mttf_s(self, n_nodes: int) -> float:
        """Expected fleet MTTF — scales as 1/n (Poisson superposition)."""
        return self.node_mtbf_s / float(n_nodes)

    def category_shares(self) -> Dict[str, float]:
        return {c.name: c.share for c in self.categories}

    def sev1_share(self) -> float:
        """Fraction of failures that are node-fatal (repair required)."""
        return sum(c.share for c in self.categories
                   if c.repair_range_s is not None)

    def scaled(self, intensity: float) -> "FleetCalibration":
        """A copy with every event rate multiplied by ``intensity``
        (shares and ranges untouched) — for stress/quick configs."""
        return dataclasses.replace(
            self,
            node_mtbf_s=self.node_mtbf_s / intensity,
            slow_rate_per_node_s=self.slow_rate_per_node_s * intensity,
            burst_rate_per_node_s=self.burst_rate_per_node_s * intensity,
            preempt_wave_rate_s=self.preempt_wave_rate_s * intensity)


DEFAULT_CALIBRATION = FleetCalibration()

# guard the committed table: shares must form a distribution
assert abs(sum(c.share for c in CATEGORIES) - 1.0) < 1e-12
