"""Cluster state: nodes, GPU workers, task placements."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional


@dataclass
class Node:
    node_id: int
    n_gpus: int = 8
    healthy: bool = True
    repair_done_at: Optional[float] = None   # when a failed node returns


class Cluster:
    def __init__(self, n_nodes: int = 16, gpus_per_node: int = 8):
        self.nodes: List[Node] = [Node(i, gpus_per_node)
                                  for i in range(n_nodes)]
        self.gpus_per_node = gpus_per_node
        # placement: task index per node (None = free pool)
        self.placement: Dict[int, Optional[int]] = {
            i: None for i in range(n_nodes)}
        # index of drained node ids, maintained by fail/recover so the
        # control loop's repair sweep and capacity reads are O(#unhealthy)
        # instead of O(#nodes) per tick at fleet scale
        self._unhealthy: set = set()
        self._total_gpus = sum(n.n_gpus for n in self.nodes)

    # ---- capacity ----------------------------------------------------------

    def healthy_nodes(self) -> List[Node]:
        return [n for n in self.nodes if n.healthy]

    def healthy_workers(self) -> int:
        return self._total_gpus - sum(self.nodes[i].n_gpus
                                      for i in self._unhealthy)

    def free_healthy_nodes(self) -> List[Node]:
        return [n for n in self.healthy_nodes()
                if self.placement[n.node_id] is None]

    # ---- failures / recovery ----------------------------------------------

    def fail_node(self, node_id: int, repair_done_at: float) -> Optional[int]:
        """Drain a node; returns the task index that owned it (if any)."""
        node = self.nodes[node_id]
        node.healthy = False
        node.repair_done_at = repair_done_at
        self._unhealthy.add(node_id)
        owner = self.placement[node_id]
        self.placement[node_id] = None
        return owner

    def recover_node(self, node_id: int) -> None:
        node = self.nodes[node_id]
        node.healthy = True
        node.repair_done_at = None
        self._unhealthy.discard(node_id)

    def repair_due(self, now: float) -> List[Node]:
        """Drained nodes whose repair has completed, id order — the
        control loop's rejoin sweep, O(#unhealthy) not O(#nodes)."""
        out = []
        for nid in sorted(self._unhealthy):
            n = self.nodes[nid]
            if not n.healthy and n.repair_done_at is not None \
                    and n.repair_done_at <= now:
                out.append(n)
        return out

    # ---- placement ---------------------------------------------------------

    def nodes_of(self, task: int) -> List[int]:
        return [nid for nid, t in self.placement.items() if t == task]

    def workers_of(self, task: int) -> int:
        return len(self.nodes_of(task)) * self.gpus_per_node

    def assign(self, assignment: List[int]) -> None:
        """Re-place tasks onto healthy nodes for a worker assignment
        (multiples of gpus_per_node; remainders are rounded down —
        GPU-granular placement inside a node is handled by the task's own
        parallelism config)."""
        for nid in self.placement:
            self.placement[nid] = None
        free = [n.node_id for n in self.healthy_nodes()]
        for ti, workers in enumerate(assignment):
            need = workers // self.gpus_per_node
            for _ in range(need):
                if not free:
                    break
                self.placement[free.pop(0)] = ti
