from repro.checkpoint import persistent
from repro.checkpoint.inmemory import InMemoryStore
from repro.checkpoint.manager import CheckpointManager

__all__ = ["persistent", "InMemoryStore", "CheckpointManager"]
