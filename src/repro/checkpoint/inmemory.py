"""GEMINI-style in-memory checkpointing [SOSP'23, ref 49 in the paper].

Each agent keeps the latest training state snapshot in host CPU RAM and
*replicates it to a neighbor host* (ring placement), so that when a node
fails, its state is recoverable from the neighbor's RAM instead of remote
storage.  Unicron's agent manages this store and asynchronously spools
snapshots to the persistent tier (checkpoint.persistent).

This module implements the functional store; the cluster simulator charges
the paper-calibrated bandwidths for each tier.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _snapshot(tree: Any) -> Any:
    """Copy a pytree to host memory (numpy)."""
    return jax.tree.map(lambda x: np.array(x), tree)


class InMemoryStore:
    """Ring-replicated host-RAM checkpoint store.

    Keyed by (task_id, rank).  ``put`` stores the snapshot locally and on
    the ring neighbor; ``get`` implements the recovery preference:
    local copy -> neighbor replica.
    """

    def __init__(self, n_ranks: int):
        self.n_ranks = n_ranks
        self._local: Dict[Tuple[str, int], Tuple[int, Any]] = {}
        self._replica: Dict[Tuple[str, int], Tuple[int, Any]] = {}

    def neighbor(self, rank: int) -> int:
        return (rank + 1) % self.n_ranks

    def put(self, task: str, rank: int, step: int, tree: Any) -> None:
        snap = _snapshot(tree)
        self._local[(task, rank)] = (step, snap)
        self._replica[(task, self.neighbor(rank))] = (step, snap)

    def drop_rank(self, task: str, rank: int) -> None:
        """Simulate host loss: local copy and any replica *held on* the
        failed host vanish."""
        self._local.pop((task, rank), None)
        self._replica.pop((task, rank), None)

    def get(self, task: str, rank: int) -> Optional[Tuple[int, Any, str]]:
        """Returns (step, snapshot, source) or None."""
        if (task, rank) in self._local:
            s, t = self._local[(task, rank)]
            return s, t, "inmemory_local"
        if (task, self.neighbor(rank)) in self._replica:
            s, t = self._replica[(task, self.neighbor(rank))]
            return s, t, "inmemory_replica"
        return None

    def available(self, task: str, rank: int) -> bool:
        return self.get(task, rank) is not None
