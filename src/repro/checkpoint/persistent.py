"""Persistent (remote-storage) checkpointing.

Pytrees are flattened to path-keyed npz archives.  In the paper's setting
this is the cloud filesystem tier (20 GB/s); the simulator charges that
bandwidth, while this module provides the real functional store used by
examples and tests.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    with open(os.path.join(directory, "latest"), "w") as f:
        f.write(str(step))
    return path


def _scan_steps(directory: str) -> Optional[int]:
    """Newest complete archive on disk, ignoring in-flight ``.tmp.npz``
    leftovers from a writer that died mid-``save``."""
    best = None
    try:
        names = os.listdir(directory)
    except OSError:
        return None
    for name in names:
        if not name.startswith("ckpt_") or not name.endswith(".npz"):
            continue
        if name.endswith(".tmp.npz"):
            continue
        stem = name[len("ckpt_"):-len(".npz")]
        if not stem.isdigit():
            continue
        step = int(stem)
        if best is None or step > best:
            best = step
    return best


def latest_step(directory: str) -> Optional[int]:
    """Crash-safe: the ``latest`` marker is written non-atomically after
    the archive, so a crash can leave it torn, empty, or pointing at a
    step whose archive never landed.  Any of those falls back to
    scanning for the newest complete archive."""
    marker = os.path.join(directory, "latest")
    step = None
    try:
        with open(marker) as f:
            step = int(f.read().strip())
    except (OSError, ValueError):
        step = None
    if step is not None and os.path.exists(
            os.path.join(directory, f"ckpt_{step:08d}.npz")):
        return step
    return _scan_steps(directory)


def restore(directory: str, like: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure (and dtypes) of ``like``."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    new_leaves = []
    for p, leaf in leaves_with_path:
        key = jax.tree_util.keystr(p)
        arr = data[key]
        new_leaves.append(np.asarray(arr).astype(leaf.dtype)
                          if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(_treedef_of(like), new_leaves)


def checkpoint_nbytes(tree: Any) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))
