"""Hierarchical checkpoint manager — the *nearest principle* (§6.3).

Recovery preference order when a task needs state:

  1. **DP replica** — a healthy data-parallel peer already holds the full
     parameter/optimizer state; replicate over the interconnect.
  2. **In-memory checkpoint** — GEMINI-style host-RAM snapshot (local or
     ring neighbor).
  3. **Persistent checkpoint** — remote cloud filesystem, slowest tier.

``restore`` returns (state, source) so callers (and the simulator, which
charges per-tier costs) know which tier satisfied the request.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

from repro.checkpoint import inmemory, persistent


class CheckpointManager:
    def __init__(self, directory: str, n_ranks: int,
                 persist_every: int = 10, *, task: str):
        """``task`` is the task id keying the in-memory store: a manager
        serves exactly one training task, and the id must match what the
        coordinator/planner uses so ring snapshots survive handoffs
        between managers of the same task."""
        self.directory = directory
        self.store = inmemory.InMemoryStore(n_ranks)
        self.persist_every = persist_every
        self.task = task

    # ---- save path -------------------------------------------------------

    def save(self, rank: int, step: int, state: Any) -> None:
        """In-memory snapshot every call; async spool to persistent tier
        every ``persist_every`` steps (synchronous here; the simulator
        models the asynchrony)."""
        self.store.put(self.task, rank, step, state)
        if step % self.persist_every == 0:
            persistent.save(self.directory, step, state)

    # ---- restore path (nearest principle) ---------------------------------

    def restore(self, rank: int, like: Any,
                dp_peer_state: Optional[Any] = None,
                peer_step: Optional[int] = None) -> Tuple[Any, int, str]:
        """Returns (state, step, source).

        ``dp_peer_state`` is the live state of a healthy DP replica if one
        exists — the nearest source (the caller knows its peers; Unicron's
        coordinator passes it when replication is possible).
        """
        if dp_peer_state is not None:
            return dp_peer_state, int(peer_step or 0), "dp_replica"
        hit = self.store.get(self.task, rank)
        if hit is not None:
            step, snap, src = hit
            return snap, step, src
        step = persistent.latest_step(self.directory)
        if step is not None:
            return persistent.restore(self.directory, like, step), step, \
                "persistent"
        raise FileNotFoundError("no recovery source available")

    def drop_rank(self, rank: int) -> None:
        self.store.drop_rank(self.task, rank)
