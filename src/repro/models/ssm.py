"""Mamba2 (SSD — state-space duality) block.  [arXiv:2405.21060]

TPU adaptation: the SSD scan is computed in *chunks* so that nearly all
FLOPs are dense einsums (MXU-friendly) — intra-chunk attention-like
matmuls plus an inter-chunk `lax.scan` carrying the (H, P, N) state.  The
recurrence implemented is

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t
    y_t = C_t . h_t + D x_t

Decode is the O(1)-per-token recurrent update (the long_500k path).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers

N_GROUPS = 1  # B/C projection groups


# ---------------------------------------------------------------------------
# Chunked SSD scan (pure jnp; the Pallas kernel oracle mirrors this)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """x: (B,S,H,P) f32; dt: (B,S,H) f32 (>0); A: (H,) f32 (<0);
    Bm, Cm: (B,S,G,N) f32.  Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    xc = x.reshape(Bsz, nc, L, H, P)
    dtc = dt.reshape(Bsz, nc, L, H)
    Bc = Bm.reshape(Bsz, nc, L, G, N)
    Cc = Cm.reshape(Bsz, nc, L, G, N)

    a = dtc * A[None, None, None, :]                    # (B,c,L,H) log-decay
    acum = jnp.cumsum(a, axis=2)                        # inclusive cumsum

    # intra-chunk: Lmat[l,s] = exp(acum[l]-acum[s]) for s<=l
    diff = acum[:, :, :, None, :] - acum[:, :, None, :, :]   # (B,c,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)

    rep = H // G
    Bh = jnp.repeat(Bc, rep, axis=3)                    # (B,c,L,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bclhn,bcshn->bclsh", Ch, Bh)   # (B,c,L,L,H)
    y_diag = jnp.einsum("bclsh,bclsh,bcsh,bcshp->bclhp",
                        scores, lmat, dtc, xc)

    # chunk-end states: sum_s exp(acum[-1]-acum[s]) dt_s B_s x_s
    decay_st = jnp.exp(acum[:, :, -1:, :] - acum)       # (B,c,L,H)
    states = jnp.einsum("bcshn,bcsh,bcsh,bcshp->bchpn",
                        Bh, decay_st, dtc, xc)          # (B,c,H,P,N)
    chunk_decay = jnp.exp(acum[:, :, -1, :])            # (B,c,H)

    # inter-chunk recurrence
    s0 = (jnp.zeros((Bsz, H, P, N), x.dtype) if init_state is None
          else init_state)

    def step(carry, inp):
        st_c, dec_c = inp
        new = carry * dec_c[:, :, None, None] + st_c
        return new, carry                               # emit state BEFORE chunk

    final, prev_states = lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,c,H,P,N)

    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Ch, prev_states, jnp.exp(acum))
    y = (y_diag + y_off).reshape(Bsz, nc * L, H, P)[:, :S]
    return y, final


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """One-token recurrent update.
    state: (B,H,P,N); x: (B,H,P); dt: (B,H); Bm, Cm: (B,G,N).
    Returns (y (B,H,P), new_state)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                    # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    decay = jnp.exp(dt * A[None, :])                    # (B,H)
    new = (state * decay[:, :, None, None]
           + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, x))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new)
    return y, new


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def init_mamba(key, cfg, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.n_heads(d)
    N = s.d_state
    conv_ch = di + 2 * N_GROUPS * N
    ks = jax.random.split(key, 4)
    return {
        "w_in": layers.init_dense(ks[0], d, 2 * di + 2 * N_GROUPS * N + H,
                                  dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32)
                   / math.sqrt(s.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "w_out": layers.init_dense(ks[3], di, d, dtype),
    }


def _causal_conv(xbc, w, b):
    """Depthwise causal conv.  xbc: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out + b[None, None, :]


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = N_GROUPS * s.d_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * gn]
    dt_raw = zxbcdt[..., di + di + 2 * gn:]
    return z, xbc, dt_raw


def mamba_apply(p: dict, cfg, x: jnp.ndarray, kernel: str = "jnp"):
    """Full-sequence forward.  x: (B,S,d) -> (B,S,d)."""
    s = cfg.ssm
    B, S, d = x.shape
    di = s.d_inner(d)
    H = s.n_heads(d)
    N = s.d_state
    gn = N_GROUPS * N

    z, xbc, dt_raw = _split_proj(cfg, x @ p["w_in"])
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :di].reshape(B, S, H, s.head_dim).astype(jnp.float32)
    Bm = xbc[..., di:di + gn].reshape(B, S, N_GROUPS, N).astype(jnp.float32)
    Cm = xbc[..., di + gn:].reshape(B, S, N_GROUPS, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if kernel == "pallas":
        from repro.kernels import ops as kops
        y, _ = kops.ssd_scan(xs, dt, A, Bm, Cm, chunk=s.chunk)
    else:
        y, _ = ssd_chunked(xs, dt, A, Bm, Cm, chunk=s.chunk)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, S, di).astype(x.dtype)
    y = layers.rms_norm_weighted(y * jax.nn.silu(z), p["gate_norm"])
    return y @ p["w_out"]


def mamba_init_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.n_heads(d)
    return {
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * N_GROUPS * s.d_state),
                          dtype),
    }


def mamba_decode(p: dict, cfg, x: jnp.ndarray, state: dict):
    """One-token decode.  x: (B,1,d); state: {"ssm","conv"}."""
    s = cfg.ssm
    B, _, d = x.shape
    di = s.d_inner(d)
    H = s.n_heads(d)
    N = s.d_state
    gn = N_GROUPS * N

    z, xbc, dt_raw = _split_proj(cfg, x @ p["w_in"])     # (B,1,*)
    xbc = xbc[:, 0]
    hist = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    xbc_t = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:]

    xs = xbc_t[:, :di].reshape(B, H, s.head_dim).astype(jnp.float32)
    Bm = xbc_t[:, di:di + gn].reshape(B, N_GROUPS, N).astype(jnp.float32)
    Cm = xbc_t[:, di + gn:].reshape(B, N_GROUPS, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, new_ssm = ssd_decode_step(state["ssm"], xs, dt, A, Bm, Cm)
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = layers.rms_norm_weighted(y * jax.nn.silu(z), p["gate_norm"])
    return y @ p["w_out"], {"ssm": new_ssm, "conv": new_conv}
