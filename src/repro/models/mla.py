"""DeepSeek-V3 Multi-head Latent Attention.  [arXiv:2412.19437]

Prefill materializes K/V from the compressed latent; decode uses the
*absorbed* formulation — the KV cache holds only the (kv_lora_rank +
qk_rope_head_dim) latent per token, and W_uk / W_uv are folded into the
query/output paths.  This is the memory-optimal TPU mapping of MLA.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


def init_mla(key, cfg, dtype) -> dict:
    m = cfg.mla
    d = cfg.d_model
    H = cfg.attn.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "w_dq": layers.init_dense(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": layers.init_dense(ks[1], m.q_lora_rank, H * (dn + dr), dtype),
        "w_dkv": layers.init_dense(ks[2], d, m.kv_lora_rank + dr, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_uk": layers.init_dense(ks[3], m.kv_lora_rank, H * dn, dtype),
        "w_uv": layers.init_dense(ks[4], m.kv_lora_rank, H * dv, dtype),
        "wo": layers.init_dense(ks[5], H * dv, d, dtype),
    }


def _project_q(p, cfg, x, positions):
    m = cfg.mla
    H = cfg.attn.n_heads
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    B, S, _ = x.shape
    cq = layers.rms_norm_weighted(x @ p["w_dq"], p["q_norm"])
    q = (cq @ p["w_uq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.attn.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p, cfg, x, positions):
    m = cfg.mla
    dr = m.qk_rope_head_dim
    ckv_full = x @ p["w_dkv"]
    ckv = layers.rms_norm_weighted(ckv_full[..., :m.kv_lora_rank],
                                   p["kv_norm"])
    k_rope = layers.apply_rope(ckv_full[..., m.kv_lora_rank:], positions,
                               cfg.attn.rope_theta)          # (B,S,dr)
    return ckv, k_rope


def mla_apply(p: dict, cfg, x: jnp.ndarray, positions: jnp.ndarray,
              use_blocked: bool = True, kernel: str = "jnp") -> jnp.ndarray:
    """Full-sequence (train / prefill).  x: (B,S,d)."""
    m = cfg.mla
    H = cfg.attn.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    B, S, _ = x.shape
    pos = positions[None]

    q_nope, q_rope = _project_q(p, cfg, x, pos)
    ckv, k_rope = _project_kv_latent(p, cfg, x, pos)
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, dn)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, dr))], axis=-1)
    if kernel == "flash":
        from repro.models.flash_vjp import flash_attention_jnp
        o = flash_attention_jnp(q, k, v, True, 0, 0.0, 0)
    elif use_blocked and S > 1024:
        o = layers.blocked_attention(q, k, v, causal=True, q_offset=0)
    else:
        o = layers.simple_attention(q, k, v, causal=True, q_offset=0)
    return o.reshape(B, S, H * dv) @ p["wo"]


def mla_init_cache(cfg, batch: int, capacity: int, dtype) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
    }


def mla_decode(p: dict, cfg, x: jnp.ndarray, cache: dict, pos):
    """Absorbed one-token decode.  x: (B,1,d); cache latent buffers."""
    m = cfg.mla
    H = cfg.attn.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    B = x.shape[0]
    C = cache["ckv"].shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    posv = pos_b[:, None]                                 # (B, 1)

    q_nope, q_rope = _project_q(p, cfg, x, posv)          # (B,1,H,dn/(dr))
    ckv_t, k_rope_t = _project_kv_latent(p, cfg, x, posv)  # (B,1,rank),(B,1,dr)
    lanes = jnp.arange(B)
    new_ckv = cache["ckv"].at[lanes, pos_b].set(ckv_t[:, 0])
    new_krope = cache["k_rope"].at[lanes, pos_b].set(k_rope_t[:, 0])

    # absorb W_uk into q:  q_lat (B,1,H,rank)
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, dn)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat, new_ckv.astype(jnp.float32))
         + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                      new_krope.astype(jnp.float32)))
    s = s / math.sqrt(dn + dr)
    valid = jnp.arange(C)[None, :] <= pos_b[:, None]      # (B, C)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    ctx = jnp.einsum("bhqs,bsr->bqhr", w, new_ckv.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, dv)
    o = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, H * dv).astype(x.dtype)
    return o @ p["wo"], {"ckv": new_ckv, "k_rope": new_krope}
