"""Unified model builder.

``build_model(cfg)`` returns a :class:`Model` bundle of pure functions:

  init(key)                          -> params
  forward(params, batch, ...)        -> (logits, extras)
  loss(params, batch, ...)           -> (scalar, metrics)
  init_cache(batch, capacity, dtype) -> decode caches
  decode_step(params, caches, tokens, pos) -> (logits, caches)

Layer stacks are executed as ``lax.scan`` over parameter pytrees stacked
along a leading ``count`` axis, so the lowered HLO is compact even for the
61-layer DeepSeek config.  Period-structured stacks (gemma3's 5-local:1-
global pattern, zamba2's shared block every 6 mamba layers) scan over
*periods* with the period slots unrolled in the body — locality is then
static per slot.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, BLOCK_HYBRID_SHARED, BLOCK_MLA_DENSE
from repro.models import blocks, layers

MTP_WEIGHT = 0.3

# Sequence-parallel TP (perf variant "seqpar", EXPERIMENTS.md §Perf):
# when set to a NamedSharding for the (B, S, d) residual stream with the
# sequence dim on the "model" axis, a sharding constraint is applied to
# the residual between blocks.  GSPMD then turns the per-layer TP
# all-reduces into reduce-scatter + all-gather pairs (Korthikanti et al.)
# and runs norms/elementwise on S/tp-sized shards.
SEQ_SHARDING = None


def _constrain(x):
    if SEQ_SHARDING is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, SEQ_SHARDING)
    return x


# ---------------------------------------------------------------------------
# Segment plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    kind: str
    count: int                 # scan length (number of periods/groups)
    inner: int                 # layers per scan step
    locality: Tuple[bool, ...]  # per-slot sliding-window flag
    shared_after: bool = False  # zamba2: apply shared block after slots

    @property
    def n_layers(self) -> int:
        return self.count * self.inner


def segment_plan(cfg: ArchConfig) -> List[Segment]:
    segs: List[Segment] = []
    for kind, count in cfg.block_pattern:
        if count == 0:
            continue
        if kind == BLOCK_HYBRID_SHARED and cfg.shared_period:
            period = min(cfg.shared_period, count)
            groups, rem = divmod(count, period)
            if groups:
                segs.append(Segment(kind, groups, period,
                                    (False,) * period, shared_after=True))
            if rem:
                segs.append(Segment(kind, 1, rem, (False,) * rem))
            continue
        a = cfg.attn
        if a is not None and a.window and a.local_ratio[0] > 0:
            loc, glob = a.local_ratio
            period = loc + glob
            pattern = (True,) * loc + (False,) * glob
            if count < period:
                segs.append(Segment(kind, 1, count, pattern[:count]))
                continue
            groups, rem = divmod(count, period)
            segs.append(Segment(kind, groups, period, pattern))
            if rem:
                segs.append(Segment(kind, 1, rem, pattern[:rem]))
            continue
        segs.append(Segment(kind, count, 1, (False,)))
    return segs


# ---------------------------------------------------------------------------
# Model bundle
# ---------------------------------------------------------------------------


@dataclass
class Model:
    cfg: ArchConfig
    init: Callable
    forward: Callable
    loss: Callable
    init_cache: Callable
    decode_step: Callable
    segments: List[Segment]


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None):
    """Mean masked token cross-entropy.  logits f32 (..., V)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def build_model(cfg: ArchConfig) -> Model:
    dtype = jnp.dtype(cfg.param_dtype)
    segs = segment_plan(cfg)

    # ---------------- init ----------------

    def init(key) -> dict:
        keys = jax.random.split(key, len(segs) + 4)
        params: dict = {"embed": layers.init_embed(keys[0], cfg.vocab,
                                                   cfg.d_model, dtype)}
        seg_params = []
        for si, seg in enumerate(segs):
            slot_list = []
            for j in range(seg.inner):
                ks = jax.random.split(jax.random.fold_in(keys[1 + si], j),
                                      seg.count)
                slot_list.append(jax.vmap(
                    lambda k: blocks.init_block(k, cfg, seg.kind, dtype))(ks))
            seg_params.append(slot_list)
        params["segments"] = seg_params
        if cfg.shared_period:
            params["shared"] = blocks.init_shared_block(keys[-3], cfg, dtype)
        params["final_norm"] = layers.init_norm(cfg.d_model, cfg.norm, dtype)
        if not cfg.tie_embeddings:
            params["head"] = {"w": layers.init_dense(
                keys[-2], cfg.d_model, cfg.vocab, dtype).T}
        if cfg.mtp:
            params["mtp"] = {
                "block": blocks.init_block(keys[-1], cfg, BLOCK_MLA_DENSE
                                           if cfg.mla else segs[0].kind,
                                           dtype),
                "norm": layers.init_norm(cfg.d_model, cfg.norm, dtype),
            }
        return params

    def _head_w(params):
        return params["embed"]["w"] if cfg.tie_embeddings \
            else params["head"]["w"]

    # ---------------- embed inputs ----------------

    def _embed_inputs(params, batch):
        if cfg.modality == "audio_stub":
            return batch["frames"].astype(dtype)
        x = layers.embed_apply(params["embed"], batch["tokens"],
                               cfg.embed_scale, cfg.d_model)
        if cfg.modality == "vision_stub":
            pre = batch["prefix_embeds"].astype(x.dtype)
            x = jnp.concatenate([pre, x], axis=1)
        return x

    # ---------------- forward ----------------

    def _run_segments(params, x, positions, kernel, remat):
        aux = jnp.zeros((), jnp.float32)
        for seg, slot_params in zip(segs, params["segments"]):
            shared_p = params.get("shared")

            def body(carry, xs, seg=seg, shared_p=shared_p):
                h, a = carry
                for j in range(seg.inner):
                    h, aj = blocks.block_apply(
                        xs[j], cfg, seg.kind, h, positions,
                        layer_is_local=seg.locality[j], kernel=kernel)
                    h = _constrain(h)
                    a = a + aj
                if seg.shared_after:
                    h = blocks.shared_block_apply(shared_p, cfg, h,
                                                  positions, kernel=kernel)
                    h = _constrain(h)
                return (h, a), None

            if remat:
                body = jax.checkpoint(body)
            (x, aux), _ = lax.scan(body, (x, aux), slot_params)
        return x, aux

    def forward(params, batch, *, kernel: str = "jnp", remat: bool = False,
                last_logits_only: bool = False):
        x = _embed_inputs(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        x, aux = _run_segments(params, x, positions, kernel, remat)
        h = layers.norm_apply(params["final_norm"], x, cfg.norm)
        if last_logits_only:
            # Serving prefill: only the last position's logits are needed
            # (full-seq logits at 32k x 262k vocab would be infeasible).
            logits = layers.logits_apply(_head_w(params), h[:, -1:])
            return logits, {"aux": aux}
        logits = layers.logits_apply(_head_w(params), h)
        extras = {"aux": aux}
        if cfg.mtp:
            hm, _ = blocks.block_apply(
                params["mtp"]["block"], cfg,
                BLOCK_MLA_DENSE if cfg.mla else segs[0].kind, x, positions)
            hm = layers.norm_apply(params["mtp"]["norm"], hm, cfg.norm)
            extras["mtp_logits"] = layers.logits_apply(_head_w(params), hm)
        return logits, extras

    # ---------------- loss ----------------

    def loss(params, batch, *, kernel: str = "jnp", remat: bool = False):
        logits, extras = forward(params, batch, kernel=kernel, remat=remat)
        metrics = {}
        if cfg.modality == "audio_stub":
            ce = cross_entropy(logits, batch["labels"],
                               batch.get("loss_mask"))
        else:
            toks = batch["tokens"]
            if cfg.modality == "vision_stub":
                logits = logits[:, -toks.shape[1]:]
            ce = cross_entropy(logits[:, :-1], toks[:, 1:],
                               None if batch.get("loss_mask") is None
                               else batch["loss_mask"][:, 1:])
        total = ce + extras["aux"]
        metrics["ce"] = ce
        metrics["aux"] = extras["aux"]
        if cfg.mtp and "mtp_logits" in extras:
            ml = extras["mtp_logits"]
            toks = batch["tokens"]
            if cfg.modality == "vision_stub":
                ml = ml[:, -toks.shape[1]:]
            mtp_ce = cross_entropy(ml[:, :-2], toks[:, 2:])
            total = total + MTP_WEIGHT * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = total
        return total, metrics

    # ---------------- decode ----------------

    def init_cache(batch_size: int, capacity: int, cache_dtype=None):
        cdt = cache_dtype or dtype
        caches = []
        for seg in segs:
            slot_caches = []
            for j in range(seg.inner):
                one = blocks.block_cache(cfg, seg.kind, batch_size, capacity,
                                         cdt, layer_is_local=seg.locality[j])
                slot_caches.append(jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (seg.count,) + a.shape)
                    .copy() if seg.count > 1 else a[None], one))
            entry = {"slots": slot_caches}
            if seg.shared_after:
                one = blocks.block_cache(cfg, "attn_dense", batch_size,
                                         capacity, cdt)
                entry["shared"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (seg.count,) + a.shape)
                    .copy() if seg.count > 1 else a[None], one)
            caches.append(entry)
        return caches

    def decode_step(params, caches, tokens, pos):
        """tokens: (B,) int32; pos: scalar int32 (absolute position).
        Returns (logits (B, vocab) f32, new_caches)."""
        x = layers.embed_apply(params["embed"], tokens[:, None],
                               cfg.embed_scale, cfg.d_model)
        new_caches = []
        for seg, slot_params, cache in zip(segs, params["segments"], caches):
            shared_p = params.get("shared")

            def body(h, xs, seg=seg, shared_p=shared_p):
                sp, sc = xs
                new_sc = {"slots": []}
                for j in range(seg.inner):
                    h, nc = blocks.block_decode(
                        sp[j], cfg, seg.kind, h, sc["slots"][j], pos,
                        layer_is_local=seg.locality[j])
                    new_sc["slots"].append(nc)
                if seg.shared_after:
                    h, nsh = blocks.shared_block_decode(shared_p, cfg, h,
                                                        sc["shared"], pos)
                    new_sc["shared"] = nsh
                return h, new_sc

            x, new_cache = lax.scan(body, x, (slot_params, cache))
            new_caches.append(new_cache)
        h = layers.norm_apply(params["final_norm"], x, cfg.norm)
        logits = layers.logits_apply(_head_w(params), h)[:, 0]
        return logits, new_caches

    return Model(cfg=cfg, init=init, forward=forward, loss=loss,
                 init_cache=init_cache, decode_step=decode_step,
                 segments=segs)
