"""Core model primitives: norms, RoPE, MLPs, attention.

Everything is functional: ``init_*`` builds a param dict, ``*_apply``
consumes it.  Attention is implemented *blocked* (flash-style online
softmax over KV blocks) so that prefill at 32k/524k sequence lengths never
materializes an (S, S) score matrix — this is both the memory-realistic
HLO for the dry-run and the jnp oracle for the Pallas kernel.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(d: int, kind: str, dtype) -> dict:
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def norm_apply(p: dict, x: jnp.ndarray, kind: str, eps: float = 1e-6):
    if kind == "rmsnorm" and RMSNORM_FUSED:
        return rmsnorm_fused(x, p["scale"])
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_norm_weighted(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    """RMSNorm with an explicit scale vector (used for qk-norm, mamba gate)."""
    if RMSNORM_FUSED:
        return rmsnorm_fused(x, scale)
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_in": init_dense(ks[0], d_model, d_ff, dtype),
         "w_out": init_dense(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = init_dense(ks[2], d_model, d_ff, dtype)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, act: str, gated: bool) -> jnp.ndarray:
    h = x @ p["w_in"]
    a = jax.nn.gelu(h, approximate=True) if act == "gelu" else jax.nn.silu(h)
    if gated:
        a = a * (x @ p["w_gate"])
    return a @ p["w_out"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs       # (..., S, half)
    if x.ndim == ang.ndim + 1:                                   # head dim present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, optional qk-norm, sliding window, softcap)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, d_model: int, dtype) -> dict:
    a = cfg.attn
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_dense(ks[0], d_model, a.n_heads * a.head_dim, dtype),
        "wk": init_dense(ks[1], d_model, a.n_kv_heads * a.head_dim, dtype),
        "wv": init_dense(ks[2], d_model, a.n_kv_heads * a.head_dim, dtype),
        "wo": init_dense(ks[3], a.n_heads * a.head_dim, d_model, dtype),
    }
    if a.qk_norm:
        p["q_norm"] = jnp.ones((a.head_dim,), dtype)
        p["k_norm"] = jnp.ones((a.head_dim,), dtype)
    return p


def blocked_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                      q_block=512, kv_block=1024,
                      q_offset=None) -> jnp.ndarray:
    """Flash-style blocked attention (pure jnp oracle).

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H % KV == 0.
    ``q_offset``: absolute position of q[:,0] (scalar int); defaults to
    Sk - Sq (decode-style right alignment).
    Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // KV
    if q_offset is None:
        q_offset = Sk - Sq

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    # pad to multiples
    nq = -(-Sq // q_block)
    nk = -(-Sk // kv_block)
    pq, pk = nq * q_block - Sq, nk * kv_block - Sk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    k_poss = jnp.where(jnp.arange(nk * kv_block) < Sk,
                       jnp.arange(nk * kv_block), jnp.iinfo(jnp.int32).max)

    qb = qp.reshape(B, nq, q_block, H, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_idx):
        qi, iq = qi_idx
        q_pos = q_offset + iq * q_block + jnp.arange(q_block)
        qg = qi.reshape(B, q_block, KV, G, D).astype(jnp.float32)

        def kv_step(carry, kj_idx):
            acc, m, l = carry
            kj, vj, jk = kj_idx
            kpos = lax.dynamic_slice_in_dim(k_poss, jk * kv_block, kv_block)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj.astype(jnp.float32))
            s = s / math.sqrt(D)
            if softcap and softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= kpos[None, :] <= q_pos[:, None]
            if window and window > 0:
                mask &= kpos[None, :] > q_pos[:, None] - window
            mask &= (kpos < jnp.iinfo(jnp.int32).max)[None, :]
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard all-masked rows
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isinf(s), 0.0, p)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vj.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        kb = kp.reshape(B, nk, kv_block, KV, D).transpose(1, 0, 2, 3, 4)
        vb = vp.reshape(B, nk, kv_block, KV, Dv).transpose(1, 0, 2, 3, 4)
        acc0 = jnp.zeros((B, KV, G, q_block, Dv), jnp.float32)
        m0 = jnp.full((B, KV, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        (acc, m, l), _ = lax.scan(kv_step, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, KV, G, q_block, D) -> (B, q_block, H, D)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, q_block, H, Dv)
        return None, out

    _, ob = lax.scan(q_step, None, (qb, jnp.arange(nq)))
    out = ob.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H, Dv)[:, :Sq]
    return out.astype(q.dtype)


def simple_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                     q_offset=None) -> jnp.ndarray:
    """Unblocked reference attention (materializes full scores).  Used for
    small shapes and as a second-level oracle in tests."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // KV
    if q_offset is None:
        q_offset = Sk - Sq
    qg = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def attention_apply(p: dict, cfg, x: jnp.ndarray, *, layer_is_local: bool,
                    positions: jnp.ndarray, use_blocked: bool = True,
                    kernel: str = "jnp") -> jnp.ndarray:
    """Full-sequence (train / prefill) attention for one layer.

    x: (B, S, d_model); positions: (S,) absolute positions.
    ``layer_is_local`` selects the sliding-window mask for gemma3-style
    local layers.
    """
    a = cfg.attn
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, a.n_heads, a.head_dim)
    k = (x @ p["wk"]).reshape(B, S, a.n_kv_heads, a.head_dim)
    v = (x @ p["wv"]).reshape(B, S, a.n_kv_heads, a.head_dim)
    if a.qk_norm:
        q = rms_norm_weighted(q, p["q_norm"])
        k = rms_norm_weighted(k, p["k_norm"])
    q = apply_rope(q, positions[None], a.rope_theta)
    k = apply_rope(k, positions[None], a.rope_theta)
    window = a.window if (a.window and layer_is_local) else 0
    if kernel == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=a.causal, window=window,
                                 softcap=a.logit_softcap)
    elif kernel == "flash":
        from repro.models.flash_vjp import flash_attention_jnp
        o = flash_attention_jnp(q, k, v, a.causal, window,
                                a.logit_softcap, 0)
    elif use_blocked and S > 1024:
        o = blocked_attention(q, k, v, causal=a.causal, window=window,
                              softcap=a.logit_softcap, q_offset=0)
    else:
        o = simple_attention(q, k, v, causal=a.causal, window=window,
                             softcap=a.logit_softcap, q_offset=0)
    return o.reshape(B, S, a.n_heads * a.head_dim) @ p["wo"]


def attention_decode(p: dict, cfg, x: jnp.ndarray, cache_k, cache_v,
                     pos: jnp.ndarray, *, layer_is_local: bool):
    """One-token decode.  x: (B, 1, d); cache_k/v: (B, C, KV, D) where C is
    the cache capacity (full seq for global layers, window for local).
    ``pos``: int32 scalar or (B,) vector — absolute position of each
    lane's new token (per-lane positions enable continuous batching).

    For local (sliding-window) layers the cache is a ring buffer of size
    ``window``; for global layers a full-length buffer written at ``pos``.
    Returns (out (B,1,d), new_k, new_v).
    """
    a = cfg.attn
    B = x.shape[0]
    C = cache_k.shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q = (x @ p["wq"]).reshape(B, 1, a.n_heads, a.head_dim)
    k = (x @ p["wk"]).reshape(B, 1, a.n_kv_heads, a.head_dim)
    v = (x @ p["wv"]).reshape(B, 1, a.n_kv_heads, a.head_dim)
    if a.qk_norm:
        q = rms_norm_weighted(q, p["q_norm"])
        k = rms_norm_weighted(k, p["k_norm"])
    posv = pos_b[:, None]                                 # (B, 1)
    q = apply_rope(q, posv, a.rope_theta)
    k = apply_rope(k, posv, a.rope_theta)
    slot = jnp.where(jnp.array(layer_is_local and a.window > 0),
                     pos_b % jnp.maximum(C, 1),
                     jnp.minimum(pos_b, C - 1))           # (B,)
    lanes = jnp.arange(B)
    new_k = cache_k.at[lanes, slot].set(k[:, 0])
    new_v = cache_v.at[lanes, slot].set(v[:, 0])
    # validity mask over cache slots, per lane: (B, C)
    slots = jnp.arange(C)[None, :]
    posc = pos_b[:, None]
    if layer_is_local and a.window:
        valid = (slots <= posc % C) | (posc >= C)         # ring fill
        window_lo = posc - a.window
        abs_pos = jnp.where(slots <= posc % C, posc - (posc % C) + slots,
                            posc - (posc % C) + slots - C)
        valid &= (abs_pos > window_lo) & (abs_pos >= 0)
    else:
        valid = slots <= posc
    G = a.n_heads // a.n_kv_heads
    qg = q.reshape(B, 1, a.n_kv_heads, G, a.head_dim).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, new_k.astype(jnp.float32))
    s = s / math.sqrt(a.head_dim)
    if a.logit_softcap:
        s = jnp.tanh(s / a.logit_softcap) * a.logit_softcap
    s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, new_v.astype(jnp.float32))
    o = o.reshape(B, 1, a.n_heads * a.head_dim).astype(x.dtype)
    return o @ p["wo"], new_k, new_v


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d: int, dtype) -> dict:
    return {"w": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02)
            .astype(dtype)}


def embed_apply(p: dict, tokens: jnp.ndarray, scale: bool, d: int):
    x = jnp.take(p["w"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(d), x.dtype)
    return x


def logits_apply(head_w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """head_w: (vocab, d) (tied layout); returns f32 logits."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      head_w.astype(jnp.float32))

# ---------------------------------------------------------------------------
# Fused RMSNorm (analytic custom VJP) — perf variant "fusednorm"
# ---------------------------------------------------------------------------
#
# Autodiff of the straightforward rmsnorm produces 5+ separate f32
# elementwise chains over (tokens, d_model) in the backward (see
# EXPERIMENTS.md §Perf iteration 2).  The analytic VJP below computes
#
#   r  = rsqrt(mean(x^2) + eps)
#   dx = r*gs - x * r^3 * mean(gs*x)          with gs = g * scale
#   dscale = sum(g * x * r)
#
# in one fused expression, saving nothing but (x, scale).  Exact same
# math as the autodiff path to float tolerance (tests/test_kernels.py).

RMSNORM_FUSED = False          # flipped by launch.dryrun variant "fusednorm"


@jax.custom_vjp
def rmsnorm_fused(x, scale):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(ms + 1e-6)
            * scale.astype(jnp.float32)).astype(x.dtype)


def _rmsnorm_fused_fwd(x, scale):
    return rmsnorm_fused(x, scale), (x, scale)


def _rmsnorm_fused_bwd(res, g):
    x, scale = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    r = lax.rsqrt(ms + 1e-6)
    gs = gf * scale.astype(jnp.float32)
    d = x.shape[-1]
    dot = jnp.sum(gs * xf, axis=-1, keepdims=True) / d
    dx = (r * gs - xf * (r ** 3) * dot).astype(x.dtype)
    dscale = jnp.sum((gf * xf * r).reshape(-1, d), axis=0)         .astype(scale.dtype)
    return dx, dscale


rmsnorm_fused.defvjp(_rmsnorm_fused_fwd, _rmsnorm_fused_bwd)
