"""Blocked attention with a flash-style custom VJP (pure jnp).

The default jnp blocked attention differentiates *through* its
``lax.scan``, which makes XLA stack per-(q-block, kv-block) probability
intermediates into (nq, nk, ..., bq, bk) residual buffers — O(S^2) HBM
traffic that dominates the memory roofline term of every dense train
pair (see EXPERIMENTS.md §Perf).

This module implements the flash-attention backward instead: the forward
saves only (o, lse); the backward recomputes P per block-pair and
immediately consumes it in two block passes (dq; then dk/dv).  Nothing
of size O(S^2) ever hits HBM.  Selected with ``kernel="flash"``.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG = -1e30


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _mask(q_pos, k_pos, causal, window, seq_k):
    m = k_pos[None, :] < seq_k
    if causal:
        m = jnp.logical_and(m, k_pos[None, :] <= q_pos[:, None])
    if window and window > 0:
        m = jnp.logical_and(m, k_pos[None, :] > q_pos[:, None] - window)
    return m


def _fwd_blocked(q, k, v, causal, window, softcap, q_offset,
                 bq, bk) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (o (B,Sq,H,Dv), lse (B,Sq,H))."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    qp = _pad_to(q, nq * bq, 1).reshape(B, nq, bq, KV, G, D) \
        .transpose(1, 0, 3, 4, 2, 5)                      # (nq,B,KV,G,bq,D)
    kp = _pad_to(k, nk * bk, 1).reshape(B, nk, bk, KV, D) \
        .transpose(1, 0, 3, 2, 4)                         # (nk,B,KV,bk,D)
    vp = _pad_to(v, nk * bk, 1).reshape(B, nk, bk, KV, Dv) \
        .transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_i):
        qi, iq = qi_i
        q_pos = q_offset + iq * bq + jnp.arange(bq)
        qf = qi.astype(jnp.float32)

        def kv_step(carry, kj_vj_j):
            acc, m, l = carry
            kj, vj, jk = kj_vj_j
            k_pos = jk * bk + jnp.arange(bk)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qf,
                           kj.astype(jnp.float32)) * scale
            if softcap and softcap > 0:
                s = jnp.tanh(s / softcap) * softcap
            msk = _mask(q_pos, k_pos, causal, window, Sk)
            s = jnp.where(msk[None, None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.where(msk[None, None, None], jnp.exp(s - m_new[..., None]),
                          0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bksd->bkgqd", p, vj.astype(jnp.float32))
            return (acc * corr[..., None] + pv, m_new, l_new), None

        acc0 = jnp.zeros(qf.shape[:-1] + (Dv,), jnp.float32)
        m0 = jnp.full(qf.shape[:-1], NEG, jnp.float32)
        l0 = jnp.zeros(qf.shape[:-1], jnp.float32)
        (acc, m, l), _ = lax.scan(kv_step, (acc0, m0, l0),
                                  (kp, vp, jnp.arange(nk)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (o, lse)

    _, (ob, lseb) = lax.scan(q_step, None, (qp, jnp.arange(nq)))
    o = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * bq, H, Dv)[:, :Sq]
    lse = lseb.transpose(1, 0, 4, 2, 3).reshape(B, nq * bq, H)[:, :Sq]
    return o.astype(q.dtype), lse


def _bwd_blocked(causal, window, softcap, q_offset, bq, bk, res, do):
    q, k, v, o, lse = res
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    nq, nk = -(-Sq // bq), -(-Sk // bk)

    dof = do.astype(jnp.float32)
    Dvec = jnp.sum(dof * o.astype(jnp.float32), axis=-1)      # (B,Sq,H)

    def blk_q(x, extra=()):   # (B,Sq,KV,G,...) -> (nq,B,KV,G,bq,...)
        x = _pad_to(x, nq * bq, 1)
        x = x.reshape((B, nq, bq, KV, G) + x.shape[3:][1:])
        return x.transpose((1, 0, 3, 4, 2) + tuple(range(5, x.ndim)))

    qb = blk_q(q.reshape(B, Sq, KV, G, D).astype(jnp.float32))
    dob = blk_q(dof.reshape(B, Sq, KV, G, Dv))
    lseb = blk_q(lse.reshape(B, Sq, KV, G))
    Db = blk_q(Dvec.reshape(B, Sq, KV, G))
    kb = _pad_to(k, nk * bk, 1).reshape(B, nk, bk, KV, D) \
        .transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vb = _pad_to(v, nk * bk, 1).reshape(B, nk, bk, KV, Dv) \
        .transpose(1, 0, 3, 2, 4).astype(jnp.float32)

    def p_and_dcap(qi, kj, iq, jk):
        q_pos = q_offset + iq * bq + jnp.arange(bq)
        k_pos = jk * bk + jnp.arange(bk)
        s_raw = jnp.einsum("bkgqd,bksd->bkgqs", qi, kj) * scale
        if softcap and softcap > 0:
            t = jnp.tanh(s_raw / softcap)
            s = t * softcap
            dcap = 1.0 - t * t                 # d s_capped / d s_raw
        else:
            s = s_raw
            dcap = jnp.ones_like(s_raw)
        msk = _mask(q_pos, k_pos, causal, window, Sk)[None, None, None]
        return jnp.where(msk, s, NEG), dcap, msk

    # pass 1: dq — scan q blocks, inner scan kv blocks
    def dq_step(_, args):
        qi, doi, lsei, Di, iq = args

        def inner(acc, kv_j):
            kj, vj, jk = kv_j
            s, dcap, msk = p_and_dcap(qi, kj, iq, jk)
            p = jnp.where(msk, jnp.exp(s - lsei[..., None]), 0.0)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", doi, vj)
            ds = p * (dp - Di[..., None]) * dcap * scale
            return acc + jnp.einsum("bkgqs,bksd->bkgqd", ds, kj), None

        dq0 = jnp.zeros_like(qi)
        dqi, _ = lax.scan(inner, dq0, (kb, vb, jnp.arange(nk)))
        return None, dqi

    _, dqb = lax.scan(dq_step, None, (qb, dob, lseb, Db, jnp.arange(nq)))
    dq = dqb.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * bq, H, D)[:, :Sq]

    # pass 2: dk, dv — scan kv blocks, inner scan q blocks
    def dkv_step(_, args):
        kj, vj, jk = args

        def inner(carry, q_i):
            dkj, dvj = carry
            qi, doi, lsei, Di, iq = q_i
            s, dcap, msk = p_and_dcap(qi, kj, iq, jk)
            p = jnp.where(msk, jnp.exp(s - lsei[..., None]), 0.0)
            dvj = dvj + jnp.einsum("bkgqs,bkgqd->bksd", p, doi)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", doi, vj)
            ds = p * (dp - Di[..., None]) * dcap * scale
            dkj = dkj + jnp.einsum("bkgqs,bkgqd->bksd", ds, qi)
            return (dkj, dvj), None

        z = (jnp.zeros_like(kj), jnp.zeros_like(vj))
        (dkj, dvj), _ = lax.scan(inner, z,
                                 (qb, dob, lseb, Db, jnp.arange(nq)))
        return None, (dkj, dvj)

    _, (dkb, dvb) = lax.scan(dkv_step, None, (kb, vb, jnp.arange(nk)))
    dk = dkb.transpose(1, 0, 3, 2, 4).reshape(B, nk * bk, KV, D)[:, :Sk]
    dv = dvb.transpose(1, 0, 3, 2, 4).reshape(B, nk * bk, KV, Dv)[:, :Sk]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_jnp(q, k, v, causal=True, window=0, softcap=0.0,
                        q_offset=0, q_block=512, kv_block=1024):
    o, _ = _fwd_blocked(q, k, v, causal, window, softcap, q_offset,
                        min(q_block, q.shape[1]), min(kv_block, k.shape[1]))
    return o


def _vjp_fwd(q, k, v, causal, window, softcap, q_offset, q_block, kv_block):
    bq, bk = min(q_block, q.shape[1]), min(kv_block, k.shape[1])
    o, lse = _fwd_blocked(q, k, v, causal, window, softcap, q_offset, bq, bk)
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, window, softcap, q_offset, q_block, kv_block, res, do):
    bq = min(q_block, res[0].shape[1])
    bk = min(kv_block, res[1].shape[1])
    return _bwd_blocked(causal, window, softcap, q_offset, bq, bk, res, do)


flash_attention_jnp.defvjp(_vjp_fwd, _vjp_bwd)
