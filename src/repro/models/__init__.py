from repro.models.model import Model, build_model, cross_entropy, segment_plan

__all__ = ["Model", "build_model", "cross_entropy", "segment_plan"]
