"""Transformer / Mamba / MoE blocks with stacked-scan support.

Blocks are pre-norm residual units.  For every block kind we provide:
  init_block(key, cfg, kind, dtype)          -> param dict
  block_apply(params, cfg, kind, x, ...)     -> (x, aux_loss)
  block_cache_spec / block_decode             -> decode-path support

The model stacks `count` blocks of a kind by vmapping init and scanning
apply (see model.py); sliding-window patterns (gemma3 5:1) and the zamba2
shared block are handled by period-structured scans.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import (BLOCK_ATTN_DENSE, BLOCK_ATTN_MOE,
                                BLOCK_HYBRID_SHARED, BLOCK_MAMBA,
                                BLOCK_MLA_DENSE, BLOCK_MLA_MOE)
from repro.models import layers, mla, moe, ssm


def has_attn(kind: str) -> bool:
    return kind in (BLOCK_ATTN_DENSE, BLOCK_ATTN_MOE)


def has_mla(kind: str) -> bool:
    return kind in (BLOCK_MLA_DENSE, BLOCK_MLA_MOE)


def has_moe(kind: str) -> bool:
    return kind in (BLOCK_ATTN_MOE, BLOCK_MLA_MOE)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_block(key, cfg, kind: str, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {}
    if kind in (BLOCK_MAMBA, BLOCK_HYBRID_SHARED):
        p["norm"] = layers.init_norm(d, cfg.norm, dtype)
        p["mamba"] = ssm.init_mamba(ks[0], cfg, dtype)
        return p
    p["norm1"] = layers.init_norm(d, cfg.norm, dtype)
    p["norm2"] = layers.init_norm(d, cfg.norm, dtype)
    if has_mla(kind):
        p["attn"] = mla.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = layers.init_attention(ks[0], cfg, d, dtype)
    if has_moe(kind):
        p["moe"] = moe.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = layers.init_mlp(ks[1], d, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def init_shared_block(key, cfg, dtype) -> dict:
    """zamba2 weight-tied attention+MLP block."""
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "norm1": layers.init_norm(d, cfg.norm, dtype),
        "norm2": layers.init_norm(d, cfg.norm, dtype),
        "attn": layers.init_attention(ks[0], cfg, d, dtype),
        "mlp": layers.init_mlp(ks[1], d, cfg.d_ff, cfg.gated_mlp, dtype),
    }


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def block_apply(p: dict, cfg, kind: str, x, positions, *,
                layer_is_local: bool = False, kernel: str = "jnp"):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (BLOCK_MAMBA, BLOCK_HYBRID_SHARED):
        h = layers.norm_apply(p["norm"], x, cfg.norm)
        x = x + ssm.mamba_apply(p["mamba"], cfg, h, kernel=kernel)
        return x, aux
    h = layers.norm_apply(p["norm1"], x, cfg.norm)
    if has_mla(kind):
        x = x + mla.mla_apply(p["attn"], cfg, h, positions, kernel=kernel)
    else:
        x = x + layers.attention_apply(p["attn"], cfg, h,
                                       layer_is_local=layer_is_local,
                                       positions=positions, kernel=kernel)
    h = layers.norm_apply(p["norm2"], x, cfg.norm)
    if has_moe(kind):
        y, aux = moe.moe_apply(p["moe"], cfg, h)
        x = x + y
    else:
        x = x + layers.mlp_apply(p["mlp"], h, cfg.mlp_act, cfg.gated_mlp)
    return x, aux


def shared_block_apply(p: dict, cfg, x, positions, kernel: str = "jnp"):
    h = layers.norm_apply(p["norm1"], x, cfg.norm)
    x = x + layers.attention_apply(p["attn"], cfg, h, layer_is_local=False,
                                   positions=positions, kernel=kernel)
    h = layers.norm_apply(p["norm2"], x, cfg.norm)
    x = x + layers.mlp_apply(p["mlp"], h, cfg.mlp_act, cfg.gated_mlp)
    return x


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def block_cache(cfg, kind: str, batch: int, capacity: int, dtype,
                layer_is_local: bool = False) -> dict:
    """Zero-initialized per-layer decode cache."""
    if kind in (BLOCK_MAMBA, BLOCK_HYBRID_SHARED):
        return ssm.mamba_init_state(cfg, batch, dtype)
    if has_mla(kind):
        return mla.mla_init_cache(cfg, batch, capacity, dtype)
    a = cfg.attn
    cap = min(capacity, a.window) if (layer_is_local and a.window) else capacity
    return {
        "k": jnp.zeros((batch, cap, a.n_kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((batch, cap, a.n_kv_heads, a.head_dim), dtype),
    }


def block_decode(p: dict, cfg, kind: str, x, cache: dict, pos, *,
                 layer_is_local: bool = False):
    """One-token decode.  x: (B,1,d).  Returns (x, new_cache)."""
    if kind in (BLOCK_MAMBA, BLOCK_HYBRID_SHARED):
        h = layers.norm_apply(p["norm"], x, cfg.norm)
        y, new = ssm.mamba_decode(p["mamba"], cfg, h, cache)
        return x + y, new
    h = layers.norm_apply(p["norm1"], x, cfg.norm)
    if has_mla(kind):
        y, new = mla.mla_decode(p["attn"], cfg, h, cache, pos)
    else:
        y, nk, nv = layers.attention_decode(p["attn"], cfg, h, cache["k"],
                                            cache["v"], pos,
                                            layer_is_local=layer_is_local)
        new = {"k": nk, "v": nv}
    x = x + y
    h = layers.norm_apply(p["norm2"], x, cfg.norm)
    if has_moe(kind):
        y2, _ = moe.moe_apply(p["moe"], cfg, h)
        x = x + y2
    else:
        x = x + layers.mlp_apply(p["mlp"], h, cfg.mlp_act, cfg.gated_mlp)
    return x, new


def shared_block_decode(p: dict, cfg, x, cache: dict, pos):
    h = layers.norm_apply(p["norm1"], x, cfg.norm)
    y, nk, nv = layers.attention_decode(p["attn"], cfg, h, cache["k"],
                                        cache["v"], pos, layer_is_local=False)
    x = x + y
    h = layers.norm_apply(p["norm2"], x, cfg.norm)
    x = x + layers.mlp_apply(p["mlp"], h, cfg.mlp_act, cfg.gated_mlp)
    return x, {"k": nk, "v": nv}
