"""Mixture-of-experts FFN with TPU-idiomatic static-shape dispatch.

Tokens are routed top-k, sorted by expert id, and scattered into a fixed
(E, C, d) capacity buffer so expert matmuls are dense einsums with static
shapes (MXU-friendly; FLOPs ~= active FLOPs x capacity_factor).  Tokens
beyond an expert's capacity are dropped (standard GShard semantics); the
router aux loss keeps the load balanced.  Shared experts (DeepSeek) are
plain dense MLPs over all tokens.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers

# Perf variant "moe3d" (EXPERIMENTS.md §Perf): dispatch into a 3-D
# (E, C+1, d) buffer whose expert dim is shardable over the model axis,
# instead of the flat (E*C+1, d) buffer (whose fused dim GSPMD cannot
# shard, forcing a replicated ~T*K*d materialization per device).
DISPATCH_3D = False

# Perf variant "moesm" (EXPERIMENTS.md §Perf): shard_map expert
# parallelism.  Under the (data..., model) mesh the activations are
# data-sharded and model-REPLICATED, so every model shard already holds
# all of its data shard's tokens: routing, sort, dispatch and combine can
# all be shard-LOCAL, each shard computes only its E/|model| experts, and
# the single collective left is a (T_local, d) psum of the combined
# output over the model axis — same traffic class as dense TP, instead
# of the (T*K, d) gather/scatter storms GSPMD emits for the global
# dispatch.  Set to (mesh, data_axes) by launch.dryrun.
SHARD_MAP = None


def capacity(n_tokens: int, n_experts: int, top_k: int,
             capacity_factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k * capacity_factor / n_experts))
    return max(8, -(-c // 8) * 8)                 # round up to multiple of 8


def init_moe(key, cfg, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": layers.init_dense(ks[0], d, m.n_experts, jnp.float32),
        "w_in": (jax.random.normal(ks[1], (m.n_experts, d, m.d_ff_expert),
                                   jnp.float32) * s).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (m.n_experts, d, m.d_ff_expert),
                                     jnp.float32) * s).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (m.n_experts, m.d_ff_expert, d),
                                    jnp.float32)
                  / math.sqrt(m.d_ff_expert)).astype(dtype),
    }
    if m.n_shared_experts:
        p["shared"] = layers.init_mlp(
            jax.random.fold_in(key, 7), d,
            m.n_shared_experts * m.d_ff_expert, True, dtype)
    return p


def moe_apply(p: dict, cfg, x: jnp.ndarray):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar f32)."""
    if SHARD_MAP is not None:
        return moe_apply_shardmap(p, cfg, x)
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, K = m.n_experts, m.top_k

    gate_logits = xt.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                      # (T, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0)
    aux = jnp.sum(me * ce) * E * m.router_aux_weight

    # ---- sort-based dispatch into (E, C, d) ----
    C = capacity(T, E, K, m.capacity_factor)
    flat_e = top_e.reshape(T * K)                               # expert ids
    tok_of = jnp.repeat(jnp.arange(T), K)                       # token ids
    w_of = top_p.reshape(T * K)
    order = jnp.argsort(flat_e)                                 # stable
    se, st, sw = flat_e[order], tok_of[order], w_of[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E))             # (E,)
    pos = jnp.arange(T * K) - seg_start[se]                     # rank in expert
    keep = pos < C
    if DISPATCH_3D:
        # (E, C+1, d) scatter: column C is the trash slot for dropped
        # tokens; the E dim stays shardable over the model axis.
        posc = jnp.where(keep, pos, C)
        buf = jnp.zeros((E, C + 1, d), x.dtype).at[se, posc].set(xt[st])
        buf = buf[:, :C]
    else:
        slot = jnp.where(keep, se * C + pos, E * C)             # E*C = trash
        buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xt[st])
        buf = buf[:E * C].reshape(E, C, d)

    # ---- expert computation: dense per-expert matmuls ----
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = jax.nn.silu(h) * g if cfg.mlp_act == "silu" \
        else jax.nn.gelu(h, approximate=True) * g
    yb = jnp.einsum("ecf,efd->ecd", h, p["w_out"])              # (E, C, d)

    # ---- combine back ----
    if DISPATCH_3D:
        posc = jnp.where(keep, pos, C)
        ybp = jnp.pad(yb, ((0, 0), (0, 1), (0, 0)))
        y_sorted = ybp[se, posc] * sw[:, None].astype(x.dtype)
    else:
        yb = jnp.concatenate([yb.reshape(E * C, d),
                              jnp.zeros((1, d), x.dtype)], axis=0)
        y_sorted = yb[jnp.where(keep, slot, E * C)] \
            * sw[:, None].astype(x.dtype)
    contrib = jnp.zeros((T, d), x.dtype).at[st].add(
        jnp.where(keep[:, None], y_sorted, 0))
    y = contrib

    if m.n_shared_experts:
        y = y + layers.mlp_apply(p["shared"], xt, cfg.mlp_act, True)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism (perf variant "moesm")
# ---------------------------------------------------------------------------


def _moe_local(cfg, xt, router_w, w_in, w_gate, w_out, model_axis: str,
               data_axes, n_shards: int, shard_idx):
    """Per-device body: xt (T_l, d) local tokens (model-replicated);
    w_* hold the E_l = E/n_shards experts of this model shard.
    Returns (partial y (T_l, d) — psum'd over model by caller — and the
    local aux-loss sums)."""
    m = cfg.moe
    T, d = xt.shape
    E, K = m.n_experts, m.top_k
    E_l = E // n_shards

    gate_logits = xt.astype(jnp.float32) @ router_w             # (T_l, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # aux-loss sufficient statistics (summed; caller normalizes globally)
    me_sum = jnp.sum(probs, axis=0)                             # (E,)
    ce_sum = jnp.sum(
        jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))

    # keep only assignments routed to THIS shard's experts
    lo = shard_idx * E_l
    flat_e = top_e.reshape(T * K)
    flat_p = top_p.reshape(T * K)
    tok_of = jnp.repeat(jnp.arange(T), K)
    mine = (flat_e >= lo) & (flat_e < lo + E_l)
    local_e = jnp.where(mine, flat_e - lo, E_l)                 # E_l = trash
    C = capacity(T, E, K, m.capacity_factor)
    order = jnp.argsort(local_e)
    se, st, sw = local_e[order], tok_of[order], flat_p[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E_l + 1))
    pos = jnp.arange(T * K) - seg_start[jnp.minimum(se, E_l)]
    keep = (pos < C) & (se < E_l)
    posc = jnp.where(keep, pos, C)
    sec = jnp.minimum(se, E_l - 1)
    buf = jnp.zeros((E_l, C + 1, d), xt.dtype) \
        .at[jnp.where(keep, sec, 0), jnp.where(keep, posc, C)] \
        .set(jnp.where(keep[:, None], xt[st], 0))
    buf = buf[:, :C]

    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    h = jax.nn.silu(h) * g if cfg.mlp_act == "silu" \
        else jax.nn.gelu(h, approximate=True) * g
    yb = jnp.einsum("ecf,efd->ecd", h, w_out)                   # (E_l, C, d)

    ybp = jnp.pad(yb, ((0, 0), (0, 1), (0, 0)))
    y_sorted = ybp[sec, posc] * sw[:, None].astype(xt.dtype)
    y = jnp.zeros((T, d), xt.dtype).at[st].add(
        jnp.where(keep[:, None], y_sorted, 0))
    return y, me_sum, ce_sum


def moe_apply_shardmap(p: dict, cfg, x: jnp.ndarray):
    """Expert-parallel MoE via shard_map (see SHARD_MAP above)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh, data_axes = SHARD_MAP
    m = cfg.moe
    model_axis = "model"
    n_shards = mesh.shape[model_axis]
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    B, S, d = x.shape
    E = m.n_experts
    assert E % n_shards == 0, (E, n_shards)

    def body(x, router_w, w_in, w_gate, w_out):
        xt = x.reshape(-1, x.shape[-1])
        shard_idx = jax.lax.axis_index(model_axis)
        y, me_sum, ce_sum = _moe_local(cfg, xt, router_w, w_in, w_gate,
                                       w_out, model_axis, data_axes,
                                       n_shards, shard_idx)
        y = jax.lax.psum(y, model_axis)                  # combine experts
        me_sum = jax.lax.psum(me_sum, da)                # global aux stats
        ce_sum = jax.lax.psum(ce_sum, da)
        return y.reshape(x.shape), me_sum, ce_sum

    y, me_sum, ce_sum = shard_map(
        body, mesh=mesh,
        in_specs=(P(da, None, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(P(da, None, None), P(None), P(None)),
        check_rep=False,
    )(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])

    T_global = B * S
    me = me_sum / T_global
    ce = ce_sum / T_global
    aux = jnp.sum(me * ce) * E * m.router_aux_weight
    if m.n_shared_experts:
        y = y + layers.mlp_apply(p["shared"], x.reshape(-1, d),
                                 cfg.mlp_act, True).reshape(x.shape)
    return y, aux
