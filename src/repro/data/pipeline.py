"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step, arch config, shape), so any
worker can regenerate any micro-batch — exactly the property Unicron's
micro-batch redistribution (§6.2) relies on: when a DP rank dies, its
micro-batches are re-assigned and *recomputed identically* elsewhere.

Token streams are Zipf-distributed with a Markov flavor so the loss has
learnable structure (quickstart/examples show a decreasing loss curve).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _zipf_logits(vocab: int) -> jnp.ndarray:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -1.1 * jnp.log(ranks)


@dataclass(frozen=True)
class SyntheticLM:
    """Deterministic synthetic language-modeling data source."""

    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def _key(self, step: int, index: int) -> jax.Array:
        k = jax.random.PRNGKey(self.seed)
        k = jax.random.fold_in(k, step)
        return jax.random.fold_in(k, index)

    def tokens(self, step: int, index: int, n: int) -> jnp.ndarray:
        """n sequences for (step, slice index) — any worker, same result."""
        key = self._key(step, index)
        logits = _zipf_logits(min(self.cfg.vocab, 4096))
        toks = jax.random.categorical(
            key, jnp.broadcast_to(logits, (n, self.seq_len, logits.shape[0])))
        # Markov flavor: every even position repeats a shifted copy so the
        # model has something to learn.
        shifted = jnp.roll(toks, 1, axis=1)
        pos = jnp.arange(self.seq_len) % 2 == 0
        return jnp.where(pos[None, :], toks, (shifted + 1) % self.cfg.vocab) \
            .astype(jnp.int32)

    def batch(self, step: int, start: int = 0,
              n: Optional[int] = None) -> Dict[str, jnp.ndarray]:
        """Slice [start, start+n) of the global batch at ``step``.

        Deterministic per-sequence: sequence i is generated from
        (seed, step, i) regardless of which worker asks for it.
        """
        n = self.global_batch if n is None else n
        cfg = self.cfg
        seqs = []
        for i in range(start, start + n):
            seqs.append(self.tokens(step, i, 1))
        toks = jnp.concatenate(seqs, axis=0)
        if cfg.modality == "audio_stub":
            key = self._key(step, start + 1_000_003)
            frames = jax.random.normal(
                key, (n, self.seq_len, cfg.d_model), jnp.float32)
            mask = (jax.random.uniform(
                jax.random.fold_in(key, 1), (n, self.seq_len)) < 0.35)
            return {"frames": frames, "labels": toks % cfg.vocab,
                    "loss_mask": mask.astype(jnp.float32)}
        out = {"tokens": toks}
        if cfg.modality == "vision_stub":
            key = self._key(step, start + 2_000_003)
            out["prefix_embeds"] = jax.random.normal(
                key, (n, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
        return out


def microbatches(batch: Dict[str, jnp.ndarray], n_micro: int):
    """Split a batch dict into ``n_micro`` equal micro-batches (list)."""
    b = next(iter(batch.values())).shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    return [jax.tree.map(lambda a: a[i * mb:(i + 1) * mb], batch)
            for i in range(n_micro)]


def stack_microbatches(batch: Dict[str, jnp.ndarray], n_micro: int):
    """Reshape a batch for ``lax.scan`` over micro-batches: (n, mb, ...)."""
    b = next(iter(batch.values())).shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    return jax.tree.map(
        lambda a: a.reshape((n_micro, mb) + a.shape[1:]), batch)
