from repro.data.pipeline import SyntheticLM, microbatches, stack_microbatches

__all__ = ["SyntheticLM", "microbatches", "stack_microbatches"]
