"""Launch layer: production meshes, dry-run driver, training launcher."""
