"""ShapeDtypeStruct stand-ins for every model input (dry-run path).

``input_specs(cfg, shape)`` returns the abstract batch for a training /
prefill step; ``decode_specs`` the (caches, tokens, pos) for a serve step.
Nothing here allocates device memory.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def n_micro_for(shape: ShapeConfig, dp: int) -> int:
    """Micro-batch count: keep the per-DP-rank micro batch >= 1 while
    bounding per-step activation memory.  train_4k (B=256) -> 8 micro
    batches of 32 sequences."""
    if shape.kind != "train":
        return 1
    for n in (8, 4, 2, 1):
        mb = shape.global_batch // n
        if mb % dp == 0 and mb >= dp:
            return n
    return 1


def batch_struct(cfg: ArchConfig, batch: int, seq: int,
                 stacked_micro: int = 0) -> Dict[str, Any]:
    """Abstract batch dict for ``loss``/``forward``.

    ``stacked_micro`` > 0 prepends the scan dim: (n_micro, batch, ...).
    """
    def s(*dims, dtype=jnp.int32):
        lead = (stacked_micro,) if stacked_micro else ()
        return SDS(lead + dims, dtype)

    if cfg.modality == "audio_stub":
        return {
            "frames": s(batch, seq, cfg.d_model, dtype=jnp.float32),
            "labels": s(batch, seq),
            "loss_mask": s(batch, seq, dtype=jnp.float32),
        }
    out = {"tokens": s(batch, seq)}
    if cfg.modality == "vision_stub":
        out["prefix_embeds"] = s(batch, cfg.n_prefix_embeds, cfg.d_model,
                                 dtype=jnp.float32)
    return out


def input_specs(cfg: ArchConfig, shape: ShapeConfig, dp: int) -> Dict:
    """Abstract inputs for the train (stacked micro-batches) or prefill
    step of (cfg, shape)."""
    if shape.kind == "train":
        n = n_micro_for(shape, dp)
        return batch_struct(cfg, shape.global_batch // n, shape.seq_len,
                            stacked_micro=n)
    return batch_struct(cfg, shape.global_batch, shape.seq_len)


def decode_specs(model, cfg: ArchConfig, shape: ShapeConfig
                 ) -> Tuple[Any, Any, Any]:
    """(caches, tokens, pos) ShapeDtypeStructs for one serve_step."""
    caches = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 jnp.dtype(cfg.param_dtype)))
    tokens = SDS((shape.global_batch,), jnp.int32)
    pos = SDS((), jnp.int32)
    return caches, tokens, pos
