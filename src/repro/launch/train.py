"""End-to-end training launcher (real execution, laptop/CI scale).

Runs the full Unicron-managed loop on the local devices: deterministic
data pipeline -> micro-batch gradient accumulation -> AdamW, with the
Unicron agent's online statistical monitor watching iteration times, the
hierarchical checkpoint manager (in-memory + persistent tiers) saving
state, and optional mid-run failure injection exercising the §6.2
micro-batch redistribution path.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 50 --seq 128 --batch 8 --n-micro 4 --inject-fail 10
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.core.agent import UnicronAgent
from repro.core.detection import ErrorKind
from repro.core.kvstore import KVStore
from repro.core.resumption import run_iteration_with_failure
from repro.data.pipeline import SyntheticLM, stack_microbatches
from repro.models.model import build_model
from repro.optim import AdamW, cosine_with_warmup
from repro.train.state import init_train_state
from repro.train.step import finalize_step, make_grad_fn, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the 2-layer smoke variant (CPU friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-micro", type=int, default=4)
    ap.add_argument("--dp", type=int, default=4,
                    help="simulated DP ranks for the resumable path")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/unicron_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--inject-fail", type=int, default=0,
                    help="inject a DP-rank failure at this step (0 = never)")
    ap.add_argument("--kernel", default="jnp",
                    choices=["jnp", "pallas", "flash"])
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} layers={cfg.n_layers} d_model={cfg.d_model} "
          f"params={cfg.param_count() / 1e6:.1f}M")

    model = build_model(cfg)
    opt = AdamW(lr=cosine_with_warmup(args.lr, 10, args.steps))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, seq_len=args.seq, global_batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, n_ranks=args.dp,
                            persist_every=args.ckpt_every,
                            task=f"train-{cfg.name}")
    kv = KVStore()
    agent = UnicronAgent(node_id=0, kv=kv)

    fused = jax.jit(make_train_step(model, opt, args.n_micro,
                                    kernel=args.kernel))
    grad_fn = make_grad_fn(model, kernel=args.kernel)
    mb_size = args.batch // args.n_micro

    for step in range(args.steps):
        t0 = time.time()
        batch = data.batch(step)
        if args.inject_fail and step == args.inject_fail:
            # Unicron path: fail one DP rank mid-iteration; survivors
            # absorb its micro-batches (Eq. 7) and the step completes
            # with exact semantics.
            def microbatch_of(mb, step=step):
                return data.batch(step, start=mb * mb_size, n=mb_size)
            print(f"step {step}: INJECTING rank-1 failure mid-iteration")
            agent.report(ErrorKind.EXITED_ABNORMALLY, now=float(step))
            grad_sum, count = run_iteration_with_failure(
                grad_fn, state.params, microbatch_of,
                n_ranks=args.dp, n_micro=args.n_micro,
                fail_rank=1, fail_after_mb=0)
            state, gnorm = finalize_step(opt, state, grad_sum, count)
            metrics = {"loss": float("nan"), "grad_norm": gnorm}
            dt = time.time() - t0
            print(f"step {step:4d} recovered-iteration "
                  f"grad_norm={float(gnorm):.3f} ({dt:.2f}s)")
        else:
            state, metrics = fused(state, stack_microbatches(batch,
                                                             args.n_micro))
            dt = time.time() - t0
            agent.observe_iteration(dt)
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f} ({dt:.2f}s)")
        if step % args.ckpt_every == 0:
            mgr.save(rank=0, step=step, state=state)
    print("done;", f"final step={int(state.step)}")


if __name__ == "__main__":
    main()
