"""Collective-traffic statistics from compiled HLO text.

``collective_bytes`` parses the SPMD-partitioned module (per-device view,
``compiled.as_text()``) and sums the result-shape bytes of every
communication op.  ``cost_analysis`` does not report collective traffic,
so this parser is the source for the roofline's collective term.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")

# result type(s) then op name:  "%x = (bf16[8,128]{1,0}, ...) all-gather-start("
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVES) + r")(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind {count, bytes} from one partitioned HLO module.

    ``-done`` ops are skipped (the ``-start`` carries the shape); for
    async pairs the start op's result tuple includes both operand and
    result buffers, so we halve those to avoid double counting.
    """
    stats: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind, is_start = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(type_str)
        if is_start and type_str.startswith("("):
            b = b / 2              # async start tuple: (operand, result, ...)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += b
    return stats


def collective_bytes(hlo_text: str) -> Tuple[float, Dict]:
    stats = collective_stats(hlo_text)
    return sum(v["bytes"] for v in stats.values()), stats
