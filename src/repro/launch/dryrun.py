import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles the production step for one (architecture x input shape
x mesh) combination with ShapeDtypeStruct inputs — no device allocation —
and reports memory analysis, cost analysis (FLOPs / bytes) and the
collective traffic parsed from the partitioned HLO.  This is the proof
that the distribution config is coherent, and the data source for the
roofline analysis (EXPERIMENTS.md §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod] [--json out.json]

The two XLA_FLAGS lines above MUST stay first: jax locks the device count
on first initialization.
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch, supports_shape
from repro.configs.base import ArchConfig
from repro.launch import hlo_analysis
from repro.launch.inputs import decode_specs, input_specs, n_micro_for
from repro.launch.mesh import (DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models.model import build_model
from repro.optim import AdamW, constant
from repro.serve.decode import make_serve_step
from repro.sharding import (batch_specs, cache_specs, data_axes_of,
                            param_specs, to_named, train_state_specs)
from repro.train.state import abstract_train_state
from repro.train.step import make_train_step


def apply_variant(cfg: ArchConfig, variant: str):
    """Perf-iteration variants (EXPERIMENTS.md §Perf).

    baseline          paper-faithful lowering (jnp blocked attention)
    flash             flash-custom-VJP attention (no O(S^2) scan saves)
    fusednorm         analytic custom-VJP RMSNorm (one fused backward)
    seqpar            sequence-parallel TP: residual stream sequence dim
                      sharded over the model axis between blocks
    moe3d             3-D (E, C, d) MoE dispatch buffer (expert dim
                      shardable; kills the replicated (T*K, d) gather)
    moesm             shard_map expert parallelism: shard-local dispatch
                      + one (T_local, d) psum over the model axis
    fsdp              ZeRO-3: parameters also sharded over the data axes
    cachemodel        decode KV caches additionally sharded over the
                      model axis on the capacity dim (residency fix)
    ep48              granite-moe: pad 40 -> 48 experts so the expert dim
                      divides the model axis (expert parallelism instead
                      of intra-expert TP); capacity scaled to keep FLOPs
    Tokens compose with '+': e.g. 'flash+ep48'.
    """
    import dataclasses
    kernel = "jnp"
    for tok in variant.split("+"):
        if tok == "flash":
            kernel = "flash"
        elif tok == "fusednorm":
            from repro.models import layers
            layers.RMSNORM_FUSED = True
        elif tok == "seqpar":
            pass                      # applied in lower_pair (needs mesh)
        elif tok == "moe3d":
            from repro.models import moe
            moe.DISPATCH_3D = True
        elif tok == "moesm":
            pass                      # applied in lower_pair (needs mesh)
        elif tok == "fsdp":
            pass                      # applied in lower_pair (train only)
        elif tok == "cachemodel":
            pass                      # applied in lower_pair (decode only)
        elif tok == "ep48" and cfg.moe is not None:
            m = cfg.moe
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                m, n_experts=48,
                capacity_factor=m.capacity_factor * m.n_experts / 48))
        elif tok not in ("baseline", ""):
            raise ValueError(f"unknown variant token {tok!r}")
    return cfg, kernel


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               variant: str = "baseline"):
    """Returns (lowered, meta) for one (arch, shape, mesh)."""
    cfg = get_arch(arch)
    cfg, kernel = apply_variant(cfg, variant)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes, dp = data_axes_of(mesh)
    if "seqpar" in variant.split("+"):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import model as model_mod
        da = data_axes if len(data_axes) > 1 else data_axes[0]
        model_mod.SEQ_SHARDING = NamedSharding(mesh, P(da, "model", None))
    if "moesm" in variant.split("+"):
        from repro.models import moe as moe_mod
        moe_mod.SHARD_MAP = (mesh, data_axes)
    model_size = mesh.shape["model"]
    model = build_model(cfg)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind, "dp": dp, "tp": model_size,
            "variant": variant}

    if shape.kind == "train":
        opt = AdamW(lr=constant(3e-4))
        state_sds = abstract_train_state(model, opt)
        state_specs = train_state_specs(
            state_sds, mesh, fsdp="fsdp" in variant.split("+"))
        n = n_micro_for(shape, dp)
        batch_sds = input_specs(cfg, shape, dp)
        bspecs = batch_specs(batch_sds, data_axes, dp, stacked=True)
        step = make_train_step(model, opt, n, kernel=kernel, remat=True)
        meta["n_micro"] = n
        jitted = jax.jit(step, in_shardings=(
            to_named(mesh, state_specs), to_named(mesh, bspecs)))
        lowered = jitted.lower(state_sds, batch_sds)
    elif shape.kind == "prefill":
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = param_specs(params_sds, model_size)
        batch_sds = input_specs(cfg, shape, dp)
        bspecs = batch_specs(batch_sds, data_axes, dp, stacked=False)

        def prefill_step(params, batch):
            logits, _ = model.forward(params, batch, kernel=kernel,
                                      remat=True, last_logits_only=True)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

        jitted = jax.jit(prefill_step, in_shardings=(
            to_named(mesh, pspecs), to_named(mesh, bspecs)))
        lowered = jitted.lower(params_sds, batch_sds)
    else:  # decode
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = param_specs(params_sds, model_size)
        caches_sds, tok_sds, pos_sds = decode_specs(model, cfg, shape)
        shard_seq = shape.name == "long_500k"
        cspecs = cache_specs(caches_sds, data_axes, dp, model_size,
                             shard_seq=shard_seq,
                             kv_model="cachemodel" in variant.split("+"))
        da = data_axes if len(data_axes) > 1 else data_axes[0]
        tok_spec = (jax.sharding.PartitionSpec(da)
                    if shape.global_batch % dp == 0 and shape.global_batch > 1
                    else jax.sharding.PartitionSpec())
        serve = make_serve_step(model)
        jitted = jax.jit(serve, in_shardings=(
            to_named(mesh, pspecs), to_named(mesh, cspecs),
            jax.sharding.NamedSharding(mesh, tok_spec),
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())))
        lowered = jitted.lower(params_sds, caches_sds, tok_sds, pos_sds)
    return lowered, meta


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                  # noqa: BLE001
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "serialized_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int, multi_pod: bool) -> dict:
    """Three roofline terms in seconds (per spec: totals over the chips'
    aggregate capability; cost_analysis numbers are per-device module,
    i.e. already divided by the chip count)."""
    link_bw = DCN_BW if multi_pod else ICI_BW
    return {
        "compute_s": flops / (PEAK_FLOPS_BF16),
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll_bytes / link_bw,
    }


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             variant: str = "baseline", verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skip", "reason": reason}
    t0 = time.time()
    lowered, meta = lower_pair(arch, shape_name, multi_pod=multi_pod,
                               variant=variant)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # raw XLA cost analysis (visits while bodies once — kept for reference)
    cost = compiled.cost_analysis() or {}
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    # loop-aware static analysis (the roofline source)
    text = compiled.as_text()
    acc = hlo_analysis.analyze(text)
    flops, hbm, coll = acc.flops, acc.bytes, acc.coll_bytes
    n_chips = 512 if multi_pod else 256
    mem = _mem_dict(compiled)

    result = dict(meta)
    result.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops": flops,
        "hlo_bytes": hbm,
        "collective_bytes": coll,
        "collectives": {k: v for k, v in acc.coll.items() if v["count"]},
        "bytes_by_op": dict(sorted(acc.bytes_by_op.items(),
                                   key=lambda kv: -kv[1])),
        "xla_cost_analysis": {"flops": raw_flops,
                              "bytes_accessed": raw_bytes},
        "memory": mem,
        "roofline": roofline_terms(flops, hbm, coll, n_chips, multi_pod),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    })
    # MODEL_FLOPS = 6*N_active*D for one step's tokens
    n = shape.global_batch * shape.seq_len if shape.kind != "decode" \
        else shape.global_batch
    mf = 6.0 * cfg.active_param_count() * n
    if shape.kind != "train":
        mf /= 3.0                  # inference fwd-only: 2*N*D
    result["model_flops"] = mf
    total_hlo = flops * n_chips
    result["model_flops_ratio"] = (mf / total_hlo) if total_hlo else 0.0
    if verbose:
        print(json.dumps(result, indent=2), flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--json", default=None, help="append result to file")
    args = ap.parse_args()

    res = run_pair(args.arch, args.shape, multi_pod=args.multi_pod,
                   variant=args.variant)
    if args.json:
        with open(args.json, "a") as f:
            f.write(json.dumps(res) + "\n")
    sys.exit(0 if res.get("status") in ("ok", "skip") else 1)


if __name__ == "__main__":
    main()
