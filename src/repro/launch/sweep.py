"""Dry-run sweep driver: every (architecture x input shape x mesh).

Spawns one subprocess per pair (``repro.launch.dryrun``) so each compile
gets a fresh XLA context, appending JSONL results to ``--out``.  Pairs are
ordered small-to-large so coverage lands early; already-present results
are skipped (resumable).

    PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun.jsonl
    PYTHONPATH=src python -m repro.launch.sweep --out ... --multi-pod
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCH_ORDER = [  # roughly by model size (compile cost)
    "gemma-2b", "granite-moe-3b-a800m", "mamba2-780m", "zamba2-1.2b",
    "internvl2-2b", "qwen3-4b", "hubert-xlarge", "granite-3-8b",
    "gemma3-12b", "deepseek-v3-671b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_done(path: str) -> set:
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                done.add((r.get("arch"), r.get("shape"), r.get("mesh"),
                          r.get("variant", "baseline")))
    return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--archs", nargs="*", default=ARCH_ORDER)
    ap.add_argument("--shapes", nargs="*", default=SHAPE_ORDER)
    args = ap.parse_args()

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = load_done(args.out)
    todo = [(a, s) for s in args.shapes for a in args.archs
            if (a, s, mesh_name, args.variant) not in done]
    print(f"sweep: {len(todo)} pairs to run on {mesh_name}", flush=True)
    failures = 0
    for i, (arch, shape) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--json", args.out,
               "--variant", args.variant]
        if args.multi_pod:
            cmd.append("--multi-pod")
        t0 = time.time()
        print(f"[{i + 1}/{len(todo)}] {arch} x {shape} x {mesh_name} ...",
              flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
        except subprocess.TimeoutExpired:
            print(f"    TIMEOUT after {args.timeout}s", flush=True)
            with open(args.out, "a") as f:
                f.write(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mesh_name,
                    "variant": args.variant, "status": "timeout"}) + "\n")
            failures += 1
            continue
        dt = time.time() - t0
        if r.returncode != 0:
            tail = (r.stderr or r.stdout or "")[-2000:]
            print(f"    FAIL ({dt:.0f}s): {tail}", flush=True)
            with open(args.out, "a") as f:
                f.write(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mesh_name,
                    "variant": args.variant, "status": "error",
                    "error": tail[-500:]}) + "\n")
            failures += 1
        else:
            print(f"    ok ({dt:.0f}s)", flush=True)
    print(f"sweep done, {failures} failures", flush=True)


if __name__ == "__main__":
    main()
