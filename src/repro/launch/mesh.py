"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — the dry-run driver
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax initialization and only then builds the mesh.

Target hardware: TPU v5e.  One pod = a 16x16 ICI torus (256 chips); the
multi-pod mesh adds a leading DCN "pod" axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
HBM_BYTES = 16e9                  # capacity
ICI_BW = 50e9                     # bytes/s per link
DCN_BW = 6.25e9                   # bytes/s per chip, cross-pod


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Laptop-scale mesh over the real local devices (tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
