"""Static analysis of compiled (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` visits ``while`` bodies ONCE — a
``lax.scan`` over 61 layers or 8 micro-batches under-counts FLOPs/bytes by
the trip count.  This analyzer re-derives the three roofline inputs from
the HLO text with correct loop multiplicity:

  * flops            — dot ops: 2 x |result| x |contracting dims| (plus
                       1 flop/element for elementwise ops); while bodies
                       multiplied by their trip count.
  * hbm_bytes        — operands + results of HBM-materializing top-level
                       ops (fusion internals excluded — they live in
                       registers/VMEM), loop-multiplied.
  * collective_bytes — result bytes of communication ops, loop-multiplied.

Trip counts are recovered from each while condition's ROOT
``compare(induction_var, constant), direction=LT`` — the shape every
``lax.scan`` lowers to.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")

# ops that never touch HBM themselves
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}

_COMP_HEADER = re.compile(
    r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:{[^}]*})?)\s+"
    r"([\w\-]+)"
    r"\((.*?)\)\s*(,.*)?$")
_PARAM = re.compile(r"%?([\w.\-]+)\s*:\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\])")
_CONSTANT_VAL = re.compile(r"constant\((\d+)\)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims={([0-9,]*)}")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    """Element count of the FIRST array shape in the type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)
    root: Optional[Instr] = None


def _split_operands(s: str) -> List[str]:
    """Top-level comma split of an operand list; returns bare names."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    names = []
    for tok in out:
        tok = tok.strip()
        if tok.startswith("%"):
            tok = tok[1:]
        # strip any inline type annotation: "f32[2] %name"
        parts = tok.split()
        if parts:
            last = parts[-1]
            names.append(last[1:] if last.startswith("%") else last)
    return names


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = cur.name
                for pname, ptype in _PARAM.findall(m.group(3)):
                    cur.types[pname] = ptype
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        ins = Instr(name=m.group(1), type_str=m.group(2),
                    opcode=m.group(3), operands=_split_operands(m.group(4)),
                    attrs=m.group(5) or "")
        # constants keep their literal for trip-count recovery
        if ins.opcode == "constant":
            ins.attrs = line
        cur.instrs.append(ins)
        cur.types[ins.name] = ins.type_str
        if line.lstrip().startswith("ROOT"):
            cur.root = ins
    return comps, entry


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.coll_bytes += mult * other.coll_bytes
        for k, v in other.coll.items():
            slot = self.coll.setdefault(k, {"count": 0.0, "bytes": 0.0})
            slot["count"] += mult * v["count"]
            slot["bytes"] += mult * v["bytes"]
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + mult * v

    def _note_bytes(self, op: str, b: float) -> None:
        self.bytes += b
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + b


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    # ---- trip counts -----------------------------------------------------

    def trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None or comp.root is None:
            return 1
        # ROOT compare(%gte, %constant), direction=LT
        for opnd in comp.root.operands:
            for ins in comp.instrs:
                if ins.name == opnd and ins.opcode == "constant":
                    m = _CONSTANT_VAL.search(ins.attrs)
                    if m:
                        return max(1, int(m.group(1)))
        # fallback: any integer constant in the condition
        for ins in comp.instrs:
            if ins.opcode == "constant":
                m = _CONSTANT_VAL.search(ins.attrs)
                if m:
                    return max(1, int(m.group(1)))
        return 1

    # ---- per-instruction flops ------------------------------------------

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems = shape_elems(ins.type_str)
        cdims = _LHS_CDIMS.search(ins.attrs)
        contract = 1
        if cdims and ins.operands:
            lhs_type = comp.types.get(ins.operands[0], "")
            dims = shape_dims(lhs_type)
            for d in cdims.group(1).split(","):
                if d and int(d) < len(dims):
                    contract *= dims[int(d)]
        return 2.0 * out_elems * contract

    # ---- recursive cost ----------------------------------------------------

    def cost_of(self, comp_name: str, in_fusion: bool = False) -> Cost:
        key = (comp_name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()          # break cycles defensively
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            return total
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                body = _BODY.search(ins.attrs)
                cond = _COND.search(ins.attrs)
                trips = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    total.add(self.cost_of(body.group(1)), trips)
                if cond:
                    total.add(self.cost_of(cond.group(1)), trips)
                continue
            if op == "fusion":
                called = _CALLS.search(ins.attrs)
                if called:
                    total.add(self.cost_of(called.group(1), in_fusion=True))
                total._note_bytes("fusion", self._io_bytes(comp, ins))
                continue
            if op in ("call", "async-start", "custom-call"):
                called = _CALLS.search(ins.attrs)
                if called:
                    total.add(self.cost_of(called.group(1)))
                if not in_fusion and op != "call":
                    total._note_bytes(op, self._io_bytes(comp, ins))
                continue
            if op == "conditional":
                # take the max across branch computations
                branches = re.findall(r"%([\w.\-]+)", ins.attrs)
                best = Cost()
                for b in branches:
                    if b in self.comps:
                        c = self.cost_of(b)
                        if c.flops > best.flops:
                            best = c
                total.add(best)
                continue
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                b = shape_bytes(ins.type_str)
                if op.endswith("-start") and ins.type_str.startswith("("):
                    b /= 2
                slot = total.coll.setdefault(
                    base, {"count": 0.0, "bytes": 0.0})
                slot["count"] += 1
                slot["bytes"] += b
                total.coll_bytes += b
                if not in_fusion:
                    total._note_bytes("collective", self._io_bytes(comp, ins))
                continue
            if op == "dot":
                total.flops += self._dot_flops(comp, ins)
                if not in_fusion:
                    total._note_bytes("dot", self._io_bytes(comp, ins))
                continue
            if op in _FREE_OPS or op.endswith("-done"):
                continue
            # generic elementwise / data-movement op
            total.flops += shape_elems(ins.type_str)
            if not in_fusion:
                cat = op if op in ("copy", "convert", "transpose", "reshape",
                                   "dynamic-slice", "dynamic-update-slice",
                                   "broadcast", "reduce", "scatter",
                                   "gather", "sort", "pad", "slice",
                                   "concatenate", "select") else "other"
                total._note_bytes(cat, self._io_bytes(comp, ins))
        self._memo[key] = total
        return total

    def _io_bytes(self, comp: Computation, ins: Instr) -> float:
        b = float(shape_bytes(ins.type_str))
        for o in ins.operands:
            t = comp.types.get(o)
            if t:
                b += shape_bytes(t)
        return b

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def analyze(text: str) -> Cost:
    return HloAnalyzer(text).entry_cost()
