"""Pallas TPU flash attention (forward) with GQA, causal/sliding-window
masking and logit soft-capping.

TPU mapping: the grid is (batch, head, q_blocks, kv_blocks) with the
kv-block dimension LAST — the last grid dimension iterates sequentially
on-core, so the online-softmax running state (acc, m, l) lives in VMEM
scratch across kv iterations.  BlockSpecs tile Q/K/V into
(block_q, head_dim) / (block_k, head_dim) VMEM tiles; block sizes default
to 128 to match the MXU's 128x128 systolic tile.  GQA is expressed in the
K/V index_map (query head h reads kv head h*KV//H), so KV tiles are never
replicated in HBM.  Fully-masked kv blocks (causal skew / out of sliding
window) are skipped with pl.when, which is where the causal 2x FLOP
saving comes from.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_config import resolve_interpret

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 block_q: int, block_k: int, seq_k: int,
                 causal: bool, window: int, softcap: float, q_offset: int,
                 scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip: block is dead if fully above the causal diagonal
    # or fully left of the sliding window
    blk_q_lo = q_offset + iq * block_q
    blk_q_hi = blk_q_lo + block_q - 1
    blk_k_lo = ik * block_k
    blk_k_hi = blk_k_lo + block_k - 1
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, blk_k_lo <= blk_q_hi)
    if window > 0:
        live = jnp.logical_and(live, blk_k_hi > blk_q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, Dv)
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
        if softcap and softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        q_pos = q_offset + iq * block_q + lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_k                            # kv padding
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        pv = lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, q_offset: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, Dk/Dv) with H % KV == 0.
    Returns (B, Sq, H, Dv).  ``interpret=None`` defers to
    REPRO_PALLAS_INTERPRET / the backend default (compile only on TPU)."""
    interpret = resolve_interpret(interpret)
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    assert H % KV == 0, (H, KV)

    block_q = max(8, min(block_q, Sq))
    block_k = max(8, min(block_k, Sk))
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)
    pq = nq * block_q - Sq
    pk = nk * block_k - Sk
    # (B, heads, S, D) layout for clean (block, head_dim) tiles
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pq), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pk), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pk), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_k=Sk,
        causal=causal, window=window, softcap=softcap, q_offset=q_offset,
        scale=1.0 / math.sqrt(D))

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h * KV // H, ik, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, iq, ik: (b, h * KV // H, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running sum l
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
