"""Pallas TPU kernels for the compute hot-spots.

The paper (Unicron) has no kernel-level contribution — its substrate
does.  Three hot-spots get TPU-native kernels, each with an ``ops.py``
jit'd wrapper and a ``ref.py`` pure-jnp oracle:

  * flash_attention — blocked online-softmax attention (GQA, sliding
    window, softcap) with VMEM scratch across the kv grid dim.
  * ssd_scan        — Mamba2 SSD chunk scan as dense MXU matmuls with the
    (P, N) recurrent state carried in VMEM.
  * rmsnorm         — fused normalization (one read + one write).
  * maxplus         — banded max-plus (tropical) convolution, the
    planner's DP inner loop (``REPRO_PLANNER_BACKEND=pallas``).

Models select them with ``kernel="pallas"``; CPU validation runs through
``interpret=True``.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
