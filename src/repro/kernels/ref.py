"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernel tests ``assert_allclose`` against
(and the backward functions for the kernels' custom VJPs).  They
intentionally share code with the model's own jnp paths so that switching
``kernel="jnp" -> "pallas"`` is a pure performance change.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.models.layers import blocked_attention, simple_attention
from repro.models.ssm import ssd_chunked


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_offset: int = 0) -> jnp.ndarray:
    """Oracle attention: blocked online-softmax for long sequences,
    direct softmax for short ones (they agree to float tolerance)."""
    if q.shape[1] > 1024:
        return blocked_attention(q, k, v, causal=causal, window=window,
                                 softcap=softcap, q_offset=q_offset)
    return simple_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, q_offset=q_offset)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, init_state=None):
    """Oracle SSD chunk scan (see models/ssm.py)."""
    return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk, init_state=init_state)


def rmsnorm(x, scale, *, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(ms + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
