"""Jit'd public wrappers around the Pallas kernels.

Each op runs the Pallas forward kernel and differentiates through the
pure-jnp oracle (``ref.py``) via ``jax.custom_vjp`` — standard practice
for forward-optimized kernels: the backward pass recomputes from the
oracle, which is bitwise-compatible with the kernel output to float
tolerance (asserted by tests/test_kernels.py).

``interpret`` resolution lives in ``pallas_config.resolve_interpret``: the
kernels compile on TPU (Mosaic) and interpret everywhere else, with
REPRO_PALLAS_INTERPRET / per-call kwargs as the overrides.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd
from repro.kernels.ssd_scan import ssd_scan_fwd


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_offset: int = 0) -> jnp.ndarray:
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset)


def _fa_fwd(q, k, v, causal, window, softcap, q_offset):
    out = flash_attention(q, k, v, causal, window, softcap, q_offset)
    return out, (q, k, v)


def _fa_bwd(causal, window, softcap, q_offset, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# SSD chunk scan
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 128) -> Tuple:
    return ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk)


def _ssd_fwd(x, dt, A, Bm, Cm, chunk):
    out = ssd_scan(x, dt, A, Bm, Cm, chunk)
    return out, (x, dt, A, Bm, Cm)


def _ssd_bwd(chunk, res, g):
    x, dt, A, Bm, Cm = res
    _, vjp = jax.vjp(
        lambda x, dt, A, Bm, Cm: ref.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk),
        x, dt, A, Bm, Cm)
    return vjp(g)


ssd_scan.defvjp(_ssd_fwd, _ssd_bwd)


# ---------------------------------------------------------------------------
# fused RMSNorm
# ---------------------------------------------------------------------------


@jax.custom_vjp
def rmsnorm(x, scale) -> jnp.ndarray:
    return rmsnorm_fwd(x, scale)


def _rn_fwd(x, scale):
    return rmsnorm(x, scale), (x, scale)


def _rn_bwd(res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda x, s: ref.rmsnorm(x, s), x, scale)
    return vjp(g)


rmsnorm.defvjp(_rn_fwd, _rn_bwd)
