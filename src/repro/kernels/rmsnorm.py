"""Pallas TPU fused RMSNorm.

One VMEM pass per (rows x d_model) tile: mean-of-squares reduce, rsqrt,
scale — fusing what would otherwise be 4 HBM round-trips (square, mean,
rsqrt, mul) into one read + one write.  Rows are tiled at 256 to keep the
(256, d_model) f32 tile within VMEM for d_model up to ~8k.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_config import resolve_interpret


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_fwd(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                interpret: Optional[bool] = None):
    """x: (..., d); scale: (d,).  Returns rmsnorm(x) * scale in x.dtype.
    ``interpret=None`` defers to REPRO_PALLAS_INTERPRET / the backend
    default (compile only on TPU)."""
    interpret = resolve_interpret(interpret)
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = max(8, min(block_rows, rows))
    nr = pl.cdiv(rows, br)
    pad = nr * br - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * br, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(orig_shape)
