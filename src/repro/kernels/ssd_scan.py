"""Pallas TPU kernel for the Mamba2 SSD (state-space duality) chunk scan.

TPU adaptation of the SSD algorithm [arXiv:2405.21060]: instead of a
token-serial recurrence (hostile to the MXU), the sequence is processed in
chunks of L tokens.  Per chunk, everything is dense matmuls —

  intra-chunk:  Y_diag = ((C B^T) .* Lmat .* dt) X          (L,L)@(L,P)
  chunk state:  S_c    = (B .* decay .* dt)^T X             (N,L)@(L,P)
  inter-chunk:  Y_off  = exp(acum) .* (C S_{c-1})           (L,N)@(N,P)

— with the (P, N) recurrent state carried in VMEM scratch across the
chunk grid dimension (last grid dim = sequential on TPU).  The grid is
(batch, heads, chunks); blocks hold one chunk of one head: X (L, P),
dt (L,), B/C (L, N) — all VMEM-resident, with L=chunk default 128 so the
(L,L) and (L,N) matmuls are MXU-aligned.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_config import resolve_interpret


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fin_ref,
                state_ref, *, chunk: int, seq: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    L = chunk
    x = x_ref[0, 0].astype(jnp.float32)                 # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)               # (L,)
    A = a_ref[0]                                        # () scalar <= 0
    Bm = b_ref[0, 0].astype(jnp.float32)                # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)                # (L, N)

    # padding tokens contribute nothing: zero their dt
    tok = ic * L + lax.broadcasted_iota(jnp.int32, (L,), 0)
    dt = jnp.where(tok < seq, dt, 0.0)

    a = dt * A                                          # (L,) log-decays
    acum = jnp.cumsum(a)                                # inclusive

    # intra-chunk: Lmat[l, s] = exp(acum[l] - acum[s]) for s <= l
    diff = acum[:, None] - acum[None, :]
    tri = lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        lax.broadcasted_iota(jnp.int32, (L, L), 1)
    lmat = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, L)
    w = scores * lmat * dt[None, :]
    y = lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)        # (L, P)

    # inter-chunk: contribution of the carried state (P, N)
    decay_in = jnp.exp(acum)                            # (L,)
    cs = lax.dot_general(Cm, state_ref[...],
                         (((1,), (1,)), ((), ())),
                         preferred_element_type=jnp.float32)       # (L, P)
    y = y + cs * decay_in[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S = exp(acum[-1]) S + sum_s exp(acum[-1]-acum[s]) dt_s
    #                                         x_s B_s^T          (P, N)
    decay_out = jnp.exp(acum[L - 1] - acum) * dt        # (L,)
    xb = lax.dot_general(x, Bm * decay_out[:, None],
                         (((0,), (0,)), ((), ())),
                         preferred_element_type=jnp.float32)       # (P, N)
    state_ref[...] = state_ref[...] * jnp.exp(acum[L - 1]) + xb

    @pl.when(ic == nc - 1)
    def _emit_state():
        fin_ref[0, 0] = state_ref[...].astype(fin_ref.dtype)


def ssd_scan_fwd(x, dt, A, Bm, Cm, *, chunk: int = 128,
                 interpret: Optional[bool] = None):
    """x: (B,S,H,P) f32; dt: (B,S,H) f32; A: (H,) f32 (<=0);
    Bm, Cm: (B,S,G,N) with H % G == 0.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).  ``interpret=None``
    defers to REPRO_PALLAS_INTERPRET / the backend default (compile only
    on TPU)."""
    interpret = resolve_interpret(interpret)
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0, (H, G)
    L = max(8, min(chunk, S))
    nc = pl.cdiv(S, L)
    pad = nc * L - S

    xt = jnp.pad(x.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad), (0, 0)))
    dtt = jnp.pad(dt.transpose(0, 2, 1), ((0, 0), (0, 0), (0, pad)))
    bt = jnp.pad(Bm.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad), (0, 0)))
    ct = jnp.pad(Cm.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_ssd_kernel, chunk=L, seq=S)
    y, fin = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h * G // H, c, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h * G // H, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, nc * L, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, bt, ct)
    return y[:, :, :S].transpose(0, 2, 1, 3), fin
