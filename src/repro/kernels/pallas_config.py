"""Shared Pallas execution-mode policy for the kernel modules.

``interpret`` resolution order:

  1. explicit kwarg (``True``/``False``) passed by the caller,
  2. ``REPRO_PALLAS_INTERPRET`` env var (``1/true/yes`` or ``0/false/no``),
  3. backend default: compile only on TPU (Mosaic).  These kernels use
     TPU-flavored constructs (``pltpu.VMEM`` scratch shapes, sequential
     last grid dim) that the GPU/Triton lowering does not accept, so CPU
     *and* GPU fall back to interpret mode.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_ENV = "REPRO_PALLAS_INTERPRET"
_TRUE = {"1", "true", "yes", "on"}
_FALSE = {"0", "false", "no", "off"}


def resolve_interpret(override: Optional[bool] = None) -> bool:
    if override is not None:
        return override
    env = os.environ.get(_ENV, "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    if env:
        raise ValueError(
            f"{_ENV}={os.environ[_ENV]!r} is not recognized; use one of "
            f"{sorted(_TRUE)} or {sorted(_FALSE)}")
    return jax.default_backend() != "tpu"
