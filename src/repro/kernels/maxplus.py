"""Pallas TPU max-plus (tropical) convolution — the planner's DP kernel.

    out[j] = max_{0 <= k <= min(j, band)} prev[j-k] + g[k]

One grid program per ``block`` output cells; the padded ``prev`` vector
and the reward row ``g`` sit whole in VMEM (they are O(n) f32 — a few KB
at planner scale), and the kernel folds the band with a ``fori_loop`` of
fused shift+add+max steps, so no (n x n) candidate matrix ever exists in
any memory space.  Follows the repo's execution-mode policy
(``pallas_config``): compiled via Mosaic on TPU, interpreted on CPU/GPU,
``REPRO_PALLAS_INTERPRET``/kwarg override.

``maxplus_conv_batched`` is the grid-batched variant behind the
``engine="batched"`` PlanTable: a (B, n+1) stack of independent
convolutions with per-row bands runs as ONE ``pallas_call`` whose grid
carries the stack axis — grid (B, n_blocks), each program reading only
its own row's padded ``prev``/``g`` block.  Per-row bands are applied by
masking each ``g`` row to -inf past its band (value-neutral: a masked
candidate can never beat the always-present finite k=0 candidate), so
every row equals the 2-D kernel on its own slice.

``maxplus_scan_chunk`` is the scan-compatible entry the fused
one-program planner engine (``engine="fused"``) uses as its inner step:
every operand arrives pre-gathered at a *static* chunk width, so the
same ``pallas_call`` shape serves every step of a ``lax.scan`` over the
planner's padded level schedule (see ``core.planner``'s "fused"
section).

The kernels run in float32 (planner's numpy path is float64); the
``REPRO_PLANNER_BACKEND=pallas`` switch in ``core.planner`` therefore
trades ~1e-7 relative reward precision for the TPU hot path and is
opt-in.  ``tests/test_kernels.py`` pins interpret-mode equivalence
against the numpy oracles (CI runs it under REPRO_PALLAS_INTERPRET=1 on
every PR, 2-D and batched legs both) and records the documented f32
error budget on paper-scale reward rows
(``test_maxplus_f32_error_budget_paper_scale``) — the ROADMAP's gate
before this backend could ever become the default.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.pallas_config import resolve_interpret

NEG = float("-inf")


def _maxplus_kernel(prev_ref, g_ref, o_ref, *, band: int, block: int):
    """o[dj] = max_k prev_pad[pid*block + band + dj - k] + g[k]."""
    j0 = pl.program_id(0) * block

    def body(k, acc):
        w = prev_ref[0, pl.ds(j0 + band - k, block)]     # prev[j0+dj-k]
        gk = g_ref[0, pl.ds(k, 1)]                       # g[k]
        return jnp.maximum(acc, w + gk[0])

    init = jnp.full((block,), NEG, dtype=jnp.float32)
    o_ref[0, :] = jax.lax.fori_loop(0, band + 1, body, init)


@functools.partial(jax.jit,
                   static_argnames=("band", "block", "interpret"))
def _maxplus_call(prev_pad, g, band: int, block: int, interpret: bool):
    grid_blocks = (prev_pad.shape[1] - band) // block
    return pl.pallas_call(
        functools.partial(_maxplus_kernel, band=band, block=block),
        grid=(grid_blocks,),
        in_specs=[
            pl.BlockSpec(prev_pad.shape, lambda i: (0, 0)),
            pl.BlockSpec(g.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, grid_blocks * block),
                                       jnp.float32),
        interpret=interpret,
    )(prev_pad, g)


def maxplus_conv(prev, g, band: Optional[int] = None, *,
                 block: int = 128,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Banded max-plus convolution of ``prev`` (DP value vector) with
    ``g`` (reward row), both length n+1; returns the length-n+1 float32
    value vector.  ``band=None`` is the dense convolution; a finite band
    is exact under the planner's band contract (``prev`` monotone,
    ``g`` flat past the band)."""
    prev = jnp.asarray(prev, dtype=jnp.float32)
    g = jnp.asarray(g, dtype=jnp.float32)
    if prev.ndim != 1 or g.ndim != 1 or prev.shape != g.shape:
        raise ValueError(f"prev/g must be equal-length vectors, got "
                         f"{prev.shape} vs {g.shape}")
    n = prev.shape[0] - 1
    b = n if band is None else max(0, min(int(band), n))
    interpret = resolve_interpret(interpret)
    nb = max(1, -(-(n + 1) // block))                    # cdiv
    length = nb * block
    prev_pad = jnp.full((1, b + length), NEG, dtype=jnp.float32)
    prev_pad = prev_pad.at[0, b:b + n + 1].set(prev)
    g_pad = jnp.full((1, max(n + 1, block)), NEG, dtype=jnp.float32)
    g_pad = g_pad.at[0, :n + 1].set(g)
    out = _maxplus_call(prev_pad, g_pad, b, block, interpret)
    return out[0, :n + 1]


def maxplus_conv_np(prev: np.ndarray, g: np.ndarray,
                    band: Optional[int] = None) -> np.ndarray:
    """Float32 numpy oracle with the kernel's exact candidate arithmetic
    (f32 adds, order-free max) — the interpret-mode equivalence target."""
    prev32 = np.asarray(prev, dtype=np.float32)
    g32 = np.asarray(g, dtype=np.float32)
    n = prev32.shape[0] - 1
    b = n if band is None else max(0, min(int(band), n))
    pad = np.concatenate([np.full(b, NEG, dtype=np.float32), prev32])
    win = np.lib.stride_tricks.sliding_window_view(pad, b + 1)
    return (win + g32[b::-1][None, :]).max(axis=1)


# ---------------------------------------------------------------------------
# Grid-batched kernel: B independent banded convolutions, one pallas_call
# ---------------------------------------------------------------------------


def _maxplus_batched_kernel(prev_ref, g_ref, o_ref, *, band: int,
                            block: int):
    """o[b, dj] = max_k prev_pad[b, j0 + band + dj - k] + g[b, k] for the
    (batch row, output block) this program owns."""
    j0 = pl.program_id(1) * block

    def body(k, acc):
        w = prev_ref[0, pl.ds(j0 + band - k, block)]     # prev[b, j0+dj-k]
        gk = g_ref[0, pl.ds(k, 1)]                       # g[b, k]
        return jnp.maximum(acc, w + gk[0])

    init = jnp.full((block,), NEG, dtype=jnp.float32)
    o_ref[0, :] = jax.lax.fori_loop(0, band + 1, body, init)


@functools.partial(jax.jit,
                   static_argnames=("band", "block", "interpret"))
def _maxplus_batched_call(prev_pad, g, band: int, block: int,
                          interpret: bool):
    B = prev_pad.shape[0]
    grid_blocks = (prev_pad.shape[1] - band) // block
    return pl.pallas_call(
        functools.partial(_maxplus_batched_kernel, band=band, block=block),
        grid=(B, grid_blocks),
        in_specs=[
            pl.BlockSpec((1, prev_pad.shape[1]), lambda b, i: (b, 0)),
            pl.BlockSpec((1, g.shape[1]), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, grid_blocks * block),
                                       jnp.float32),
        interpret=interpret,
    )(prev_pad, g)


def maxplus_conv_batched(prev, g, bands=None, *, block: int = 128,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Stacked banded max-plus convolution: ``prev`` and ``g`` are
    (B, n+1) float32 stacks, ``bands`` a per-row band sequence (``None``
    entries = dense; a scalar or ``None`` applies one band to every
    row).  Returns the (B, n+1) float32 value stack; row r equals
    ``maxplus_conv(prev[r], g[r], band=bands[r])`` — rows are padded to
    the widest band and the extra candidates are masked to -inf, which
    never beats the finite k=0 candidate.  The batch axis rides on the
    Pallas grid: one launch for the whole level of the batched
    PlanTable engine."""
    prev = jnp.asarray(prev, dtype=jnp.float32)
    g = jnp.asarray(g, dtype=jnp.float32)
    if prev.ndim != 2 or g.ndim != 2 or prev.shape != g.shape:
        raise ValueError(f"prev/g must be equal-shape (B, n+1) stacks, "
                         f"got {prev.shape} vs {g.shape}")
    B, n1 = prev.shape
    n = n1 - 1
    if bands is None or np.isscalar(bands):
        bands = [bands] * B
    bs = np.array([n if b is None else max(0, min(int(b), n))
                   for b in bands], dtype=np.int64)
    if len(bs) != B:
        raise ValueError(f"got {len(bs)} bands for a batch of {B}")
    bmax = int(bs.max()) if B else 0
    interpret = resolve_interpret(interpret)
    nb = max(1, -(-n1 // block))                         # cdiv
    length = nb * block
    prev_pad = jnp.full((B, bmax + length), NEG, dtype=jnp.float32)
    prev_pad = prev_pad.at[:, bmax:bmax + n1].set(prev)
    ks = np.arange(n1)
    g = jnp.where(jnp.asarray(ks[None, :] > bs[:, None]), NEG, g)
    g_pad = jnp.full((B, max(n1, block)), NEG, dtype=jnp.float32)
    g_pad = g_pad.at[:, :n1].set(g)
    out = _maxplus_batched_call(prev_pad, g_pad, bmax, block, interpret)
    return out[:, :n1]


# ---------------------------------------------------------------------------
# Scan-compatible chunk kernel: the fused one-program engine's inner step
# ---------------------------------------------------------------------------


def _maxplus_scan_kernel(w_ref, g_ref, o_ref, *, chunk: int, block: int):
    """o[r, dj] = max_k w[r, j0 + dj + chunk-1 - k] + g[r, k] for the
    (row, output block) this program owns."""
    j0 = pl.program_id(1) * block

    def body(k, acc):
        w = w_ref[0, pl.ds(j0 + chunk - 1 - k, block)]   # w[r, j+K-1-k]
        gk = g_ref[0, pl.ds(k, 1)]                       # g[r, k]
        return jnp.maximum(acc, w + gk[0])

    init = jnp.full((block,), NEG, dtype=jnp.float32)
    o_ref[0, :] = jax.lax.fori_loop(0, chunk, body, init)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block", "interpret"))
def _maxplus_scan_call(wins, gs, chunk: int, block: int, interpret: bool):
    B = wins.shape[0]
    grid_blocks = (wins.shape[1] - (chunk - 1)) // block
    return pl.pallas_call(
        functools.partial(_maxplus_scan_kernel, chunk=chunk, block=block),
        grid=(B, grid_blocks),
        in_specs=[
            pl.BlockSpec((1, wins.shape[1]), lambda b, i: (b, 0)),
            pl.BlockSpec((1, gs.shape[1]), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, grid_blocks * block),
                                       jnp.float32),
        interpret=interpret,
    )(wins, gs)


def maxplus_scan_chunk(wins, gs, *, block: int = 128,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Chunked max-plus step over pre-gathered windows — the fused
    planner engine's ``lax.scan`` inner kernel.

    ``wins`` is a (B, n1 + K - 1) stack of already-shifted ``prev``
    windows (position ``j + K-1-k`` holds ``prev[j - (off+k)]`` for the
    row's candidate-offset chunk base ``off``, -inf where out of range)
    and ``gs`` a (B, K) stack of reward-row chunks (masked to -inf past
    each row's band).  Returns the (B, n1) float32 stack::

        out[r, j] = max_{0 <= k < K} wins[r, j + K-1-k] + gs[r, k]

    Every shape is a function of (B, n1, K) only — all static per
    planner schedule signature — so one trace serves every scan step,
    and the fused engine's whole-table rebuild stays a single compiled
    dispatch.  Chunk decomposition is exact: a banded convolution's
    candidate set partitions over offset chunks, and the caller's
    scatter-max reduction over chunks reproduces the full-band maximum
    order-free."""
    wins = jnp.asarray(wins, dtype=jnp.float32)
    gs = jnp.asarray(gs, dtype=jnp.float32)
    if wins.ndim != 2 or gs.ndim != 2 or wins.shape[0] != gs.shape[0]:
        raise ValueError(f"wins/gs must be (B, n1+K-1)/(B, K) stacks, "
                         f"got {wins.shape} vs {gs.shape}")
    B, K = gs.shape
    n1 = wins.shape[1] - (K - 1)
    if n1 < 1:
        raise ValueError(f"window width {wins.shape[1]} shorter than "
                         f"chunk {K}")
    interpret = resolve_interpret(interpret)
    nb = max(1, -(-n1 // block))                         # cdiv
    wins_pad = jnp.full((B, (K - 1) + nb * block), NEG, dtype=jnp.float32)
    wins_pad = wins_pad.at[:, :wins.shape[1]].set(wins)
    gs_pad = jnp.full((B, max(K, block)), NEG, dtype=jnp.float32)
    gs_pad = gs_pad.at[:, :K].set(gs)
    out = _maxplus_scan_call(wins_pad, gs_pad, K, block, interpret)
    return out[:, :n1]
