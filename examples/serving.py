"""Batched serving example: prefill + decode with the KV/state cache.

Part 1 serves batched requests through the static RequestBatcher for a
dense-GQA arch and the attention-free SSM arch (O(1) decode state — the
long_500k path).  Part 2 runs the vLLM-style continuous batcher: six
requests of different lengths share two lanes, joining and leaving
mid-flight (per-lane decode positions); one poisoned request is evicted
(lane failure -> lane recycled) and the batcher's lane-outcome counters
calibrate the planner-side ``ServingSLO`` objective — the feedback loop
between decode-path health and cluster-level worker assignment.

    PYTHONPATH=src python examples/serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.serve.decode import RequestBatcher
from repro.serve.scheduler import ContinuousBatcher, Request


def main():
    for arch in ("qwen3-4b", "mamba2-780m"):
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        data = SyntheticLM(cfg, seq_len=16, global_batch=4)
        prompts = [data.batch(0)["tokens"][i] for i in range(3)]

        batcher = RequestBatcher(model, params, batch_size=4, capacity=64)
        t0 = time.time()
        outs = batcher.serve(prompts, n_new=12)
        dt = time.time() - t0
        print(f"{arch}: served {len(outs)} requests, 12 new tokens each "
              f"({dt:.1f}s incl. compile)")
        for i, o in enumerate(outs):
            print(f"  req{i}: {o.tolist()}")
        # greedy decode is deterministic
        again = batcher.serve(prompts, n_new=12)
        assert all(jnp.array_equal(a, b) for a, b in zip(outs, again))
        print(f"  deterministic: yes")

    # ---- continuous batching: 6 requests over 2 lanes --------------------
    cfg = get_arch("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(model, params, batch_size=2, capacity=48)
    key = jax.random.PRNGKey(11)
    for i in range(6):
        plen = 4 + 2 * (i % 3)
        prompt = jax.random.randint(jax.random.fold_in(key, i), (plen,), 0,
                                    cfg.vocab)
        cb.submit(Request(req_id=i, prompt=prompt, max_new=5 + i))
    t0 = time.time()
    cb.step()                      # admits the first two requests
    cb.evict(0)                    # req 0 is poisoned: lane failure
    done = cb.run()
    print(f"\ncontinuous batching: {len(done)} requests over 2 lanes in "
          f"{cb.steps} fused steps ({time.time() - t0:.1f}s)")
    for r in sorted(done, key=lambda r: r.req_id):
        print(f"  req{r.req_id} ({r.prompt.shape[0]} prompt toks -> "
              f"{len(r.out)} new): {r.out}")

    # ---- lane stats -> planner objective calibration ---------------------
    from repro.core.waf import ServingSLO
    stats = cb.slo_stats()
    slo = ServingSLO(rate_rps=120.0).calibrated(stats)
    print(f"\nslo_stats: {stats}")
    print(f"calibrated ServingSLO: lane_fail_discount="
          f"{slo.lane_fail_discount:.3f} (per-worker capacity "
          f"{slo.capacity_rps * (1 - slo.lane_fail_discount):.2f} rps "
          f"of {slo.capacity_rps:.0f})")


if __name__ == "__main__":
    main()
