"""Multi-task cluster management demo (the paper's Fig. 11 scenario).

Replays a compressed failure trace against a 128-GPU cluster running six
concurrent GPT-3 training tasks under each recovery policy, then prints
the accumulated-WAF comparison and the Unicron coordinator's actual plan
decisions for the first few SEV1 events.

    PYTHONPATH=src python examples/multitask_cluster.py
"""
from repro.configs import get_arch
from repro.core.costmodel import A800, TaskModel
from repro.core.coordinator import UnicronCoordinator
from repro.core.simulator import run_policies
from repro.core.traces import trace_b
from repro.core.waf import Task


def main():
    sizes = ["gpt3-1.3b"] * 3 + ["gpt3-7b"] * 2 + ["gpt3-13b"]
    weights = [2.0, 1.7, 1.4, 1.1, 0.8, 0.5]
    tasks = [Task(model=TaskModel.from_arch(get_arch(s), global_batch=128),
                  weight=w) for s, w in zip(sizes, weights)]
    assignment = [16, 16, 16, 24, 24, 32]

    print("== coordinator plan decisions (first SEV1 events) ==")
    coord = UnicronCoordinator(tasks, assignment, A800)
    trace = trace_b()
    sev1 = [e for e in trace if e.repair_s is not None][:3]
    n = 128
    for e in sev1:
        n -= 8
        plan = coord.reconfigure(n, faulted_task=e.node % len(tasks))
        print(f"t={e.time / 3600:7.1f}h {e.kind.value:18s} "
              f"-> plan {plan.assignment} (cluster WAF "
              f"{plan.waf / 1e12:.0f} TFLOP/s)")

    print("\n== trace-b replay: accumulated WAF per policy ==")
    res = run_policies(tasks, assignment, trace)
    uni = res["unicron"].accumulated_waf
    for p, r in sorted(res.items(), key=lambda kv: -kv[1].accumulated_waf):
        print(f"  {p:10s} acc_waf={r.accumulated_waf:.3e}  "
              f"unicron is {uni / r.accumulated_waf:4.2f}x  "
              f"(downtime {r.downtime_s / 3600:.1f}h, "
              f"{r.n_reconfigs} reconfigs)")


if __name__ == "__main__":
    main()
