"""Multi-task cluster management demo (the paper's Fig. 11 scenario).

Replays a compressed failure trace against a 128-GPU cluster running six
concurrent GPT-3 training tasks under each recovery policy, then prints
the accumulated-WAF comparison and the Unicron coordinator's actual plan
decisions for the first few SEV1 events.  A final section admits a
serving task (``ServingSLO`` objective: goodput under a p99 latency SLO,
saturating at the offered request rate) and replays the same failures to
show the planner trading training throughput against serving goodput —
and re-trading when the offered load steps up (``Task.objective`` swap,
the ``scenarios.RateChangeEvent`` path).

    PYTHONPATH=src python examples/multitask_cluster.py
"""
import dataclasses

from repro.configs import get_arch
from repro.core.costmodel import A800, TaskModel
from repro.core.coordinator import UnicronCoordinator
from repro.core.simulator import run_policies
from repro.core.traces import trace_b
from repro.core.waf import ServingSLO, Task


def main():
    sizes = ["gpt3-1.3b"] * 3 + ["gpt3-7b"] * 2 + ["gpt3-13b"]
    weights = [2.0, 1.7, 1.4, 1.1, 0.8, 0.5]
    tasks = [Task(model=TaskModel.from_arch(get_arch(s), global_batch=128),
                  weight=w) for s, w in zip(sizes, weights)]
    assignment = [16, 16, 16, 24, 24, 32]

    print("== coordinator plan decisions (first SEV1 events) ==")
    coord = UnicronCoordinator(tasks, assignment, A800)
    trace = trace_b()
    sev1 = [e for e in trace if e.repair_s is not None][:3]
    n = 128
    for e in sev1:
        n -= 8
        plan = coord.reconfigure(n, faulted_task=e.node % len(tasks))
        print(f"t={e.time / 3600:7.1f}h {e.kind.value:18s} "
              f"-> plan {plan.assignment} (cluster WAF "
              f"{plan.waf / 1e12:.0f} TFLOP/s)")

    print("\n== trace-b replay: accumulated WAF per policy ==")
    res = run_policies(tasks, assignment, trace)
    uni = res["unicron"].accumulated_waf
    for p, r in sorted(res.items(), key=lambda kv: -kv[1].accumulated_waf):
        print(f"  {p:10s} acc_waf={r.accumulated_waf:.3e}  "
              f"unicron is {uni / r.accumulated_waf:4.2f}x  "
              f"(downtime {r.downtime_s / 3600:.1f}h, "
              f"{r.n_reconfigs} reconfigs)")

    # ---- mixed fleet: a serving task joins (ServingSLO objective) --------
    # weight = FLOP-equivalents per served request: the knapsack DP trades
    # serving goodput against training throughput in one currency
    slo = ServingSLO(rate_rps=120.0, capacity_rps=8.0)
    serve = Task(model=tasks[0].model, weight=1e14, max_workers=40,
                 objective=slo)
    mixed = tasks[:4] + [serve]
    print("\n== mixed training+serving fleet: failure replan ==")
    coord = UnicronCoordinator(mixed, [24, 24, 24, 32, 24], A800,
                               n_cluster_workers=128)
    plan = coord.reconfigure(120, faulted_task=0)     # one node lost
    served = serve.objective.value(serve, plan.assignment[-1],
                                   A800) / serve.weight
    print(f"  plan {plan.assignment}: serving task holds "
          f"{plan.assignment[-1]} workers "
          f"({served:.0f} of {slo.rate_rps:.0f} rps within SLO)")

    # the offered load doubles (a RateChangeEvent in simulation): swap
    # the objective and replan — the serving slot widens at training's
    # expense
    surge = dataclasses.replace(serve, objective=slo.with_rate(240.0))
    coord.task_updated(4, surge)
    plan2 = coord.reconfigure(120, faulted_task=None)
    served2 = surge.objective.value(surge, plan2.assignment[-1],
                                    A800) / surge.weight
    print(f"  rate 120 -> 240 rps: plan {plan2.assignment}, serving "
          f"task now {plan2.assignment[-1]} workers "
          f"({served2:.0f} of 240 rps within SLO)")


if __name__ == "__main__":
    main()
