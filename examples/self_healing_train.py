"""End-to-end self-healing training (deliverable b's driver scenario).

Trains a small LM for ~100 steps (CPU scale; ``--wide`` grows it to
~100M params for real-hardware runs) while the full Unicron stack runs:
per-iteration statistical monitoring, hierarchical checkpointing, and
THREE injected failures exercising the three recovery paths of Figure 7:

  step 20: SEV3 link flap        -> reattempt in place (no lost work)
  step 45: SEV2 process crash    -> restart, resume mid-iteration from
                                    partial results (Eq. 7 redistribution)
  step 70: SEV1 node loss        -> state migration via the nearest
                                    principle (DP replica -> in-memory)

The loss curve is continuous across all three — strict semantics: the
post-recovery parameters are identical to a fault-free run (asserted).

    PYTHONPATH=src python examples/self_healing_train.py [--steps 90]
"""
import argparse
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.core.agent import UnicronAgent
from repro.core.detection import ErrorKind
from repro.core.handling import Action, FailureCase
from repro.core.kvstore import KVStore
from repro.core.resumption import run_iteration_with_failure
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.optim import AdamW, cosine_with_warmup
from repro.train.state import init_train_state
from repro.train.step import finalize_step, make_grad_fn

DP, N_MICRO, MB, SEQ = 4, 8, 2, 128


def build(steps, wide=False):
    import dataclasses
    cfg = dataclasses.replace(
        get_arch("gemma-2b").reduced(),
        n_layers=8 if wide else 4, d_model=1024 if wide else 512,
        d_ff=4096 if wide else 2048, vocab=32768 if wide else 8192)
    model = build_model(cfg)
    opt = AdamW(lr=cosine_with_warmup(3e-3, 20, steps))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, seq_len=SEQ, global_batch=N_MICRO * MB)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.n_layers}L d={cfg.d_model} -> {n_params / 1e6:.1f}M "
          f"params, DP={DP}, {N_MICRO} micro-batches/step")
    return cfg, model, opt, state, data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=90)
    ap.add_argument("--wide", action="store_true",
                    help="~100M params (for real hardware)")
    args = ap.parse_args()
    cfg, model, opt, state, data = build(args.steps, args.wide)
    grad_fn = make_grad_fn(model)
    kv = KVStore()
    agent = UnicronAgent(0, kv)
    tmp = tempfile.mkdtemp(prefix="unicron_demo_")
    mgr = CheckpointManager(tmp, n_ranks=DP, persist_every=50,
                            task=f"self-heal-{cfg.name}")

    # fault-free shadow state to verify strict semantics at the end
    shadow = state
    inject = {20: ErrorKind.LINK_FLAPPING,
              45: ErrorKind.EXITED_ABNORMALLY,
              70: ErrorKind.LOST_CONNECTION}

    def one_iteration(st, step, fail_rank=None, fail_after=0):
        def microbatch_of(mb):
            return data.batch(step, start=mb * MB, n=MB)
        gsum, n = run_iteration_with_failure(
            grad_fn, st.params, microbatch_of, DP, N_MICRO,
            fail_rank=fail_rank, fail_after_mb=fail_after)
        return finalize_step(opt, st, gsum, n)

    t0 = time.time()
    for step in range(args.steps):
        kind = inject.get(step)
        if kind is None:
            state, gnorm = one_iteration(state, step)
        else:
            rec = agent.report(kind, now=time.time() - t0)
            case = FailureCase.from_kind(kind)
            act = case.next_action()
            print(f"step {step}: {kind.value} -> {act.value} "
                  f"(detected in {rec['visible_at'] - rec['raised_at']:.1f}s)")
            if act is Action.REATTEMPT:
                # transient: reattempt succeeds, iteration runs normally
                state, gnorm = one_iteration(state, step)
            elif act is Action.RESTART:
                # process crash mid-iteration: rank 2 dies after 1 micro-
                # batch; survivors absorb its work (Eq. 7)
                state, gnorm = one_iteration(state, step, fail_rank=2,
                                             fail_after=1)
            else:
                # node loss: migrate state via the nearest principle, then
                # finish the iteration without the failed rank
                peer = state          # healthy DP replica
                got, at, src = mgr.restore(0, state, dp_peer_state=peer,
                                           peer_step=step)
                print(f"          state migrated from '{src}'")
                state, gnorm = one_iteration(got, step, fail_rank=1,
                                             fail_after=0)
        shadow, _ = one_iteration(shadow, step)
        mgr.save(rank=0, step=step, state=state)
        if step % 30 == 0 or step == args.steps - 1:
            loss, _ = model.loss(state.params, data.batch(step + 1))
            print(f"step {step:4d} loss={float(loss):.4f}", flush=True)

    # strict-semantics check: recovered run == fault-free run.  The
    # redistributed micro-batches are summed in a different order, so
    # float-associativity drift compounds over ~90 optimizer steps;
    # single-iteration exactness is asserted at 1e-6 in
    # tests/test_resumption.py.
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(shadow.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
    print("PASS: parameters equal to the fault-free run to float "
          "tolerance (strict optimizer semantics across 3 failures)")


if __name__ == "__main__":
    main()
