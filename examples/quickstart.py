"""Quickstart: build a model, train a few steps, save/restore, decode.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-4b]
"""
import argparse
import tempfile

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch, list_archs
from repro.data.pipeline import SyntheticLM, stack_microbatches
from repro.models.model import build_model
from repro.optim import AdamW, cosine_with_warmup
from repro.serve.decode import generate
from repro.train.state import init_train_state
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    # 1) config: full assigned architecture, reduced to smoke scale for CPU
    cfg = get_arch(args.arch).reduced()
    print(f"[1] {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"({cfg.param_count() / 1e6:.1f}M params, {cfg.arch_type})")

    # 2) model + optimizer + deterministic data
    model = build_model(cfg)
    opt = AdamW(lr=cosine_with_warmup(1e-3, 5, args.steps))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, seq_len=64, global_batch=8)

    # 3) train
    step = jax.jit(make_train_step(model, opt, n_micro=2))
    for i in range(args.steps):
        state, m = step(state, stack_microbatches(data.batch(i), 2))
        if i % 5 == 0 or i == args.steps - 1:
            print(f"[2] step {i:3d} loss={float(m['loss']):.4f}")

    # 4) checkpoint through the hierarchical manager
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, n_ranks=1, persist_every=1,
                                task=f"quickstart-{cfg.name}")
        mgr.save(rank=0, step=args.steps, state=state)
        restored, at, src = mgr.restore(0, state)
        print(f"[3] checkpoint restored from tier '{src}' at step {at}")

    # 5) greedy decode with the KV / state cache
    if not cfg.encoder_only and cfg.modality == "text":
        prompt = data.batch(0)["tokens"][:2, :8]
        out = generate(model, state.params, prompt, n_new=8)
        print(f"[4] generated tokens: {out.tolist()}")
    print("quickstart done")


if __name__ == "__main__":
    main()
