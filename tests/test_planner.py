"""Plan-generation tests (§5): WAF model, DP solver vs brute force,
lookup table, and the cost model's Figure-4 phenomenology."""
import pytest

from repro.configs import get_arch
from repro.core import costmodel, planner, waf
from repro.core.costmodel import A800, TPU_V5E, TaskModel
from repro.core.planner import PlanInput, PlanTable
from repro.core.waf import Task


def _task(size="gpt3-1.3b", weight=1.0, seq=2048, gb=256):
    cfg = get_arch(size)
    return Task(model=TaskModel.from_arch(cfg, seq_len=seq, global_batch=gb),
                weight=weight)


def _inp(tasks, assignment, n, d_run=3600.0, d_tr=120.0, faulted=None):
    faulted = faulted or (False,) * len(tasks)
    return PlanInput(tuple(tasks), tuple(assignment), n, d_run, d_tr,
                     tuple(faulted))


def test_waf_zero_below_necessary():
    t = _task("gpt3-7b")
    floor = t.necessary(A800)
    assert floor >= 1
    assert waf.waf(t, floor - 1, A800) == 0.0
    assert waf.waf(t, floor, A800) > 0.0


def test_waf_scales_with_weight():
    t1 = _task(weight=1.0)
    t2 = _task(weight=2.0)
    x = max(t1.necessary(A800), 8)
    assert waf.waf(t2, x, A800) == pytest.approx(2 * waf.waf(t1, x, A800))


def test_dp_matches_brute_force():
    tasks = [_task("gpt3-1.3b"), _task("gpt3-1.3b", weight=1.5),
             _task("gpt3-7b")]
    inp = _inp(tasks, [4, 4, 8], 12)
    got = planner.solve(inp, A800)
    want = planner.brute_force(inp, A800)
    assert got.total_reward == pytest.approx(want.total_reward, rel=1e-9)
    assert sum(got.assignment) <= inp.n_workers


def test_penalty_discourages_reconfiguring_healthy_tasks():
    """With a large transition cost, the planner keeps healthy tasks at
    their current assignment (Eq. 3 penalty term)."""
    tasks = [_task(), _task()]
    inp_cheap = _inp(tasks, [8, 8], 16, d_run=10 * 86400.0, d_tr=1.0)
    inp_dear = _inp(tasks, [8, 8], 16, d_run=600.0, d_tr=3000.0)
    dear = planner.solve(inp_dear, A800)
    assert dear.assignment == (8, 8)        # stay put: penalty dominates
    cheap = planner.solve(inp_cheap, A800)
    assert sum(cheap.assignment) <= 16


def test_plan_table_lookup_consistency():
    tasks = [_task("gpt3-1.3b"), _task("gpt3-7b")]
    assignment = [8, 24]
    table = PlanTable(tasks, assignment, A800, d_running=3600.0,
                      d_transition=120.0, workers_per_fault=8)
    hit = table.lookup("fault:0")
    assert hit is not None
    fresh = planner.solve(
        _inp(tasks, assignment, sum(assignment) - 8,
             faulted=(True, False)), A800)
    assert hit.total_reward == pytest.approx(fresh.total_reward, rel=1e-9)
    assert table.lookup("join:1") is not None
    assert table.lookup("finish:1") is not None
    assert table.lookup("nonsense") is None


def test_costmodel_nonlinear_figure4():
    """T(t, x) is monotone-ish but the achieved-FLOP/s *ratio* is not:
    awkward worker counts force worse parallelism configs (Fig. 4)."""
    t = TaskModel.from_arch(get_arch("gpt3-7b"), seq_len=2048,
                            global_batch=256)
    xs = list(range(8, 129, 8))
    ratios = [costmodel.flops_ratio(t, x, A800) for x in xs]
    assert all(0 <= r <= 1 for r in ratios)
    # non-monotonic ratio somewhere (the Fig. 4 dip)
    diffs = [b - a for a, b in zip(ratios, ratios[1:])]
    assert any(d < 0 for d in diffs), ratios


def test_costmodel_feasibility_floor():
    """Big models are infeasible on tiny clusters (memory), giving the
    T_necessary requirement floor."""
    big = TaskModel.from_arch(get_arch("gpt3-175b"), global_batch=256)
    assert costmodel.achieved_flops(big, 1, A800) == 0.0
    floor = costmodel.min_feasible_workers(big, A800)
    assert floor > 8
    assert costmodel.achieved_flops(big, floor, A800) > 0.0


def test_costmodel_tpu_preset():
    t = TaskModel.from_arch(get_arch("qwen3-4b"), global_batch=256)
    a = costmodel.achieved_flops(t, 64, TPU_V5E)
    assert a > 0
    assert a <= 64 * TPU_V5E.peak_flops


def test_expected_run_duration_shrinks_with_cluster():
    d1 = waf.expected_run_duration(64, 30 * 86400.0)
    d2 = waf.expected_run_duration(128, 30 * 86400.0)
    assert d2 < d1
