"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates its REDUCED variant (2 layers,
d_model <= 512, <= 4 experts — same family/block structure) and runs one
real forward/train step on CPU, asserting output shapes and the absence
of NaNs.  Non-encoder archs additionally run two decode steps against the
KV/state cache.  The FULL configs are exercised by the dry-run only.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_arch, supports_shape
from repro.data.pipeline import SyntheticLM, stack_microbatches
from repro.models.model import build_model
from repro.optim import AdamW, constant
from repro.serve.decode import make_serve_step
from repro.train.state import init_train_state
from repro.train.step import make_train_step

SEQ, BATCH, N_MICRO = 64, 4, 2


def _tree_finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    model = build_model(cfg)
    opt = AdamW(lr=constant(1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, seq_len=SEQ, global_batch=BATCH)
    batch = data.batch(0)

    logits, _ = model.forward(state.params, batch)
    expect_s = SEQ + (cfg.n_prefix_embeds if cfg.modality == "vision_stub"
                      else 0)
    assert logits.shape == (BATCH, expect_s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step = jax.jit(make_train_step(model, opt, N_MICRO))
    state2, metrics = step(state, stack_microbatches(batch, N_MICRO))
    assert jnp.isfinite(metrics["loss"])
    assert int(state2.step) == 1
    assert _tree_finite(state2.params)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_arch(arch).reduced()
    if cfg.encoder_only:
        pytest.skip("encoder-only: no decode step (DESIGN.md)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.init_cache(2, capacity=16)
    serve = jax.jit(make_serve_step(model))
    toks = jnp.zeros((2,), jnp.int32)
    for pos in range(3):
        toks, caches = serve(params, caches, toks, jnp.int32(pos))
        assert toks.shape == (2,)
        assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab)))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_shape_support_matrix(arch):
    """The (arch x shape) support matrix matches DESIGN.md §Shape-skips."""
    cfg = get_arch(arch)
    ok_long, _ = supports_shape(cfg, SHAPES["long_500k"])
    expect_long = arch in ("mamba2-780m", "zamba2-1.2b", "gemma3-12b")
    assert ok_long == expect_long
    ok_dec, _ = supports_shape(cfg, SHAPES["decode_32k"])
    assert ok_dec == (arch != "hubert-xlarge")
    ok_train, _ = supports_shape(cfg, SHAPES["train_4k"])
    assert ok_train


def test_full_configs_match_assignment():
    """Exact numbers from the assignment block."""
    expect = {
        "qwen3-4b": (36, 2560, 9728, 151936),
        "zamba2-1.2b": (38, 2048, 8192, 32000),
        "gemma3-12b": (48, 3840, 15360, 262144),
        "deepseek-v3-671b": (61, 7168, 2048, 129280),
        "granite-moe-3b-a800m": (32, 1536, 512, 49155),
        "mamba2-780m": (48, 1536, 0, 50280),
        "internvl2-2b": (24, 2048, 8192, 92553),
        "gemma-2b": (18, 2048, 16384, 256000),
        "hubert-xlarge": (48, 1280, 5120, 504),
        "granite-3-8b": (40, 4096, 12800, 49155),
    }
    for arch, (L, d, dff, v) in expect.items():
        cfg = get_arch(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.vocab == v, arch
        if cfg.moe is not None:
            assert cfg.moe.d_ff_expert == dff, arch
        elif dff:
            assert cfg.d_ff == dff, arch
    # attention/expert structure spot checks
    q = get_arch("qwen3-4b")
    assert q.attn.n_heads == 32 and q.attn.n_kv_heads == 8 and q.attn.qk_norm
    ds = get_arch("deepseek-v3-671b")
    assert ds.moe.n_experts == 256 and ds.moe.top_k == 8 and ds.mla
    g = get_arch("gemma-2b")
    assert g.attn.n_kv_heads == 1 and g.attn.head_dim == 256
    g3 = get_arch("gemma3-12b")
    assert g3.attn.local_ratio == (5, 1) and g3.attn.window > 0
    m = get_arch("mamba2-780m")
    assert m.ssm.d_state == 128 and m.attn is None
    h = get_arch("hubert-xlarge")
    assert h.encoder_only and not h.attn.causal
