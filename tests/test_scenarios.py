"""Scenario library (core.scenarios) + cluster-scale engine invariants:
seeded determinism, correlated failures respecting switch domains, the
§4.1 degradation margin, and vectorized-vs-scalar WAF equivalence."""
import pytest

from benchmarks.common import case5_tasks
from repro.core import scenarios as sc
from repro.core.detection import DEGRADE_MARGIN, OnlineStatMonitor
from repro.core.planner import PlannerCache
from repro.core.simulator import (EFFICIENCY, TraceSimulator,
                                  VectorSimulator, run_monte_carlo)
from repro.core.traces import DAY

N_NODES = 16
SPAN = 7 * DAY


def _sig(scenario):
    fails = [(e.time, e.node, e.kind, e.repair_s)
             for e in scenario.failures]
    degr = [(d.time, d.node, d.slowdown, d.duration_s)
            for d in scenario.degradations]
    churn = [(c.time, type(c).__name__) for c in scenario.churn]
    return (fails, degr, churn)


def test_identical_seeds_identical_traces():
    tasks, _ = case5_tasks()
    for maker in (
        lambda seed: sc.independent_failures(
            n_nodes=N_NODES, span_s=SPAN, seed=seed),
        lambda seed: sc.correlated_failures(
            n_nodes=N_NODES, span_s=SPAN, seed=seed),
        lambda seed: sc.slow_nodes(n_nodes=N_NODES, span_s=SPAN, seed=seed),
        lambda seed: sc.preemption_waves(
            n_nodes=N_NODES, span_s=SPAN, seed=seed),
        lambda seed: sc.mixed_fleet(
            n_nodes=N_NODES, span_s=SPAN, seed=seed, m_initial=6,
            candidates=tasks[:2]),
    ):
        assert _sig(maker(7)) == _sig(maker(7))
        assert _sig(maker(7)) != _sig(maker(8))


def test_correlated_failures_respect_group_boundaries():
    one = sc.correlated_failures(n_nodes=N_NODES, span_s=SPAN, seed=3,
                                 group_size=4, n_bursts=1,
                                 hit_fraction=1.0)
    assert one.failures, "burst produced no failures"
    groups = {one.groups.group_of(e.node) for e in one.failures}
    assert len(groups) == 1
    # multi-burst: cluster events by time gaps; each burst stays in-domain
    many = sc.correlated_failures(n_nodes=N_NODES, span_s=SPAN, seed=5,
                                  group_size=4, n_bursts=4,
                                  burst_span_s=60.0, hit_fraction=1.0)
    bursts, current, last_t = [], [], None
    for e in many.failures:
        if last_t is not None and e.time - last_t > 120.0:
            bursts.append(current)
            current = []
        current.append(e)
        last_t = e.time
    bursts.append(current)
    for burst in bursts:
        assert len({many.groups.group_of(e.node) for e in burst}) == 1
    # all burst members are SEV1 node losses with a repair
    assert all(e.repair_s is not None for e in many.failures)


def test_degradations_trip_statistical_monitor_margin():
    scen = sc.slow_nodes(n_nodes=N_NODES, span_s=SPAN, seed=11, n_events=16)
    assert len(scen.degradations) == 16
    for ev in scen.degradations:
        assert ev.slowdown >= DEGRADE_MARGIN
        mon = OnlineStatMonitor.primed(30.0)
        assert mon.status(ev.slowdown * 30.0) != "ok"
    # sub-margin slowdowns do NOT trip the monitor
    mon = OnlineStatMonitor.primed(30.0)
    assert mon.status(1.05 * 30.0) == "ok"


def test_preemption_wave_shape():
    scen = sc.preemption_waves(n_nodes=N_NODES, span_s=SPAN, seed=2,
                               n_waves=2, wave_fraction=0.25)
    assert len(scen.failures) == 2 * 4       # 25% of 16 nodes per wave
    assert all(e.repair_s is not None for e in scen.failures)


def test_task_churn_valid_slots():
    tasks, _ = case5_tasks()
    scen = sc.task_churn(span_s=SPAN, seed=4, n_nodes=N_NODES, m_initial=6,
                         candidates=tasks[:3], n_arrivals=2, n_finishes=3)
    finishes = [c for c in scen.churn if isinstance(c, sc.TaskFinish)]
    arrivals = [c for c in scen.churn if isinstance(c, sc.TaskArrival)]
    assert len(finishes) == 3 and len(arrivals) == 2
    slots = [f.slot for f in finishes]
    assert len(set(slots)) == len(slots)
    assert all(0 <= s < 6 for s in slots)
    assert all(a.task in tasks[:3] for a in arrivals)


def test_unicron_drains_slow_nodes_baselines_crawl():
    """§4.1: the statistical monitor turns a slow node into a drain +
    replan; without in-band detection the task crawls at the slow pace."""
    tasks, assignment = case5_tasks()
    scen = sc.slow_nodes(n_nodes=N_NODES, span_s=SPAN, seed=11, n_events=6)
    uni = TraceSimulator(tasks, list(assignment), "unicron").run(scen)
    blind = TraceSimulator(tasks, list(assignment), "unicron",
                           ablate_detection=True).run(scen)
    assert uni.n_degraded_drains > 0
    assert blind.n_degraded_drains == 0
    assert uni.accumulated_waf > blind.accumulated_waf


def test_churn_flows_through_planner():
    tasks, assignment = case5_tasks()
    scen = sc.task_churn(span_s=SPAN, seed=4, n_nodes=N_NODES, m_initial=6,
                         candidates=tasks[:2], n_arrivals=2, n_finishes=2)
    sim = TraceSimulator(tasks, list(assignment), "unicron")
    res = sim.run(scen)
    assert res.n_reconfigs >= 4              # 2 finishes + 2 launches
    finished_slots = [c.slot for c in scen.churn
                      if isinstance(c, sc.TaskFinish)]
    for slot in finished_slots:
        assert not sim.tasks[slot].active
        assert sim.tasks[slot].workers == 0
    assert len(sim.tasks) == 6 + 2           # arrivals appended
    assert sum(t.workers for t in sim.tasks) <= N_NODES * 8
    assert sim.coord.plan_stats.task_finishes == 2
    assert sim.coord.plan_stats.task_launches == 2


@pytest.mark.parametrize("policy", list(EFFICIENCY))
def test_vector_engine_matches_scalar_reference(policy):
    """Accumulated WAF of VectorSimulator (lazy cached planner + numpy
    segment integration) matches the per-event scalar loop to float
    reordering on the full mixed scenario."""
    tasks, assignment = case5_tasks()
    scen = sc.mixed_fleet(n_nodes=N_NODES, span_s=SPAN, seed=5,
                          m_initial=len(tasks), candidates=tasks[:2],
                          mtbf_node_s=20 * DAY, n_degradations=4)
    ref = TraceSimulator(tasks, list(assignment), policy).run(scen)
    got = VectorSimulator(tasks, list(assignment), policy).run(scen)
    assert got.accumulated_waf == pytest.approx(ref.accumulated_waf,
                                                rel=1e-9)
    assert got.n_reconfigs == ref.n_reconfigs
    assert got.n_degraded_drains == ref.n_degraded_drains


def test_monte_carlo_shares_plan_cache():
    tasks, assignment = case5_tasks()
    cache = PlannerCache()

    def make(seed):
        return sc.independent_failures(n_nodes=N_NODES, span_s=SPAN,
                                       seed=seed, mtbf_node_s=30 * DAY)

    out = run_monte_carlo(tasks, assignment, make, seeds=range(3),
                          policies=["unicron", "megatron"],
                          n_nodes=N_NODES, plan_cache=cache,
                          engine="vector")
    assert set(out) == {"unicron", "megatron"}
    assert len(out["unicron"].per_seed) == 3
    stats = cache.stats()
    assert stats["hits"]["tables"] > 0       # cross-seed state reuse
    # per-seed results equal a fresh single run (cache must not leak state)
    solo = VectorSimulator(tasks, list(assignment), "unicron",
                           n_nodes=N_NODES).run(make(1))
    assert solo.accumulated_waf == pytest.approx(
        out["unicron"].per_seed[1], rel=1e-12)
