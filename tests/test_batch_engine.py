"""Batched multi-policy engine (BatchSimulator) and its array-native
models (detection_times / estimate_batch / FleetMonitor) against their
scalar references."""
import numpy as np
import pytest

from benchmarks.common import case5_tasks
from repro.core import scenarios as sc, transition
from repro.core.detection import (ErrorKind, FleetMonitor,
                                  OnlineStatMonitor, detection_time,
                                  detection_times)
from repro.core.planner import PlannerCache
from repro.core.simulator import (EFFICIENCY, BatchSimulator,
                                  TraceSimulator, VectorSimulator,
                                  run_monte_carlo)
from repro.core.traces import DAY, trace_b

N_NODES = 16
SPAN = 7 * DAY
POLICIES = list(EFFICIENCY)


def _mixed(seed):
    tasks, _ = case5_tasks()
    return sc.mixed_fleet(n_nodes=N_NODES, span_s=SPAN, seed=seed,
                          m_initial=len(tasks), candidates=tasks[:2],
                          mtbf_node_s=20 * DAY, n_degradations=4)


# ---------------------------------------------------------------------------
# engine equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 3, 5])
def test_batched_matches_scalar_reference_per_policy(seed):
    """One BatchSimulator pass reproduces every policy's TraceSimulator
    run on a seeded mixed_fleet trace: accumulated WAF to float
    reordering, decision counters and downtime exactly."""
    tasks, assignment = case5_tasks()
    scen = _mixed(seed)
    bat = BatchSimulator(tasks, list(assignment), POLICIES).run(scen)
    assert set(bat) == set(POLICIES)
    for policy in POLICIES:
        ref = TraceSimulator(tasks, list(assignment), policy).run(scen)
        got = bat[policy]
        assert got.accumulated_waf == pytest.approx(ref.accumulated_waf,
                                                    rel=1e-9), policy
        assert got.n_reconfigs == ref.n_reconfigs, policy
        assert got.downtime_s == ref.downtime_s, policy
        assert got.n_events == ref.n_events, policy
        assert got.n_degraded_drains == ref.n_degraded_drains, policy


def test_batched_matches_scalar_on_plain_traces():
    """Plain failure traces (the original Fig. 11 inputs) work too."""
    tasks, assignment = case5_tasks()
    trace = trace_b()
    bat = BatchSimulator(tasks, list(assignment), POLICIES).run(trace)
    for policy in POLICIES:
        ref = TraceSimulator(tasks, list(assignment), policy).run(trace)
        assert bat[policy].accumulated_waf == pytest.approx(
            ref.accumulated_waf, rel=1e-9), policy


def test_finished_task_ghost_workers_produce_no_waf():
    """Regression (found by batched-vs-scalar comparison): a baseline
    rejoin may hand idle workers back to a task that already finished;
    the scalar loop never counts them, and neither may the vectorized
    integrations.  Seed 3 exercises exactly that interleaving."""
    tasks, assignment = case5_tasks()
    scen = _mixed(3)
    for policy in ("oobleck", "megatron"):
        ref = TraceSimulator(tasks, list(assignment), policy).run(scen)
        vec = VectorSimulator(tasks, list(assignment), policy).run(scen)
        assert vec.accumulated_waf == pytest.approx(ref.accumulated_waf,
                                                    rel=1e-9), policy


def test_run_monte_carlo_batched_default_matches_vector():
    tasks, assignment = case5_tasks()

    def make(seed):
        return sc.independent_failures(n_nodes=N_NODES, span_s=SPAN,
                                       seed=seed, mtbf_node_s=30 * DAY)

    got = run_monte_carlo(tasks, assignment, make, seeds=range(3),
                          n_nodes=N_NODES)           # engine="batched"
    want = run_monte_carlo(tasks, assignment, make, seeds=range(3),
                           n_nodes=N_NODES, engine="vector")
    assert set(got) == set(want) == set(POLICIES)
    for policy in POLICIES:
        assert got[policy].per_seed == pytest.approx(
            want[policy].per_seed, rel=1e-9)
        assert got[policy].n_reconfigs == want[policy].n_reconfigs
    # suite wall is attributed as an even per-policy share
    walls = {got[p].wall_s for p in POLICIES}
    assert len(walls) == 1


def test_run_monte_carlo_batched_shares_plan_cache():
    tasks, assignment = case5_tasks()
    cache = PlannerCache()

    def make(seed):
        return sc.independent_failures(n_nodes=N_NODES, span_s=SPAN,
                                       seed=seed, mtbf_node_s=30 * DAY)

    out = run_monte_carlo(tasks, assignment, make, seeds=range(3),
                          policies=["unicron", "megatron"],
                          n_nodes=N_NODES, plan_cache=cache)
    assert len(out["unicron"].per_seed) == 3
    assert cache.stats()["hits"]["tables"] > 0   # cross-seed state reuse
    solo = VectorSimulator(tasks, list(assignment), "unicron",
                           n_nodes=N_NODES).run(make(1))
    assert solo.accumulated_waf == pytest.approx(
        out["unicron"].per_seed[1], rel=1e-9)


def test_run_monte_carlo_rejects_unknown_engine():
    tasks, assignment = case5_tasks()
    with pytest.raises(ValueError, match="engine"):
        run_monte_carlo(tasks, assignment, lambda s: _mixed(s),
                        seeds=range(1), engine="warp")


def test_same_task_readmitted_with_different_iteration_times():
    """Regression: the same Task object admitted twice with different
    ``avg_iter_s`` hints must not share one memoized transition cost —
    statistical detection and recompute both scale with the slot's
    iteration time."""
    tasks, assignment = case5_tasks()
    twin = tasks[0]
    churn = [sc.TaskArrival(time=1000.0, task=twin, workers_hint=16,
                            avg_iter_s=30.0),
             sc.TaskArrival(time=2000.0, task=twin, workers_hint=16,
                            avg_iter_s=120.0)]
    fails = [sc.FailureEvent(time=3000.0 + 50.0 * nd, node=nd,
                             kind=ErrorKind.TASK_HANG, repair_s=None)
             for nd in range(N_NODES)]
    scen = sc.ClusterScenario("readmit", N_NODES, 8, SPAN,
                              failures=fails, churn=churn)
    bat = BatchSimulator(tasks, list(assignment), POLICIES).run(scen)
    for policy in POLICIES:
        ref = TraceSimulator(tasks, list(assignment), policy).run(scen)
        got = bat[policy]
        assert got.downtime_s == ref.downtime_s, policy
        assert got.accumulated_waf == pytest.approx(ref.accumulated_waf,
                                                    rel=1e-9), policy


def test_batched_policy_subsets():
    """Any policy subset runs and agrees with the full stacked pass."""
    tasks, assignment = case5_tasks()
    scen = _mixed(5)
    full = BatchSimulator(tasks, list(assignment), POLICIES).run(scen)
    sub = BatchSimulator(tasks, list(assignment),
                         ["megatron", "bamboo"]).run(scen)
    for policy in ("megatron", "bamboo"):
        assert sub[policy].accumulated_waf == pytest.approx(
            full[policy].accumulated_waf, rel=1e-12)


# ---------------------------------------------------------------------------
# array-native detection model
# ---------------------------------------------------------------------------


def test_detection_times_matches_scalar_lookup():
    """Every (kind, policy) cell equals the scalar detection_time."""
    kinds = list(ErrorKind)
    uni = np.array([True, False, True, False])
    M = detection_times(kinds, 30.0, uni)
    assert M.shape == (len(kinds), 4)
    for i, kind in enumerate(kinds):
        for j, u in enumerate(uni):
            assert M[i, j] == detection_time(kind, 30.0, unicron=bool(u))


def test_detection_times_per_cell_iteration_times():
    """avg_iter_s broadcasts per cell: statistical kinds scale with the
    owner task's iteration time, fixed-latency methods do not."""
    kinds = [ErrorKind.TASK_HANG, ErrorKind.LOST_CONNECTION]
    uni = np.array([True, True])
    avg = np.array([[10.0, 40.0], [10.0, 40.0]])
    M = detection_times(kinds, avg, uni)
    assert M[0, 0] == detection_time(ErrorKind.TASK_HANG, 10.0)
    assert M[0, 1] == detection_time(ErrorKind.TASK_HANG, 40.0)
    assert M[1, 0] == M[1, 1] == detection_time(
        ErrorKind.LOST_CONNECTION, 40.0)


# ---------------------------------------------------------------------------
# array-native transition model
# ---------------------------------------------------------------------------


def test_estimate_batch_matches_scalar_estimates():
    policies = POLICIES
    sb, avg, det = 16e9, 30.0, 5.6
    for dp in (1, 2, 8):
        costs = transition.estimate_batch(policies, sb, avg, dp, det)
        assert costs.shape == (len(policies), len(transition.COMPONENTS))
        totals = transition.batch_total(costs)
        for j, p in enumerate(policies):
            if p == "unicron":
                ref = transition.estimate_unicron(sb, avg, dp_degree=dp,
                                                  detect_s=det)
            elif p in transition.FFTRAINER_POLICIES:
                ref = transition.estimate_fftrainer(sb, avg, detect_s=det)
            elif p in transition.HIERARCHICAL_POLICIES:
                ref = transition.estimate_hierarchical(sb, avg,
                                                       detect_s=det)
            elif p in transition.REDUNDANT_POLICIES:
                ref = transition.estimate_redundant()
            elif p in transition.CKPT_RESTART_POLICIES:
                ref = transition.estimate_baseline(
                    sb, det, dynamic_reconfig=False, ckpt_restart=True)
            else:
                ref = transition.estimate_baseline(
                    sb, det, dynamic_reconfig=True, ckpt_restart=False)
            want = [ref.detect_s, ref.plan_s, ref.respawn_s,
                    ref.migrate_s, ref.recompute_s]
            assert list(costs[j]) == want, p
            assert totals[j] == ref.total, p


def test_estimate_batch_per_policy_vectors():
    """Per-policy owner state (sizes, iteration times, DP degrees,
    detection latencies) lands in the right rows."""
    policies = ["unicron", "megatron"]
    costs = transition.estimate_batch(
        policies, np.array([16e9, 32e9]), np.array([30.0, 60.0]),
        np.array([4, 1]), np.array([5.6, 1800.0]))
    uni = transition.estimate_unicron(16e9, 30.0, dp_degree=4,
                                      detect_s=5.6)
    meg = transition.estimate_baseline(32e9, 1800.0,
                                       dynamic_reconfig=False,
                                       ckpt_restart=True)
    assert transition.batch_total(costs)[0] == uni.total
    assert transition.batch_total(costs)[1] == meg.total


def test_estimate_batch_lookup_miss_and_sources():
    c_hit = transition.estimate_batch(["unicron"], 1e9, 30.0, 1, 5.6)
    c_miss = transition.estimate_batch(["unicron"], 1e9, 30.0, 1, 5.6,
                                       lookup_hit=False)
    assert c_hit[0, 1] == transition.PLAN_LOOKUP_S
    assert c_miss[0, 1] == transition.PLAN_SOLVE_S
    # dp=1 without in-memory checkpoint falls back to the persistent tier
    c_pers = transition.estimate_batch(["unicron"], 1e9, 30.0, 1, 5.6,
                                       inmemory_available=False)
    assert c_pers[0, 3] == 1e9 / transition.BW_PERSISTENT


def test_estimate_batch_rejects_unknown_policy():
    with pytest.raises(ValueError, match="unknown recovery policies"):
        transition.estimate_batch(["unicron", "k8s"], 1e9, 30.0, 1, 5.6)


# ---------------------------------------------------------------------------
# fleet monitor ring buffer
# ---------------------------------------------------------------------------


def test_fleet_monitor_primed_matches_scalar_monitor():
    fm = FleetMonitor.primed([30.0, 10.0])
    for i, avg in enumerate((30.0, 10.0)):
        om = OnlineStatMonitor.primed(avg)
        assert fm.averages()[i] == om.average
        for waited in (avg, 1.05 * avg, 1.2 * avg, 4.0 * avg):
            want = {"ok": 0, "degraded": 1, "failed": 2}[om.status(waited)]
            assert int(fm.statuses([i], waited)[0]) == want


def test_fleet_monitor_rolling_window_matches_scalar():
    fm = FleetMonitor(1, window=4)
    om = OnlineStatMonitor(window=4)
    for x in (10.0, 12.0, 8.0, 30.0, 6.0, 7.0):     # wraps the ring
        fm.observe([0], x)
        om.observe(x)
        assert fm.averages()[0] == pytest.approx(om.average, rel=1e-12)
    assert int(fm.statuses([0], 100.0)[0]) == 2      # > 3x average


def test_fleet_monitor_empty_history_is_ok():
    fm = FleetMonitor(2)
    assert np.isnan(fm.averages()).all()
    assert list(fm.statuses([0, 1], 1e9)) == [0, 0]  # no history: ok


def test_fleet_monitor_grow_admits_primed_task():
    fm = FleetMonitor.primed([30.0])
    slot = fm.grow(12.0)
    assert slot == 1 and fm.n_tasks == 2
    assert fm.averages()[1] == OnlineStatMonitor.primed(12.0).average
    assert int(fm.statuses([1], 12.0 * 1.2)[0]) == 1


def test_fleet_monitor_vectorized_observe_scatter():
    fm = FleetMonitor.primed([10.0, 10.0, 10.0])
    fm.observe([0, 2], [20.0, 40.0])
    om0 = OnlineStatMonitor.primed(10.0)
    om0.observe(20.0)
    assert fm.averages()[0] == pytest.approx(om0.average, rel=1e-12)
    assert fm.averages()[1] == 10.0                  # untouched row
