"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests and benches must see
the single real CPU device (the 512-device override is dryrun.py-only)."""
import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
