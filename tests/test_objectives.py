"""Objective-protocol + engine-registry tests (the api_redesign PR).

Covers the three API seams the redesign touched:

* the engine registry: one canonical ``engine=`` axis, with the
  historical spellings (``solver=``, ``incremental=False``) resolving
  through ``planner.resolve_engine`` to the same place;
* the ``Task``/``Objective`` contract: ``max_workers`` is a real
  attribute (no duck-probing), ``TrainingWAF`` is bit-identical to the
  pre-protocol reward, ``ServingSLO`` obeys the curve/value and band
  contracts;
* mixed-objective fleets: all PlanTable engines and both fresh solvers
  agree on plans, and all three simulator engines agree on accumulated
  WAF under objective-swapping rate events.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import costmodel, planner, scenarios, waf as waf_mod
from repro.core.coordinator import UnicronCoordinator
from repro.core.costmodel import A800, TaskModel
from repro.core.planner import PlanInput, PlannerCache, PlanTable
from repro.core.simulator import (BatchSimulator, TraceSimulator,
                                  VectorSimulator)
from repro.core.waf import TRAINING_WAF, ServingSLO, Task, TrainingWAF

D_RUN, D_TRANS = 7200.0, 120.0


def _tm(name, p=1.3e9, layers=24, d=2048):
    return TaskModel(name=name, n_params=p, n_layers=layers, d_model=d)


def _mixed_tasks():
    train = [Task(model=_tm("t0"), weight=1.0),
             Task(model=_tm("t1", 2.7e9, 32, 2560), weight=2.0)]
    serve = [Task(model=_tm("s0"), weight=5e13, max_workers=24,
                  objective=ServingSLO(rate_rps=100.0)),
             Task(model=_tm("s1"), weight=8e13, max_workers=32,
                  objective=ServingSLO(rate_rps=160.0,
                                       capacity_rps=10.0))]
    return train + serve


# ---------------------------------------------------------------------------
# engine registry (satellite: one axis, four spellings)
# ---------------------------------------------------------------------------


def test_engine_registry_lists_both_axes():
    reg = planner.engines()
    assert set(reg) == {"engine", "backend"}
    assert set(reg["engine"]) == set(planner.ENGINES) \
        == {"batched", "fused", "segtree", "chain", "reference"}
    assert "numpy" in reg["backend"] and "pallas" in reg["backend"]


def test_resolve_engine_shims():
    """Historical kwargs resolve onto the canonical axis."""
    assert planner.resolve_engine() == "batched"
    assert planner.resolve_engine("chain") == "chain"
    assert planner.resolve_engine(None, incremental=False) == "reference"
    assert planner.resolve_engine(
        None, solver=planner.solve_reference) == "reference"
    with pytest.raises(ValueError):
        planner.resolve_engine("segment-tree")


def test_old_kwargs_build_same_plans():
    """``incremental=False`` / ``solver=`` (deprecated spellings) produce
    the same plans as the canonical ``engine=`` names."""
    tasks = _mixed_tasks()
    assignment = [32, 40, 16, 24]
    kw = dict(d_running=D_RUN, d_transition=D_TRANS, workers_per_fault=8)
    canonical = PlanTable(tasks, assignment, A800, engine="batched", **kw)
    legacy_ref = PlanTable(tasks, assignment, A800, incremental=False, **kw)
    explicit_ref = PlanTable(tasks, assignment, A800, engine="reference",
                             solver=planner.solve_reference, **kw)
    for key in ["join:1", "finish:0"] + \
            [f"fault:{i}" for i in range(len(tasks))]:
        want = canonical.lookup(key)
        for table in (legacy_ref, explicit_ref):
            got = table.lookup(key)
            assert got.assignment == want.assignment, key
            assert got.total_reward == pytest.approx(want.total_reward,
                                                     rel=1e-6), key


def test_planner_cache_normalizes_engine():
    """The cache memo key uses the canonical engine name, so the default
    spelling and the explicit one share a table."""
    cache = PlannerCache()
    tasks = _mixed_tasks()
    assignment = [32, 40, 16, 24]
    t1 = cache.table(tasks, assignment, A800, D_RUN, D_TRANS)
    t2 = cache.table(tasks, assignment, A800, D_RUN, D_TRANS,
                     engine="batched")
    assert t1 is t2


# ---------------------------------------------------------------------------
# Task/Objective contract (satellite: duck probe removed)
# ---------------------------------------------------------------------------


def test_max_workers_is_part_of_the_contract():
    """``waf.waf`` reads ``task.max_workers`` directly: a duck-typed task
    without the attribute is a contract violation, not a silent
    uncapped task."""
    class NoCap:
        model = _tm("duck")
        weight = 1.0
        min_workers = None

        def necessary(self, hw):
            return 1

    with pytest.raises(AttributeError):
        waf_mod.waf(NoCap(), 8, A800)


def test_training_waf_is_bit_identical_to_legacy_reward():
    """The default objective reproduces the pre-protocol semantics
    exactly: weight * achieved FLOP/s, floor/cap owned by ``waf()``."""
    t = Task(model=_tm("t"), weight=1.7, max_workers=16)
    assert t.objective == TRAINING_WAF == TrainingWAF()
    n = 32
    curve = waf_mod.waf_curve(t, n, A800)
    for x in range(n + 1):
        assert curve[x] == waf_mod.waf(t, x, A800)
    legacy = t.weight * costmodel.achieved_flops(t.model, 12, A800)
    assert waf_mod.waf(t, 12, A800) == legacy
    assert (curve[16:] == curve[16]).all()        # cap: flat tail
    assert waf_mod.state_bytes(t) == 16.0 * t.model.n_params


def test_serving_slo_objective_contract():
    slo = ServingSLO(rate_rps=100.0, capacity_rps=8.0)
    t = Task(model=_tm("s"), weight=2.0, max_workers=40, objective=slo)
    n = 64
    curve = waf_mod.waf_curve(t, n, A800)
    # curve/value elementwise identity (scalar path == vector path)
    for x in (0, 1, 7, 13, 40, 64):
        assert curve[x] == waf_mod.waf(t, x, A800)
    # monotone, saturating toward rate * weight, flat past the cap
    assert (np.diff(curve) >= -1e-12).all()
    assert curve[-1] <= t.weight * slo.rate_rps + 1e-9
    assert (curve[41:] == curve[40]).all()
    # overloaded widths (capacity below the offered rate, rho > 1 with
    # the SLO tail fully missed) serve nothing
    assert curve[0] == 0.0
    # fp16 weights only — far lighter to move than a training task
    assert waf_mod.state_bytes(t) == 2.0 * t.model.n_params
    assert t.necessary(A800) == 1
    assert slo.with_rate(250.0) == dataclasses.replace(slo,
                                                       rate_rps=250.0)


def test_min_workers_overrides_objective_necessary():
    slo = ServingSLO(rate_rps=100.0)
    t = Task(model=_tm("s"), min_workers=4, max_workers=40, objective=slo)
    assert t.necessary(A800) == 4
    assert waf_mod.waf(t, 3, A800) == 0.0
    # above the floor AND above the overload knee (4 workers would clear
    # the floor but serve nothing: 32 rps capacity vs 100 rps offered)
    assert waf_mod.waf(t, 16, A800) > 0.0


# ---------------------------------------------------------------------------
# mixed-objective fleets: planner engine equivalence (satellite 3)
# ---------------------------------------------------------------------------


def test_mixed_fleet_plan_engines_agree():
    tasks = _mixed_tasks()
    assignment = [32, 40, 16, 24]
    kw = dict(d_running=D_RUN, d_transition=D_TRANS, workers_per_fault=8)
    tables = {eng: PlanTable(tasks, assignment, A800, engine=eng, **kw)
              for eng in ("batched", "segtree", "chain", "reference")}
    keys = [f"fault:{i}" for i in range(len(tasks))] + \
        ["join:1", "finish:0", "finish:3"]
    for key in keys:
        plans = {eng: t.lookup(key) for eng, t in tables.items()}
        want = plans["batched"]
        for eng, got in plans.items():
            assert got.assignment == want.assignment, (key, eng)
            assert got.total_reward == pytest.approx(
                want.total_reward, rel=1e-6), (key, eng)


def test_mixed_fleet_solvers_agree():
    tasks = tuple(_mixed_tasks())
    inp = PlanInput(tasks, (32, 40, 16, 24), 104, D_RUN, D_TRANS,
                    (True, False, False, False))
    a = planner.solve(inp, A800)
    b = planner.solve_fast(inp, A800)
    c = planner.solve_reference(inp, A800)
    assert a.assignment == b.assignment == c.assignment
    assert a.total_reward == pytest.approx(c.total_reward, rel=1e-6)
    # the serving tasks never exceed their caps
    for t, x in zip(tasks, a.assignment):
        if t.max_workers is not None:
            assert x <= t.max_workers


# ---------------------------------------------------------------------------
# mixed-objective fleets: simulator engine equivalence + rate events
# ---------------------------------------------------------------------------


def _rate_trace(n_nodes, span, slo):
    base = scenarios.independent_failures(
        n_nodes=n_nodes, span_s=span, seed=5, gpus_per_node=8,
        mtbf_node_s=10 * scenarios.DAY)
    di = scenarios.diurnal_load(n_nodes=n_nodes, span_s=span, seed=2,
                                slot=2, base=slo, step_s=6 * 3600.0)
    spk = scenarios.traffic_spikes(n_nodes=n_nodes, span_s=span, seed=4,
                                   slot=2, base=slo)
    return base.merged(di).merged(spk)


@pytest.mark.parametrize("policy", ["unicron", "megatron"])
def test_simulator_engines_agree_on_rate_events(policy):
    slo = ServingSLO(rate_rps=100.0)
    tasks = [Task(model=_tm("t0")), Task(model=_tm("t1"), weight=2.0),
             Task(model=_tm("s0"), weight=5e13, max_workers=32,
                  objective=slo)]
    assignment = [40, 48, 24]
    n_nodes, span = 16, 2 * scenarios.DAY
    trace = _rate_trace(n_nodes, span, slo)
    assert any(isinstance(c, scenarios.RateChangeEvent)
               for c in trace.churn)

    ref = TraceSimulator(tasks, list(assignment), policy,
                         n_nodes=n_nodes).run(trace)
    vec = VectorSimulator(tasks, list(assignment), policy,
                          n_nodes=n_nodes).run(trace)
    bat = BatchSimulator(tasks, list(assignment), [policy],
                         n_nodes=n_nodes).run(trace)[policy]
    for got in (vec, bat):
        rel = abs(ref.accumulated_waf - got.accumulated_waf) \
            / max(abs(ref.accumulated_waf), 1.0)
        assert rel < 1e-6, (policy, rel)
        assert got.n_reconfigs == ref.n_reconfigs


def test_rate_event_updates_coordinator_tasks():
    """A rate step swaps the slot's objective in the simulator AND in the
    coordinator's entries, so the next replan sees the new rate; workers
    do not move on the rate event itself."""
    slo = ServingSLO(rate_rps=100.0)
    tasks = [Task(model=_tm("t0")), Task(model=_tm("s0"), weight=5e13,
                                         max_workers=32, objective=slo)]
    sim = TraceSimulator(tasks, [40, 24], "unicron", n_nodes=16)
    new = slo.with_rate(240.0)
    trace = scenarios.ClusterScenario(
        "one_step", 16, 8, 3600.0,
        churn=[scenarios.RateChangeEvent(time=600.0, slot=1,
                                         objective=new)])
    before = [st.workers for st in sim.tasks]
    sim.run(trace)
    assert [st.workers for st in sim.tasks] == before
    assert sim.tasks[1].task.objective == new
    assert sim.coord.entries[1].task.objective == new
    assert len(sim._rate_log) == 1


def test_coordinator_task_updated_survives_recovery():
    """``task_updated`` journals the swapped task: a recovered
    coordinator plans against the updated objective."""
    tasks = [Task(model=_tm("t0")),
             Task(model=_tm("s0"), weight=5e13, max_workers=32,
                  objective=ServingSLO(rate_rps=100.0))]
    coord = UnicronCoordinator(tasks, [40, 24], A800,
                               n_cluster_workers=128)
    updated = dataclasses.replace(
        tasks[1], objective=ServingSLO(rate_rps=240.0))
    coord.task_updated(1, updated)
    assert coord.entries[1].task == updated
    successor = UnicronCoordinator.recover(coord.kv, A800,
                                           n_cluster_workers=128)
    assert successor.entries[1].task == updated
    assert successor.entries[1].state_bytes == \
        waf_mod.state_bytes(updated)
