"""Chaos-hardened control plane: convergence under seeded fault
injection, at-least-once delivery, idempotent consumption, coordinator
crash-recovery, and the KV residency/lease fixes.

The load-bearing property (ISSUE 6): for any seeded chaos schedule with
finite partitions — message drop, delayed visibility, duplication,
reordering, per-node partitions, coordinator crashes — the quiesced
cluster assignment and WAF equal the chaos-free run's within 1e-6.
"""
import os

import pytest

from repro.configs import get_arch
from repro.core.agent import UnicronAgent
from repro.core.chaos import (ChaosHarness, ChaosKVStore, ChaosSchedule,
                              demo_world, world_windows)
from repro.core.cluster import Cluster
from repro.core.controlloop import ControlLoop
from repro.core.coordinator import (INCARNATION_KEY, StaleCoordinatorError,
                                    UnicronCoordinator)
from repro.core.costmodel import A800, TaskModel
from repro.core.detection import ErrorKind
from repro.core.kvstore import CONSUMED_PREFIX, KVStore
from repro.core.scenarios import chaos_schedule, chaos_suite
from repro.core.waf import Task

SPAN = 2600.0           # long enough for partitions to place after the
                        # world script's avoid windows (guarded gaps)


def _task(size: str, weight: float) -> Task:
    return Task(model=TaskModel.from_arch(get_arch(size), global_batch=128),
                weight=weight)


def _fleet():
    tasks = [_task("gpt3-1.3b", 2.0), _task("gpt3-7b", 1.4),
             _task("gpt3-1.3b", 1.0)]
    return tasks, [8, 8, 4], _task("gpt3-1.3b", 0.7)


def _harness(schedule=None, seed=0):
    tasks, assignment, launch = _fleet()
    world = demo_world(tasks[2], launch)
    h = ChaosHarness(tasks=tasks, assignment=assignment, hw=A800,
                     schedule=schedule, seed=seed)
    return h, world


@pytest.fixture(scope="module")
def baseline():
    """The chaos-free reference run every chaos run must converge to."""
    h, world = _harness()
    res = h.run(world, until=SPAN)
    return res, world_windows(world)


def _assert_converged(res, free):
    assert res.assignment == free.assignment
    assert abs(res.waf - free.waf) < 1e-6
    assert res.healthy_workers == free.healthy_workers


# ---- satellite: KVStore.cas lease preservation ----------------------------


def test_cas_preserves_lease():
    kv = KVStore()
    kv.put("/nodes/3/alive", 10.0, ttl=6.0, now=10.0)
    assert kv.cas("/nodes/3/alive", 10.0, 11.0)
    assert kv.get("/nodes/3/alive") == 11.0
    # the lease must survive the swap: the key still expires on schedule
    assert kv.expire(15.9) == []
    assert kv.expire(16.0) == ["/nodes/3/alive"]


def test_cas_on_missing_key():
    kv = KVStore()
    assert not kv.cas("/x", 1, 2)
    assert kv.cas("/x", None, 2)        # expected-absent insert
    assert kv.get("/x") == 2


# ---- tentpole: convergence under the full chaos suite ---------------------


def test_convergence_suite(baseline):
    """Every chaos class — drop, delay+dup (reordering), partitions,
    coordinator crash, and all combined — quiesces to the chaos-free
    assignment and WAF."""
    free, windows = baseline
    suite = chaos_suite(seed=3, span_s=SPAN, n_nodes=6, avoid=windows)
    assert len(suite["partition"].partitions) > 0
    assert len(suite["full"].crash_times) > 0
    for name, sched in suite.items():
        h, world = _harness(schedule=sched, seed=7)
        # chaos parity for the sharded control plane: ChaosKVStore wraps
        # the sharded store unchanged, liveness is array-native, and the
        # loop drains from cursor queues — same convergence contract
        assert isinstance(h.kv, ChaosKVStore)
        assert isinstance(h.kv, KVStore)
        assert h.loop._queued
        res = h.run(world, until=max(SPAN, sched.horizon() + 120.0))
        assert len(h.kv._heartbeats) > 0, name
        assert h.quiesced(), name
        _assert_converged(res, free)
        if name in ("crash", "full"):
            assert res.n_crashes >= 1
        if name == "partition":
            assert res.chaos_stats["rejected"] > 0
        if name == "drop":
            assert res.chaos_stats["dropped"] > 0


def test_hypothesis_convergence(baseline):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    free, windows = baseline

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 2**16),
           drop_p=st.floats(0.0, 0.4),
           dup_p=st.floats(0.0, 0.3),
           n_crashes=st.integers(0, 2))
    def prop(seed, drop_p, dup_p, n_crashes):
        sched = chaos_schedule(seed=seed, span_s=SPAN, n_nodes=6,
                               drop_p=drop_p, dup_p=dup_p,
                               n_crashes=n_crashes, avoid=windows)
        h, world = _harness(schedule=sched, seed=seed % 97)
        res = h.run(world, until=max(SPAN, sched.horizon() + 120.0))
        assert h.quiesced()
        _assert_converged(res, free)
        assert res.n_crashes == n_crashes

    prop()


@pytest.mark.skipif(not os.environ.get("REPRO_CHAOS_SOAK"),
                    reason="set REPRO_CHAOS_SOAK=1 for the soak sweep")
def test_chaos_soak(baseline):
    """CI soak leg: several suite seeds back to back."""
    free, windows = baseline
    for seed in (11, 23, 47):
        for name, sched in chaos_suite(seed=seed, span_s=SPAN, n_nodes=6,
                                       avoid=windows).items():
            h, world = _harness(schedule=sched, seed=seed)
            res = h.run(world, until=max(SPAN, sched.horizon() + 120.0))
            assert h.quiesced(), (seed, name)
            _assert_converged(res, free)


# ---- at-least-once publish / idempotent consume ---------------------------


def test_outbox_republishes_until_acked():
    """A dropped report is re-published with backoff until the control
    loop's processed marker acks it."""
    sched = ChaosSchedule(seed=1, drop_p=1.0, end_s=10.0)
    kv = ChaosKVStore(sched)
    agent = UnicronAgent(2, kv.bind(2), n_gpus=4, seed=5)
    agent.report(ErrorKind.CUDA_ERROR, now=0.0)
    assert agent.outbox_size == 1
    assert kv.prefix("/errors/") == {}           # dropped
    t = 0.0
    while not kv.prefix("/errors/") and t < 60.0:
        t += 1.0
        agent.flush_outbox(t)                    # injection ends at 10s
    assert kv.prefix("/errors/"), "report never got through"
    key = next(iter(kv.prefix("/errors/")))
    kv.delete(key)
    kv.put(CONSUMED_PREFIX + key, t)             # the loop's ack
    agent.flush_outbox(t + 20.0)
    assert agent.outbox_size == 0                # retired


def test_outbox_queues_through_partition():
    sched = ChaosSchedule(seed=1, partitions=((2, 0.0, 30.0),), end_s=0.0)
    kv = ChaosKVStore(sched)
    agent = UnicronAgent(2, kv.bind(2), n_gpus=4, seed=5)
    agent.heartbeat(5.0)                         # swallowed, no raise
    agent.report(ErrorKind.ECC_ERROR, now=5.0)
    assert agent.outbox_size == 1 and not kv.prefix("/errors/")
    for t in (12.0, 20.0, 28.0, 36.0, 44.0):     # heal at 30s
        kv.advance(t)          # the control loop's tick pumps the clock
        agent.flush_outbox(t)
    assert kv.prefix("/errors/")                 # flushed on heal


def test_restarted_loop_never_double_fires():
    """Consumption state lives in the KV: a fresh ControlLoop (post-crash)
    sees the processed markers and treats re-delivered records as dups."""
    tasks, assignment, _ = _fleet()
    kv = KVStore()
    coord = UnicronCoordinator(list(tasks), list(assignment), A800, kv=kv,
                               n_cluster_workers=24, workers_per_node=4)
    cluster = Cluster(6, 4)
    cluster.assign(list(assignment))
    agents = {i: UnicronAgent(i, kv, n_gpus=4) for i in range(6)}
    loop = ControlLoop(coord, cluster, agents)
    for a in agents.values():
        a.heartbeat(0.0)
    rec = agents[1].report(ErrorKind.ECC_ERROR, 0.0)           # SEV1
    [key] = [k for k in kv.prefix("/errors/")]
    t1 = rec["visible_at"] + 1.0
    for a in agents.values():
        a.heartbeat(t1)                          # keep leases alive
    loop.tick(t1)
    assert kv.prefix("/errors/") == {}           # delete-on-consume
    assert cluster.healthy_workers() == 24 - 4   # node 1 drained
    # coordinator + loop crash; successor inherits the markers
    coord2 = UnicronCoordinator.recover(kv, A800, n_cluster_workers=24,
                                        workers_per_node=4)
    loop2 = ControlLoop(coord2, cluster, agents)
    kv.put(key, rec, now=200.0)                  # late duplicate delivery
    for a in agents.values():
        a.heartbeat(200.0)
    evs = loop2.tick(200.0)
    assert evs == []                             # marker: dup is a no-op
    assert kv.prefix("/errors/") == {}
    assert (coord2.plan_stats.fresh_solves
            + coord2.plan_stats.lookup_hits) == 0
    assert cluster.healthy_workers() == 24 - 4   # still exactly one drain


# ---- satellite: bounded KV residency over a long trace --------------------


def test_bounded_residency_long_trace():
    """30-day-scale report stream: consumed records are deleted and
    markers are GC'd, so KV residency stays O(retention window), not
    O(trace length).  (The old ``_seen`` set grew forever.)"""
    tasks, assignment, _ = _fleet()
    kv = KVStore()
    coord = UnicronCoordinator(list(tasks), list(assignment), A800, kv=kv,
                               n_cluster_workers=24, workers_per_node=4)
    cluster = Cluster(6, 4)
    cluster.assign(list(assignment))
    agents = {i: UnicronAgent(i, kv, n_gpus=4) for i in range(6)}
    loop = ControlLoop(coord, cluster, agents, marker_retention_s=600.0)
    assert not hasattr(loop, "_seen")
    # no heartbeats: this exercises the report stream in isolation (the
    # coarse 50s cadence would otherwise churn leases every tick)
    for i in range(400):
        t = 50.0 * i
        agents[i % 6].report(ErrorKind.NCCL_TIMEOUT, t)     # SEV3: benign
        loop.tick(t + 40.0)
    loop.tick(20200.0)                           # settle the tail report
    assert kv.prefix("/errors/") == {}
    n_markers = len(kv.prefix(CONSUMED_PREFIX))
    assert n_markers <= 600.0 / 50.0 + 2         # retention window only
    assert len(loop.events) == 400               # every report fired once


# ---- coordinator crash-recovery + incarnation fencing ---------------------


def test_recover_rebuilds_state():
    tasks, assignment, launch = _fleet()
    kv = KVStore()
    coord = UnicronCoordinator(list(tasks), list(assignment), A800, kv=kv,
                               n_cluster_workers=24, workers_per_node=4)
    coord.task_launched(launch, 20, avg_iter_s=12.0)
    coord.on_error("9:cuda:1", ErrorKind.CUDA_ERROR)    # left open: crash
    back = UnicronCoordinator.recover(kv, A800, n_cluster_workers=24,
                                      workers_per_node=4)
    assert [e.task for e in back.entries] == [e.task for e in coord.entries]
    assert ([e.n_workers for e in back.entries]
            == [e.n_workers for e in coord.entries])
    assert [e.avg_iter_s for e in back.entries] \
        == [e.avg_iter_s for e in coord.entries]
    assert back.plan_epoch == coord.plan_epoch
    assert set(back.open_cases) == {"9:cuda:1"}
    case = back.open_cases["9:cuda:1"]
    assert case.kind is ErrorKind.CUDA_ERROR
    # the successor plans identically: same table scenario keys and the
    # same fresh plan for the same input
    p1 = coord._fresh_plan(20)
    p2 = back._fresh_plan(20)
    assert p1.assignment == p2.assignment


def test_incarnation_fence_rejects_deposed():
    tasks, assignment, launch = _fleet()
    kv = KVStore()
    old = UnicronCoordinator(list(tasks), list(assignment), A800, kv=kv,
                             n_cluster_workers=24, workers_per_node=4)
    new = UnicronCoordinator.recover(kv, A800, n_cluster_workers=24,
                                     workers_per_node=4)
    assert new.incarnation == old.incarnation + 1
    assert kv.get(INCARNATION_KEY) == new.incarnation
    with pytest.raises(StaleCoordinatorError):
        old.task_launched(launch, 20)            # journaling write fences
    new.task_launched(launch, 20)                # successor unaffected


def test_recover_without_journal_raises():
    with pytest.raises(RuntimeError):
        UnicronCoordinator.recover(KVStore(), A800)


# ---- false-positive drain -> exact restore --------------------------------


def test_reappearance_restores_exact_assignment():
    """A partition-induced drain (heartbeats lost, node healthy) must be
    rolled back to the exact pre-drain assignment when the node
    reappears — replanning would stick elsewhere (reward hysteresis)."""
    tasks, assignment, _ = _fleet()
    kv = KVStore()
    coord = UnicronCoordinator(list(tasks), list(assignment), A800, kv=kv,
                               n_cluster_workers=24, workers_per_node=4)
    cluster = Cluster(6, 4)
    cluster.assign(list(assignment))
    agents = {i: UnicronAgent(i, kv, n_gpus=4) for i in range(6)}
    loop = ControlLoop(coord, cluster, agents)
    pre = [e.n_workers for e in coord.entries]
    for t in (0.0, 2.0, 4.0):
        for a in agents.values():
            a.heartbeat(t)
        loop.tick(t)
    # node 3 goes silent (partition): lease expires -> SEV1 drain
    silent = 3
    for t in (6.0, 8.0, 10.0, 12.0):
        for i, a in agents.items():
            if i != silent:
                a.heartbeat(t)
        loop.tick(t)
    assert not cluster.nodes[silent].healthy
    assert kv.get(f"/coord/lost/{silent}") is not None
    assert [e.n_workers for e in coord.entries] != pre
    dispatches = (coord.plan_stats.fresh_solves
                  + coord.plan_stats.lookup_hits)
    # partition heals: heartbeats resume, restore (not replan) fires
    for t in (14.0, 16.0):
        for a in agents.values():
            a.heartbeat(t)
        evs = loop.tick(t)
    assert cluster.nodes[silent].healthy
    assert [e.n_workers for e in coord.entries] == pre
    assert kv.get(f"/coord/lost/{silent}") is None
    # restore is a rollback, not a planner dispatch
    assert (coord.plan_stats.fresh_solves
            + coord.plan_stats.lookup_hits) == dispatches
    assert evs == [] or evs[-1].plan_latency_s is None


def test_duplicate_sev1_on_drained_node_is_noop():
    tasks, assignment, _ = _fleet()
    kv = KVStore()
    coord = UnicronCoordinator(list(tasks), list(assignment), A800, kv=kv,
                               n_cluster_workers=24, workers_per_node=4)
    cluster = Cluster(6, 4)
    cluster.assign(list(assignment))
    loop = ControlLoop(coord, cluster, {})
    loop._handle(10.0, 2, ErrorKind.LOST_CONNECTION)
    after = [e.n_workers for e in coord.entries]
    workers = cluster.healthy_workers()
    ev = loop._handle(12.0, 2, ErrorKind.LOST_CONNECTION)   # duplicate
    assert ev.plan is None
    assert [e.n_workers for e in coord.entries] == after
    assert cluster.healthy_workers() == workers
