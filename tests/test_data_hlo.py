"""Data pipeline modality paths + HLO analyzer loop handling."""
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import SyntheticLM
from repro.launch.hlo_analysis import HloAnalyzer

SYNTH_HLO = """
HloModule test, is_scheduled=true

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %y = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %c = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_analyzer_multiplies_while_trip_count():
    a = HloAnalyzer(SYNTH_HLO)
    assert a.entry is not None
    assert a.trip_count("cond") == 7
    cost = a.entry_cost()
    # dot flops = 2 * 8*8 * 8 = 1024 per iteration, 7 iterations
    assert cost.flops >= 7 * 1024
    assert cost.flops < 7 * 1024 + 2000      # elementwise slack


def test_audio_batch_structure():
    cfg = get_arch("hubert-xlarge").reduced()
    d = SyntheticLM(cfg, seq_len=16, global_batch=2)
    b = d.batch(0)
    assert set(b) == {"frames", "labels", "loss_mask"}
    assert b["frames"].shape == (2, 16, cfg.d_model)
    assert b["labels"].shape == (2, 16)
    assert bool(jnp.all(b["labels"] < cfg.vocab))
    assert 0.0 < float(b["loss_mask"].mean()) < 1.0
    # deterministic
    b2 = d.batch(0)
    assert jnp.array_equal(b["frames"], b2["frames"])


def test_vlm_batch_structure():
    cfg = get_arch("internvl2-2b").reduced()
    d = SyntheticLM(cfg, seq_len=16, global_batch=2)
    b = d.batch(0)
    assert set(b) == {"tokens", "prefix_embeds"}
    assert b["prefix_embeds"].shape == (2, cfg.n_prefix_embeds, cfg.d_model)
