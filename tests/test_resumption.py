"""§6.2 resumption correctness — the paper's core exactness claim.

A DP-rank failure mid-iteration, followed by Unicron's round-robin
micro-batch redistribution (Eq. 7), must produce the SAME aggregated
gradient as the fault-free iteration: strict optimizer semantics, no
approximation.  Scenario #2 (failure after the bucketed all-reduce
started) must likewise preserve already-reduced buckets and recompute
only the unreduced ones.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.resumption import (MicroBatchIteration, bucket_masks,
                                   run_iteration_with_failure, run_scenario2)
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.optim import AdamW, constant
from repro.train.state import init_train_state
from repro.train.step import finalize_step, make_grad_fn

N_RANKS, N_MICRO, MB = 4, 8, 2
SEQ = 32


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, seq_len=SEQ, global_batch=N_MICRO * MB)
    grad_fn = make_grad_fn(model)

    def microbatch_of(mb):
        return data.batch(0, start=mb * MB, n=MB)
    return model, params, grad_fn, microbatch_of


def _assert_tree_close(a, b, atol=1e-5, rtol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   rtol=rtol)


def test_scenario1_exact_gradient(setup):
    model, params, grad_fn, microbatch_of = setup
    ref, n = run_iteration_with_failure(grad_fn, params, microbatch_of,
                                        N_RANKS, N_MICRO, fail_rank=None)
    for fail_after in (0, 1, 2):
        got, n2 = run_iteration_with_failure(
            grad_fn, params, microbatch_of, N_RANKS, N_MICRO,
            fail_rank=1, fail_after_mb=fail_after)
        assert n2 == n
        _assert_tree_close(got, ref)


def test_scenario2_partial_reduce(setup):
    model, params, grad_fn, microbatch_of = setup
    ref, _ = run_iteration_with_failure(grad_fn, params, microbatch_of,
                                        N_RANKS, N_MICRO, fail_rank=None)
    for buckets_reduced in (0, 1, 3, 4):
        got, _ = run_scenario2(grad_fn, params, microbatch_of,
                               N_RANKS, N_MICRO, fail_rank=2,
                               n_buckets=4, buckets_reduced=buckets_reduced)
        _assert_tree_close(got, ref)


def test_recovered_step_equals_faultfree_step(setup):
    """End to end: the optimizer step after recovery is bit-compatible."""
    model, params, grad_fn, microbatch_of = setup
    opt = AdamW(lr=constant(1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))

    ref_g, n = run_iteration_with_failure(grad_fn, state.params,
                                          microbatch_of, N_RANKS, N_MICRO)
    ref_state, _ = finalize_step(opt, state, ref_g, n)

    got_g, n2 = run_iteration_with_failure(
        grad_fn, state.params, microbatch_of, N_RANKS, N_MICRO,
        fail_rank=3, fail_after_mb=1)
    got_state, _ = finalize_step(opt, state, got_g, n2)
    # The aggregated gradients are identical up to float32 summation order
    # (redistribution reorders the micro-batch accumulation), and AdamW's
    # g / (sqrt(v) + eps) amplifies that noise for near-zero v: allow the
    # update-scale relative band instead of a bitwise-tight atol.
    _assert_tree_close(got_state.params, ref_state.params, atol=1e-5,
                       rtol=1e-4)


def test_redistribution_round_robin():
    it = MicroBatchIteration(n_ranks=4, n_micro=8)
    assert it.owners == {0: [0, 1], 1: [2, 3], 2: [4, 5], 3: [6, 7]}
    orphans = it.fail_rank(1)
    assert orphans == [2, 3]
    # round-robin over survivors [0, 2, 3]
    assert it.owners[0] == [0, 1, 2]
    assert it.owners[2] == [4, 5, 3]
    assert it.owners[3] == [6, 7]
    # every micro-batch owned exactly once
    owned = sorted(m for r in it.live_ranks() for m in it.owners[r])
    assert owned == list(range(8))


def test_all_ranks_failed_raises():
    it = MicroBatchIteration(n_ranks=2, n_micro=4)
    it.fail_rank(0)
    with pytest.raises(RuntimeError):
        it.fail_rank(1)


def test_bucket_masks_partition():
    params = {"a": jnp.zeros(3), "b": jnp.zeros(3), "c": jnp.zeros(3),
              "d": jnp.zeros(3), "e": jnp.zeros(3)}
    masks = bucket_masks(params, 2)
    n_leaves = len(jax.tree.leaves(params))
    for i in range(n_leaves):
        assert sum(m[i] for m in masks) == 1          # exactly one bucket
