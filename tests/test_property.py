"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an optional test dependency (the ``test`` extra in
pyproject.toml); the module skips cleanly where it isn't installed."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.planner import PlanInput, brute_force, solve, solve_reference
from repro.core.resumption import MicroBatchIteration
from repro.core.costmodel import Hardware
from repro.data.pipeline import SyntheticLM, microbatches, stack_microbatches
from repro.launch.hlo_analysis import shape_bytes, shape_elems

HW = Hardware(name="toy", peak_flops=1e12, hbm_bytes=1e12, hbm_bw=1e12,
              intra_bw=1e11, inter_bw=1e10, intra_size=8, compute_eff=0.5)


class _TableTask:
    """Task with an arbitrary tabulated T(t, x) (monotone not required).

    Implements the Task contract proper (``weight`` / ``max_workers`` /
    ``necessary``) instead of relying on the reward layer duck-probing
    for optional attributes; ``waf.waf`` reads ``max_workers`` directly."""

    max_workers = None                  # uncapped (Task contract)

    def __init__(self, table, weight, floor):
        self.table = table
        self.weight = weight
        self.floor = floor

    def necessary(self, hw):
        return self.floor


def _twaf(task, x):
    if x < task.necessary(None) or x <= 0 or x >= len(task.table):
        return 0.0 if x < len(task.table) else task.weight * task.table[-1]
    return task.weight * task.table[x]


# monkeypatchable WAF for table tasks: reuse planner via a tiny shim
def _reward_tables(tasks, assignment, n, d_run, d_tr, faulted):
    import repro.core.waf as waf_mod

    orig = waf_mod.waf

    def table_waf(task, x, hw):
        if isinstance(task, _TableTask):
            return _twaf(task, x)
        return orig(task, x, hw)

    waf_mod.waf = table_waf
    try:
        inp = PlanInput(tuple(tasks), tuple(assignment), n, d_run, d_tr,
                        tuple(faulted))
        got = solve(inp, HW)
        scalar = solve_reference(inp, HW)
        want = brute_force(inp, HW)
    finally:
        waf_mod.waf = orig
    return got, scalar, want


@settings(max_examples=15, deadline=None)
@given(data=st.data(), m=st.integers(min_value=1, max_value=3))
def test_cached_plan_table_matches_reference_under_churn(data, m):
    """Cross-rebuild-cached lazy PlanTable == scalar-reference rewards for
    every scenario of every state along a random churn sequence (ISSUE 2:
    the chain cache must never serve a stale prefix/suffix DP)."""
    from benchmarks.common import fleet_tasks
    from repro.core.costmodel import A800
    from repro.core.planner import PlannerCache, PlanTable

    tasks = fleet_tasks(m)
    cache = PlannerCache()
    assignment = [data.draw(st.sampled_from([4, 8, 12])) for _ in range(m)]
    for _ in range(data.draw(st.integers(min_value=1, max_value=4))):
        lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                           workers_per_fault=4, n_budget=40)
        ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                        workers_per_fault=4, incremental=False,
                        solver=solve_reference)
        for key in ref.table:
            got = lazy.lookup(key)
            assert abs(got.total_reward - ref.table[key].total_reward) \
                <= 1e-9 * max(1.0, abs(ref.table[key].total_reward)), key
        i = data.draw(st.integers(min_value=0, max_value=m - 1))
        assignment[i] = data.draw(st.sampled_from([4, 8, 12, 16]))


@settings(max_examples=12, deadline=None)
@given(data=st.data(), m=st.integers(min_value=1, max_value=4))
def test_segtree_plan_table_matches_reference_under_capped_churn(data, m):
    """ISSUE 3 property: random cap-constrained churn sequences driven
    through the segment-tree PlanTable (shared PlannerCache, so node
    merges are reused across rebuilds) must reproduce the scalar
    reference's reward on every scenario of every intermediate state,
    and the traced plans must be feasible (budget + flat-past-cap)."""
    from repro.configs import get_arch
    from repro.core.costmodel import A800, TaskModel
    from repro.core.planner import PlannerCache, PlanTable
    from repro.core.waf import Task

    sizes = ["gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b"]
    caps = [data.draw(st.sampled_from([4, 8, 12, None])) for _ in range(m)]
    tasks = [Task(model=TaskModel.from_arch(get_arch(sizes[i % 4]),
                                            global_batch=128 if i % 2
                                            else 256),
                  weight=0.5 + 0.1 * i, max_workers=caps[i])
             for i in range(m)]
    cache = PlannerCache()
    assignment = [data.draw(st.sampled_from([4, 8, 12])) for _ in range(m)]
    for _ in range(data.draw(st.integers(min_value=1, max_value=3))):
        lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                           workers_per_fault=4, n_budget=52,
                           engine="segtree")
        ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                        workers_per_fault=4, incremental=False,
                        solver=solve_reference)
        n_now = sum(assignment)
        for key in ref.table:
            got = lazy.lookup(key)
            want = ref.table[key]
            assert abs(got.total_reward - want.total_reward) \
                <= 1e-9 * max(1.0, abs(want.total_reward)), key
            budget = {"join:1": n_now + 4}.get(
                key, n_now if key.startswith("finish")
                else max(n_now - 4, 0))
            assert sum(got.assignment) <= budget, (key, got)
            kind, _, idx = key.partition(":")
            kept = [i for i in range(m)
                    if not (kind == "finish" and i == int(idx))]
            for i, x in zip(kept, got.assignment):
                if caps[i] is not None:
                    assert x <= max(caps[i], assignment[i]), (key, got)
        i = data.draw(st.integers(min_value=0, max_value=m - 1))
        assignment[i] = data.draw(st.sampled_from([4, 8, 12, 16]))


@settings(max_examples=12, deadline=None)
@given(data=st.data(), m=st.integers(min_value=1, max_value=4))
def test_batched_plan_table_matches_reference_under_capped_churn(data, m):
    """ISSUE 5 property: random cap-constrained churn driven through
    ``engine="batched"`` tables (shared PlannerCache; whole-table value
    rebuilds interleaved with single-scenario dispatches) must reproduce
    the scalar reference's reward on every scenario of every
    intermediate state, with assignments identical to the segtree engine
    (the batched sweep stacks exactly its merges) and budget-feasible."""
    from repro.configs import get_arch
    from repro.core.costmodel import A800, TaskModel
    from repro.core.planner import PlannerCache, PlanTable
    from repro.core.waf import Task

    sizes = ["gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b"]
    caps = [data.draw(st.sampled_from([4, 8, 12, None])) for _ in range(m)]
    tasks = [Task(model=TaskModel.from_arch(get_arch(sizes[i % 4]),
                                            global_batch=128 if i % 2
                                            else 256),
                  weight=0.5 + 0.1 * i, max_workers=caps[i])
             for i in range(m)]
    cache = PlannerCache()
    seg_cache = PlannerCache()
    assignment = [data.draw(st.sampled_from([4, 8, 12])) for _ in range(m)]
    for _ in range(data.draw(st.integers(min_value=1, max_value=3))):
        lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                           workers_per_fault=4, n_budget=52,
                           engine="batched")
        seg = seg_cache.table(tasks, assignment, A800, 3600.0, 120.0,
                              workers_per_fault=4, n_budget=52,
                              engine="segtree")
        ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                        workers_per_fault=4, incremental=False,
                        solver=solve_reference)
        n_now = sum(assignment)
        whole_table = data.draw(st.booleans())
        if whole_table:
            tb_before = lazy.batch_stats["tracebacks"]
            totals = lazy.rebuild_values()
            # value-only: the sweep never materializes assignments
            assert lazy.batch_stats["tracebacks"] == tb_before
        for key in ref.table:
            got = lazy.lookup(key)
            want = ref.table[key]
            assert abs(got.total_reward - want.total_reward) \
                <= 1e-9 * max(1.0, abs(want.total_reward)), key
            if whole_table:
                assert got.total_reward == totals[key], key
            assert got.assignment == seg.lookup(key).assignment, key
            budget = {"join:1": n_now + 4}.get(
                key, n_now if key.startswith("finish")
                else max(n_now - 4, 0))
            assert sum(got.assignment) <= budget, (key, got)
        i = data.draw(st.integers(min_value=0, max_value=m - 1))
        assignment[i] = data.draw(st.sampled_from([4, 8, 12, 16]))


@settings(max_examples=12, deadline=None)
@given(data=st.data(), m=st.integers(min_value=1, max_value=4))
def test_fused_plan_table_matches_reference_under_capped_churn(data, m):
    """ISSUE 8 property: random cap-constrained churn driven through
    ``engine="fused"`` tables (shared PlannerCache; each whole-table
    value rebuild is ONE compiled device dispatch) must reproduce the
    scalar reference's reward on every scenario of every intermediate
    state, with totals BIT-identical to a parallel ``"batched"`` lane
    (the program reduces exactly the batched candidate sets in f64) and
    the dispatch counter moving by exactly 1 per cold rebuild, 0 on a
    warm table."""
    from repro.configs import get_arch
    from repro.core.costmodel import A800, TaskModel
    from repro.core.planner import PlannerCache, PlanTable
    from repro.core.waf import Task

    sizes = ["gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b"]
    caps = [data.draw(st.sampled_from([4, 8, 12, None])) for _ in range(m)]
    tasks = [Task(model=TaskModel.from_arch(get_arch(sizes[i % 4]),
                                            global_batch=128 if i % 2
                                            else 256),
                  weight=0.5 + 0.1 * i, max_workers=caps[i])
             for i in range(m)]
    cache = PlannerCache()
    bat_cache = PlannerCache()
    assignment = [data.draw(st.sampled_from([4, 8, 12])) for _ in range(m)]
    for _ in range(data.draw(st.integers(min_value=1, max_value=3))):
        lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                           workers_per_fault=4, n_budget=52,
                           engine="fused")
        bat = bat_cache.table(tasks, assignment, A800, 3600.0, 120.0,
                              workers_per_fault=4, n_budget=52,
                              engine="batched")
        ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                        workers_per_fault=4, incremental=False,
                        solver=solve_reference)
        n_now = sum(assignment)
        warm = lazy._values_built
        before = lazy.batch_stats["device_dispatches"]
        totals = lazy.rebuild_values()
        # one compiled program execution per COLD whole-table rebuild;
        # a warm (cache-returned) table re-reads its memoized values
        assert (lazy.batch_stats["device_dispatches"] - before
                == (0 if warm else 1))
        bat_totals = bat.rebuild_values()
        assert set(totals) == set(bat_totals) == set(ref.table)
        for key in ref.table:
            want = ref.table[key].total_reward
            assert abs(totals[key] - want) <= 1e-9 * max(1.0, abs(want)), key
            assert totals[key] == bat_totals[key], key    # bit-identical
            got = lazy.lookup(key)
            assert got.total_reward == totals[key], key
            budget = {"join:1": n_now + 4}.get(
                key, n_now if key.startswith("finish")
                else max(n_now - 4, 0))
            assert sum(got.assignment) <= budget, (key, got)
        i = data.draw(st.integers(min_value=0, max_value=m - 1))
        assignment[i] = data.draw(st.sampled_from([4, 8, 12, 16]))


@settings(max_examples=40, deadline=None)
@given(
    data=st.data(),
    m=st.integers(min_value=1, max_value=4),
    n=st.integers(min_value=0, max_value=10),
)
def test_planner_dp_equals_bruteforce(data, m, n):
    """Eq. 5 dynamic program is exactly optimal for arbitrary (even
    non-monotone) per-task reward tables."""
    tasks, assignment, faulted = [], [], []
    for i in range(m):
        table = data.draw(st.lists(
            st.floats(min_value=0, max_value=100, allow_nan=False),
            min_size=n + 1, max_size=n + 1))
        weight = data.draw(st.floats(min_value=0.5, max_value=2.0))
        floor = data.draw(st.integers(min_value=0, max_value=max(n, 1)))
        tasks.append(_TableTask(table, weight, floor))
        assignment.append(data.draw(st.integers(min_value=0, max_value=n)))
        faulted.append(data.draw(st.booleans()))
    got, scalar, want = _reward_tables(tasks, assignment, n, d_run=10.0,
                                       d_tr=2.0, faulted=faulted)
    assert abs(got.total_reward - want.total_reward) < 1e-6
    assert abs(scalar.total_reward - want.total_reward) < 1e-6
    assert got.assignment == scalar.assignment   # identical tie-breaking
    assert sum(got.assignment) <= n


@settings(max_examples=60, deadline=None)
@given(
    n_ranks=st.integers(min_value=2, max_value=8),
    n_micro=st.integers(min_value=1, max_value=32),
    data=st.data(),
)
def test_microbatch_ownership_invariant(n_ranks, n_micro, data):
    """After any sequence of rank failures (leaving >= 1 survivor), every
    micro-batch is owned by exactly one live rank."""
    it = MicroBatchIteration(n_ranks=n_ranks, n_micro=n_micro)
    n_fail = data.draw(st.integers(min_value=0, max_value=n_ranks - 1))
    ranks = data.draw(st.permutations(list(range(n_ranks))))
    for r in ranks[:n_fail]:
        it.fail_rank(r)
    owned = sorted(m for r in it.live_ranks() for m in it.owners[r])
    assert owned == list(range(n_micro))
    for r in it.failed_ranks:
        assert it.owners[r] == []
    # no survivor is left idle while others are overloaded by more than a
    # full failed-rank share per failure (round-robin redistribution)
    sizes = [len(it.owners[r]) for r in it.live_ranks()]
    assert sum(sizes) == n_micro


@settings(max_examples=20, deadline=None)
@given(step=st.integers(min_value=0, max_value=1000),
       idx=st.integers(min_value=0, max_value=63))
def test_data_pipeline_deterministic(step, idx):
    """Micro-batch regeneration is a pure function of (step, index) —
    the property Eq. 7 redistribution relies on."""
    from repro.configs import get_arch
    cfg = get_arch("gemma-2b").reduced()
    d = SyntheticLM(cfg, seq_len=16, global_batch=64)
    a = d.tokens(step, idx, 1)
    b = d.tokens(step, idx, 1)
    assert jnp.array_equal(a, b)
    assert a.shape == (1, 16)
    assert bool(jnp.all((a >= 0) & (a < cfg.vocab)))


@settings(max_examples=20, deadline=None)
@given(n_micro=st.sampled_from([1, 2, 4, 8]))
def test_microbatch_split_consistency(n_micro):
    from repro.configs import get_arch
    cfg = get_arch("gemma-2b").reduced()
    d = SyntheticLM(cfg, seq_len=16, global_batch=8)
    batch = d.batch(3)
    mbs = microbatches(batch, n_micro)
    stacked = stack_microbatches(batch, n_micro)
    assert len(mbs) == n_micro
    for i, mb in enumerate(mbs):
        assert jnp.array_equal(mb["tokens"], stacked["tokens"][i])
    recat = jnp.concatenate([m["tokens"] for m in mbs], axis=0)
    assert jnp.array_equal(recat, batch["tokens"])


@settings(max_examples=50, deadline=None)
@given(dims=st.lists(st.integers(min_value=1, max_value=64), min_size=0,
                     max_size=4),
       dt=st.sampled_from(["f32", "bf16", "s32", "pred", "s8"]))
def test_hlo_shape_parsing(dims, dt):
    width = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1, "s8": 1}[dt]
    s = f"{dt}[{','.join(map(str, dims))}]"
    n = 1
    for d in dims:
        n *= d
    assert shape_elems(s) == n
    assert shape_bytes(s) == n * width
    # tuple form sums components
    assert shape_bytes(f"({s}, {s})") == 2 * n * width


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_flash_vjp_random_shapes(data):
    """Flash custom-VJP attention matches the oracle on random shapes,
    GQA ratios, block sizes and masks (forward + gradients)."""
    import numpy as np
    from repro.models.flash_vjp import flash_attention_jnp
    from repro.models.layers import simple_attention

    B = data.draw(st.integers(1, 2))
    S = data.draw(st.integers(3, 65))
    KV = data.draw(st.sampled_from([1, 2, 4]))
    G = data.draw(st.sampled_from([1, 2]))
    D = data.draw(st.sampled_from([8, 16]))
    causal = data.draw(st.booleans())
    window = data.draw(st.sampled_from([0, 0, 8]))
    bq = data.draw(st.sampled_from([8, 16, 128]))
    bk = data.draw(st.sampled_from([8, 32, 128]))
    H = KV * G
    key = jax.random.PRNGKey(data.draw(st.integers(0, 2 ** 16)))
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))

    def f(q, k, v):
        return flash_attention_jnp(q, k, v, causal, window, 0.0, 0, bq, bk)

    def r(q, k, v):
        return simple_attention(q, k, v, causal=causal, window=window,
                                q_offset=0)

    np.testing.assert_allclose(f(q, k, v), r(q, k, v), atol=3e-5, rtol=3e-5)
    g1 = jax.grad(lambda q, k, v: jnp.sum(f(q, k, v) ** 2), (0, 1, 2))(
        q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(r(q, k, v) ** 2), (0, 1, 2))(
        q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_batched_engine_matches_vector_on_random_subsets(data):
    """Random seed/policy subsets through ``engine="batched"`` vs
    ``engine="vector"``: per-seed WAF, reconfiguration counts and
    downtime agree for every policy (ISSUE 4: one stacked pass per seed
    must reproduce every per-policy run)."""
    from benchmarks.common import case5_tasks
    from repro.core import scenarios as sc
    from repro.core.simulator import EFFICIENCY, run_monte_carlo
    from repro.core.traces import DAY

    tasks, assignment = case5_tasks()
    policies = data.draw(st.lists(st.sampled_from(list(EFFICIENCY)),
                                  min_size=1, max_size=3, unique=True))
    seeds = data.draw(st.lists(st.integers(0, 60), min_size=1,
                               max_size=2, unique=True))
    scenario_cls = data.draw(st.sampled_from(["mixed", "independent"]))

    def make(seed):
        if scenario_cls == "mixed":
            return sc.mixed_fleet(n_nodes=16, span_s=7 * DAY, seed=seed,
                                  m_initial=len(tasks),
                                  candidates=tasks[:2],
                                  mtbf_node_s=20 * DAY, n_degradations=3)
        return sc.independent_failures(n_nodes=16, span_s=7 * DAY,
                                       seed=seed, mtbf_node_s=20 * DAY)

    got = run_monte_carlo(tasks, assignment, make, seeds=seeds,
                          policies=policies, n_nodes=16, engine="batched")
    want = run_monte_carlo(tasks, assignment, make, seeds=seeds,
                           policies=policies, n_nodes=16, engine="vector")
    for policy in policies:
        import pytest
        assert got[policy].per_seed == pytest.approx(
            want[policy].per_seed, rel=1e-9), policy
        assert got[policy].n_reconfigs == want[policy].n_reconfigs
        assert got[policy].downtime_s == want[policy].downtime_s


@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_new_policies_batched_waf_matches_scalar_on_calibrated(data):
    """The three new recovery policies (fftrainer / hierarchical_ckpt /
    redundant) through the batched engine reproduce the scalar
    TraceSimulator's WAF and downtime on calibrated traces, including
    replica-loss bursts (ISSUE 10: new policies are engine-equivalence
    peers of the paper's five)."""
    from benchmarks.common import case5_tasks
    from repro.core import scenarios as sc
    from repro.core.simulator import BatchSimulator, TraceSimulator
    from repro.core.traces import DAY

    tasks, assignment = case5_tasks()
    policies = data.draw(st.lists(
        st.sampled_from(["fftrainer", "hierarchical_ckpt", "redundant"]),
        min_size=1, max_size=3, unique=True))
    seed = data.draw(st.integers(0, 40))
    intensity = data.draw(st.sampled_from([4.0, 12.0]))
    scen = sc.calibrated_fleet(n_nodes=16, span_s=7 * DAY, seed=seed,
                               m_initial=len(tasks), intensity=intensity)

    bat = BatchSimulator(tasks, list(assignment), list(policies),
                         n_nodes=16)
    got = bat.run(scen)
    import pytest
    for policy in policies:
        ref = TraceSimulator(tasks, list(assignment), policy,
                             n_nodes=16).run(scen)
        assert got[policy].accumulated_waf == pytest.approx(
            ref.accumulated_waf, rel=1e-9, abs=1e-12), policy
        assert got[policy].downtime_s == pytest.approx(
            ref.downtime_s, rel=1e-9, abs=1e-9), policy
        assert got[policy].n_reconfigs == ref.n_reconfigs, policy
