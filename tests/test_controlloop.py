"""Control-loop integration: agents -> KV store -> coordinator decisions."""
import pytest

from repro.configs import get_arch
from repro.core.agent import UnicronAgent
from repro.core.cluster import Cluster
from repro.core.controlloop import ControlLoop
from repro.core.coordinator import UnicronCoordinator
from repro.core.costmodel import A800, TaskModel
from repro.core.detection import ErrorKind
from repro.core.handling import Action
from repro.core.kvstore import KVStore, LegacyKVStore
from repro.core.waf import Task


# every trigger path runs against both the sharded store (queue-cursor
# drains) and the legacy flat-dict store (scan+sort fallback)
@pytest.fixture(params=[KVStore, LegacyKVStore], ids=["sharded", "legacy"])
def loop(request):
    tasks = [Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                            global_batch=64)),
             Task(model=TaskModel.from_arch(get_arch("gpt3-7b"),
                                            global_batch=64))]
    kv = request.param()
    coord = UnicronCoordinator(tasks, [32, 96], A800, kv=kv)
    cluster = Cluster(n_nodes=16, gpus_per_node=8)
    cluster.assign([32, 96])
    agents = {i: UnicronAgent(i, kv) for i in range(16)}
    return ControlLoop(coord, cluster, agents), agents, cluster, coord


def test_heartbeat_loss_triggers_reconfigure(loop):
    cl, agents, cluster, coord = loop
    for a in agents.values():
        a.heartbeat(now=0.0)
    assert cl.tick(now=3.0) == []                 # all alive
    agents[5].kill()
    for i, a in agents.items():
        a.heartbeat(now=4.0)                      # 5 is dead: no refresh
    events = cl.tick(now=8.0)                     # 5's lease (0+6s) lapsed;
                                                  # others live until 10
    assert len(events) == 1
    ev = events[0]
    assert ev.node == 5 and ev.kind is ErrorKind.LOST_CONNECTION
    assert ev.action is Action.RECONFIGURE
    assert sum(ev.plan) <= cluster.healthy_workers()
    assert not cluster.nodes[5].healthy


def test_inband_report_respects_detection_latency(loop):
    cl, agents, cluster, coord = loop
    agents[2].report(ErrorKind.CUDA_ERROR, now=100.0)      # visible at 100.3
    assert cl.tick(now=100.1) == []               # not yet visible
    events = cl.tick(now=100.5)
    assert len(events) == 1
    assert events[0].action is Action.RESTART     # SEV2
    assert cluster.nodes[2].healthy               # no drain for SEV2


def test_sev3_reattempt_then_escalation(loop):
    cl, agents, cluster, coord = loop
    agents[1].report(ErrorKind.CONNECTION_REFUSED, now=0.0)
    events = cl.tick(now=2.0)                     # visible at +1.8 s
    assert events[0].action is Action.REATTEMPT   # SEV3
    # reattempt fails -> SEV2 restart; fails again -> SEV1 reconfigure
    ev = cl.action_failed(now=2.0, node=1,
                          kind=ErrorKind.CONNECTION_REFUSED)
    assert ev.action is Action.RECONFIGURE or ev.action is Action.RESTART


def test_repair_rejoins_and_replans(loop):
    cl, agents, cluster, coord = loop
    for a in agents.values():
        a.heartbeat(now=0.0)
    agents[7].kill()
    for a in agents.values():
        if a.alive:
            a.heartbeat(now=4.0)
    cl.tick(now=8.0)                              # node 7 drained
    assert not cluster.nodes[7].healthy
    before = cluster.healthy_workers()
    for a in agents.values():
        if a.alive:
            a.heartbeat(now=8.0)                  # leases live until 14
    cluster.nodes[7].repair_done_at = 10.0        # repaired early
    events = cl.tick(now=12.0)
    assert any(e.action is Action.RESUME for e in events)
    assert cluster.healthy_workers() == before + 8
    assert agents[7].alive


def test_duplicate_reports_deduplicated(loop):
    cl, agents, cluster, coord = loop
    agents[3].report(ErrorKind.NCCL_TIMEOUT, now=0.0)
    n1 = len(cl.tick(now=200.0))
    n2 = len(cl.tick(now=300.0))
    assert n1 == 1 and n2 == 0


def test_agent_task_finished_report_fires_trigger(loop):
    """Agents announce task completion through the KV store and the next
    tick fires the coordinator's ``task_finished`` trigger end-to-end:
    the entry is dropped, the survivors are replanned, and the event
    carries the plan (Figure 7 trigger 5)."""
    cl, agents, cluster, coord = loop
    assert len(coord.entries) == 2
    rec = agents[4].report_task_finished(task_index=0, now=50.0,
                                         epoch=coord.plan_epoch)
    assert rec["task"] == 0
    events = cl.tick(now=51.0)
    assert len(events) == 1
    ev = events[0]
    assert ev.kind is None and ev.action is Action.RESUME
    assert len(coord.entries) == 1
    assert ev.plan is not None and len(ev.plan) == 1
    assert sum(ev.plan) <= cluster.healthy_workers()
    assert coord.plan_stats.task_finishes == 1
    assert cl.events[-1] is ev                 # recorded exactly once
    assert len(cl.events) == 1
    # the report is consumed: the next tick is quiet
    assert cl.tick(now=52.0) == []


def test_agent_task_finished_reports_deduplicated(loop):
    """Every worker of a task may announce completion; one tick fires the
    trigger once per task, and out-of-range indices are ignored."""
    cl, agents, cluster, coord = loop
    e = coord.plan_epoch
    for node in (1, 2, 3):
        agents[node].report_task_finished(task_index=1, now=10.0, epoch=e)
    agents[5].report_task_finished(task_index=7, now=10.0,   # no such task
                                   epoch=e)
    events = cl.tick(now=11.0)
    assert len(events) == 1
    assert len(coord.entries) == 1
    assert cl.tick(now=12.0) == []


def test_agent_launch_request_fires_task_arrival_trigger(loop):
    """Agents announce task launches through the KV store and the next
    tick fires the coordinator's ``task_launched`` trigger end-to-end:
    the task is admitted, the whole cluster is replanned, and the event
    carries the plan (Figure 7 trigger 6)."""
    cl, agents, cluster, coord = loop
    new_task = Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                              global_batch=32))
    rec = agents[6].request_task_launch(new_task, now=40.0,
                                       epoch=coord.plan_epoch,
                                       avg_iter_s=12.0)
    assert rec["task"] is new_task
    events = cl.tick(now=41.0)
    assert len(events) == 1
    ev = events[0]
    assert ev.kind is None and ev.action is Action.RESUME
    assert len(coord.entries) == 3
    assert coord.entries[-1].task is new_task
    assert coord.entries[-1].avg_iter_s == 12.0
    assert ev.plan is not None and len(ev.plan) == 3
    assert sum(ev.plan) <= cluster.healthy_workers()
    assert coord.plan_stats.task_launches == 1
    # the request is consumed: the next tick is quiet
    assert cl.tick(now=42.0) == []


def test_agent_launch_requests_deduplicated_per_task(loop):
    """Several nodes may announce the same launch; one tick admits the
    task once."""
    cl, agents, cluster, coord = loop
    new_task = Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                              global_batch=32))
    e = coord.plan_epoch
    for node in (1, 2, 3):
        agents[node].request_task_launch(new_task, now=10.0, epoch=e)
    events = cl.tick(now=11.0)
    assert len(events) == 1
    assert len(coord.entries) == 3
    assert cl.tick(now=12.0) == []


def test_same_node_same_time_launches_both_admitted(loop):
    """Two distinct launches announced by one node at the same timestamp
    must not overwrite each other in the status monitor (per-agent
    sequence in the key)."""
    cl, agents, cluster, coord = loop
    e = coord.plan_epoch
    a = Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                       global_batch=32))
    b = Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                       global_batch=16))
    agents[2].request_task_launch(a, now=10.0, epoch=e)
    agents[2].request_task_launch(b, now=10.0, epoch=e)
    events = cl.tick(now=11.0)
    assert len(events) == 2
    admitted = {coord.entries[-2].task, coord.entries[-1].task}
    assert admitted == {a, b}


def test_launch_admission_order_is_chronological(loop):
    """Launch keys drain in sorted order, so lexicographic order must be
    chronological across digit-width boundaries (99.0 vs 100.0): the
    earlier request is admitted first, which fixes coordinator entry
    order and the plans produced."""
    cl, agents, cluster, coord = loop
    e = coord.plan_epoch
    a = Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                       global_batch=32))
    b = Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                       global_batch=16))
    agents[1].request_task_launch(a, now=99.0, epoch=e)
    agents[1].request_task_launch(b, now=100.0, epoch=e)
    events = cl.tick(now=101.0)
    assert len(events) == 2
    assert coord.entries[-2].task is a       # admitted first
    assert coord.entries[-1].task is b


def test_stale_epoch_launch_request_is_dropped(loop):
    """A launch request computed against a superseded plan state (its
    epoch predates a task-set change) is consumed without firing."""
    cl, agents, cluster, coord = loop
    old_epoch = coord.plan_epoch
    new_task = Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                              global_batch=32))
    # the task set shifts before the request becomes visible
    agents[0].report_task_finished(task_index=0, now=50.0, epoch=old_epoch)
    assert len(cl.tick(now=50.5)) == 1
    assert coord.plan_epoch == old_epoch + 1
    agents[4].request_task_launch(new_task, now=51.0, epoch=old_epoch)
    assert cl.tick(now=51.5) == []             # stale request: no event
    assert len(coord.entries) == 1
    # re-announced against the current epoch, it is honored
    agents[4].request_task_launch(new_task, now=52.0,
                                  epoch=coord.plan_epoch)
    assert len(cl.tick(now=52.5)) == 1
    assert coord.entries[-1].task is new_task


def test_stale_epoch_task_report_never_removes_wrong_task(loop):
    """Task indices are positional: a duplicate finish report that drains
    only after the task set already shifted carries a stale plan epoch
    and must be consumed without firing — not resolved against the new
    index 0 (which now names a different, still-running task)."""
    cl, agents, cluster, coord = loop
    survivor = coord.entries[1].task
    old_epoch = coord.plan_epoch
    agents[0].report_task_finished(task_index=0, now=50.0, epoch=old_epoch)
    assert len(cl.tick(now=50.5)) == 1         # task 0 finished
    assert len(coord.entries) == 1
    assert coord.plan_epoch == old_epoch + 1
    # a second worker of the *same* finished task reports late with the
    # (index, epoch) pair it learned at dispatch time — now stale
    agents[1].report_task_finished(task_index=0, now=51.0,
                                   epoch=old_epoch)
    assert cl.tick(now=51.5) == []             # stale report: no event
    assert len(coord.entries) == 1             # survivor still running
    assert coord.entries[0].task is survivor


def test_plan_events_carry_batched_engine_counters(loop):
    """Plan-producing LoopEvents are stamped with the coordinator's
    cumulative batched-engine counters (level sweeps, stacked kernel
    launches, lazy tracebacks), like ``plan_latency_s``."""
    cl, agents, cluster, coord = loop
    # non-plan events stay unstamped (SEV2 -> restart, no reconfigure)
    agents[2].report(ErrorKind.CUDA_ERROR, now=0.0)
    restart = cl.tick(now=0.5)[0]
    assert restart.plan is None and restart.plan_tracebacks is None
    for a in agents.values():
        a.heartbeat(now=1.0)
    agents[5].kill()
    for a in agents.values():
        a.heartbeat(now=5.0)                      # 5 is dead: no refresh
    events = cl.tick(now=9.0)                     # 5's lease (1+6s) lapsed
    assert len(events) == 1
    ev = events[0]
    assert ev.plan is not None
    # the dispatched fault scenario was materialized by one lazy
    # traceback over batched level sweeps
    assert ev.plan_tracebacks >= 1
    assert ev.plan_launches >= 1
    assert ev.plan_levels >= 1
    assert ev.plan_tracebacks == coord.plan_stats.lazy_tracebacks
    assert ev.plan_launches == coord.plan_stats.batched_launches


def test_prebuild_scenarios_precomputes_whole_table_values():
    """``prebuild_scenarios=True`` runs the whole-table batched value
    rebuild on every refresh: totals for every scenario are ready before
    any dispatch, and a dispatch only adds its own lazy traceback."""
    from repro.core.planner import PlannerCache

    tasks = [Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                            global_batch=64)),
             Task(model=TaskModel.from_arch(get_arch("gpt3-7b"),
                                            global_batch=64))]
    cache = PlannerCache()
    coord = UnicronCoordinator(tasks, [32, 96], A800, plan_cache=cache,
                               n_cluster_workers=128,
                               prebuild_scenarios=True)
    assert coord.plan_stats.batched_launches >= 1
    assert coord.plan_stats.lazy_tracebacks == 0   # values only so far
    table = coord._table
    assert set(table.rebuild_values()) == set(table.scenario_keys())
    plan, hit = coord.plan_for(120, 0, "fault:0")
    assert hit
    assert coord.plan_stats.lazy_tracebacks == 1
