"""Serving-layer tests: batched generation + continuous batching."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models.model import build_model
from repro.serve.decode import RequestBatcher, generate
from repro.serve.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_continuous_batcher_matches_sequential(small_model):
    """Requests scheduled through slot lanes produce the same greedy
    tokens as sequential one-at-a-time generation."""
    cfg, model, params = small_model
    key = jax.random.PRNGKey(7)
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (6,), 0,
                                  cfg.vocab) for i in range(5)]

    cb = ContinuousBatcher(model, params, batch_size=3, capacity=32)
    for i, p in enumerate(prompts):
        cb.submit(Request(req_id=i, prompt=p, max_new=5))
    done = cb.run()
    assert len(done) == 5
    got = {r.req_id: r.out[:5] for r in done}

    for i, p in enumerate(prompts):
        want = generate(model, params, p[None], n_new=5,
                        capacity=32)[0].tolist()
        # first token comes from prefill logits; remaining from decode
        assert got[i][:5] == want[:5], (i, got[i], want)


def test_continuous_batcher_more_requests_than_slots(small_model):
    cfg, model, params = small_model
    cb = ContinuousBatcher(model, params, batch_size=2, capacity=24)
    for i in range(6):
        cb.submit(Request(req_id=i, prompt=jnp.arange(4, dtype=jnp.int32),
                          max_new=3))
    done = cb.run()
    assert len(done) == 6
    assert all(len(r.out) >= 3 for r in done)


def test_evict_recycles_slot(small_model):
    cfg, model, params = small_model
    cb = ContinuousBatcher(model, params, batch_size=1, capacity=24)
    cb.submit(Request(req_id=0, prompt=jnp.arange(4, dtype=jnp.int32),
                      max_new=100))
    cb.submit(Request(req_id=1, prompt=jnp.arange(4, dtype=jnp.int32),
                      max_new=2))
    cb.step()                       # admits req 0
    assert cb.evict(0)
    done = cb.run()
    ids = {r.req_id for r in done}
    assert ids == {0, 1}
    req1 = next(r for r in done if r.req_id == 1)
    assert len(req1.out) >= 2


def test_lane_failure_stats_feed_slo_calibration(small_model):
    """Lane failure -> eviction -> lane recycling, with the outcome
    counters flowing into ``ServingSLO.calibrated`` (the decode-path ->
    planner feedback loop)."""
    from repro.core.waf import ServingSLO

    cfg, model, params = small_model
    cb = ContinuousBatcher(model, params, batch_size=2, capacity=24)
    assert cb.slo_stats() == {"lane_failures": 0, "completed": 0,
                              "steps": 0, "queue_depth": 0, "in_flight": 0}
    for i in range(4):
        cb.submit(Request(req_id=i, prompt=jnp.arange(4, dtype=jnp.int32),
                          max_new=3))
    cb.step()                           # admits reqs 0 and 1
    stats = cb.slo_stats()
    assert stats["in_flight"] == 2 and stats["queue_depth"] == 2
    assert cb.evict(0)                  # poisoned request: lane failure
    assert not cb.evict(0)              # already gone
    done = cb.run()
    assert len(done) == 4               # evicted lane was recycled
    stats = cb.slo_stats()
    assert stats["lane_failures"] == 1
    assert stats["completed"] == 3      # natural finishes only
    assert stats["in_flight"] == 0 and stats["queue_depth"] == 0

    slo = ServingSLO(rate_rps=100.0)
    cal = slo.calibrated(stats)
    assert cal.lane_fail_discount == pytest.approx(1.0 / 4.0)
    # derated capacity strictly lowers goodput at any finite width
    assert cal.value(_slo_task(cal), 20, None) \
        < slo.value(_slo_task(slo), 20, None)
    # a clean batcher calibrates back to zero discount
    assert slo.calibrated({"lane_failures": 0, "completed": 10}) == slo


def _slo_task(objective):
    from repro.core.costmodel import TaskModel
    from repro.core.waf import Task
    return Task(model=TaskModel(name="serve", n_params=1e9, n_layers=8,
                                d_model=512),
                max_workers=32, objective=objective)


def test_request_batcher(small_model):
    cfg, model, params = small_model
    rb = RequestBatcher(model, params, batch_size=4, capacity=32)
    prompts = [jnp.arange(5, dtype=jnp.int32) for _ in range(2)]
    outs = rb.serve(prompts, n_new=4)
    assert len(outs) == 2 and all(o.shape == (4,) for o in outs)
