"""Statistical tests for the calibrated scenario family (ISSUE 10).

The committed parameter tables in ``core/calibration.py`` are the
single source of truth; these tests assert the generated event streams
actually reproduce them: Poisson event counts, per-category shares,
exponential inter-arrival times (KS), 1/n MTTF scaling, repair-time
ranges, and the documented MTTF anchor.  All bounds are +-5 sigma on
seeded draws, so the tests are deterministic, not flaky.
"""
import math

import numpy as np
import pytest

from repro.core import scenarios as sc
from repro.core.calibration import (CATEGORIES, DAY, DEFAULT_CALIBRATION,
                                    FleetCalibration)
from repro.core.detection import Severity, classify

CAL = DEFAULT_CALIBRATION
KIND_TO_CAT = {k: c for c in CATEGORIES for k in c.kinds}


def _sig(scn):
    return ([(e.time, e.node, e.kind, e.repair_s) for e in scn.failures],
            [(e.time, e.node, e.slowdown, e.duration_s)
             for e in scn.degradations])


# ---------------------------------------------------------------------------
# parameter table itself
# ---------------------------------------------------------------------------


def test_category_shares_form_distribution():
    shares = CAL.category_shares()
    assert abs(sum(shares.values()) - 1.0) < 1e-12
    assert all(s > 0 for s in shares.values())
    # kinds partition cleanly: no kind claimed by two categories
    kinds = [k for c in CATEGORIES for k in c.kinds]
    assert len(kinds) == len(set(kinds))


def test_mttf_anchor_matches_meta_study():
    """Meta (arXiv 2410.21680): ~7.9 h job MTTF at 1024 GPUs/128 nodes."""
    assert CAL.mttf_s(128) / 3600.0 == pytest.approx(7.9, abs=0.1)
    # and the superposition identity rate * mttf == 1
    assert CAL.failure_rate_s(128) * CAL.mttf_s(128) == pytest.approx(1.0)


def test_sev1_share_is_infrastructure_fraction():
    """Acme (arXiv 2403.07648): ~30% of failures are node-fatal infra."""
    assert CAL.sev1_share() == pytest.approx(0.31, abs=1e-12)
    for c in CATEGORIES:
        if c.repair_range_s is not None:
            lo, hi = c.repair_range_s
            assert 0 < lo < hi


def test_scaled_multiplies_every_rate():
    s = CAL.scaled(3.0)
    assert s.failure_rate_s(16) == pytest.approx(3.0 * CAL.failure_rate_s(16))
    assert s.slow_rate_per_node_s == pytest.approx(
        3.0 * CAL.slow_rate_per_node_s)
    assert s.burst_rate_per_node_s == pytest.approx(
        3.0 * CAL.burst_rate_per_node_s)
    assert s.preempt_wave_rate_s == pytest.approx(
        3.0 * CAL.preempt_wave_rate_s)
    # shares and ranges untouched
    assert s.categories is CAL.categories
    assert s.slow_slowdown_range == CAL.slow_slowdown_range


# ---------------------------------------------------------------------------
# generated streams vs the table
# ---------------------------------------------------------------------------


def test_calibrated_generators_deterministic():
    a = sc.calibrated_fleet(n_nodes=32, span_s=30 * DAY, seed=11)
    b = sc.calibrated_fleet(n_nodes=32, span_s=30 * DAY, seed=11)
    c = sc.calibrated_fleet(n_nodes=32, span_s=30 * DAY, seed=12)
    assert _sig(a) == _sig(b)
    assert _sig(a) != _sig(c)
    assert a.name == "calibrated_fleet"


def test_failure_count_is_poisson_at_calibrated_rate():
    n, span = 64, 360 * DAY
    scn = sc.calibrated_failures(n_nodes=n, span_s=span, seed=5)
    expected = CAL.failure_rate_s(n) * span
    sigma = math.sqrt(expected)
    assert abs(len(scn.failures) - expected) < 5 * sigma


def test_category_shares_within_binomial_bounds():
    n, span = 64, 360 * DAY
    scn = sc.calibrated_failures(n_nodes=n, span_s=span, seed=5)
    N = len(scn.failures)
    counts = {c.name: 0 for c in CATEGORIES}
    for e in scn.failures:
        counts[KIND_TO_CAT[e.kind].name] += 1
    for c in CATEGORIES:
        sigma = math.sqrt(N * c.share * (1 - c.share))
        assert abs(counts[c.name] - N * c.share) < 5 * sigma, c.name
    # node-fatal events (and only those) carry a repair time, and it
    # stays inside the category's calibrated range
    sev1 = 0
    for e in scn.failures:
        cat = KIND_TO_CAT[e.kind]
        if cat.repair_range_s is None:
            assert e.repair_s is None
        else:
            sev1 += 1
            lo, hi = cat.repair_range_s
            assert lo <= e.repair_s <= hi
            assert classify(e.kind)[1] is Severity.SEV1
    p = CAL.sev1_share()
    assert abs(sev1 - N * p) < 5 * math.sqrt(N * p * (1 - p))


def test_interarrivals_are_exponential_ks():
    """One-sample KS against Exp(lambda): D * sqrt(N) < 2.0 (the 5%
    critical value is ~1.36; 2.0 keeps the seeded draw deterministic)."""
    n, span = 64, 360 * DAY
    scn = sc.calibrated_failures(n_nodes=n, span_s=span, seed=5)
    t = np.array([e.time for e in scn.failures])
    gaps = np.diff(np.sort(t))
    lam = CAL.failure_rate_s(n)
    u = np.sort(1.0 - np.exp(-lam * gaps))
    k = u.size
    grid = np.arange(1, k + 1) / k
    d = np.max(np.maximum(grid - u, u - (grid - 1.0 / k)))
    assert d * math.sqrt(k) < 2.0


def test_mttf_scales_inversely_with_fleet_size():
    """Doubling nodes doubles the fleet event rate: the n=256 count over
    the same span is ~4x the n=64 count (Poisson superposition)."""
    span = 360 * DAY
    n64 = len(sc.calibrated_failures(n_nodes=64, span_s=span,
                                     seed=9).failures)
    n256 = len(sc.calibrated_failures(n_nodes=256, span_s=span,
                                      seed=10).failures)
    expect64 = CAL.failure_rate_s(64) * span
    expect256 = CAL.failure_rate_s(256) * span
    assert expect256 / expect64 == pytest.approx(4.0)
    ratio_sigma = 4.0 * (1 / math.sqrt(expect64) + 1 / math.sqrt(expect256))
    assert abs(n256 / n64 - 4.0) < 5 * ratio_sigma


def test_slow_nodes_sit_inside_monitor_band():
    """Calibrated stragglers must be catchable: above the 1.1x
    degradation margin, below the 3x failure threshold (paper Fig. 6)."""
    scn = sc.calibrated_slow_nodes(n_nodes=64, span_s=720 * DAY, seed=3)
    assert scn.degradations
    lo, hi = CAL.slow_slowdown_range
    dlo, dhi = CAL.slow_duration_range_s
    for e in scn.degradations:
        assert 1.1 < lo <= e.slowdown <= hi < 3.0
        assert dlo <= e.duration_s <= dhi
    expected = 64 * CAL.slow_rate_per_node_s * 720 * DAY
    assert abs(len(scn.degradations) - expected) < 5 * math.sqrt(expected)


def test_bursts_take_ring_neighbors_together():
    """Correlated bursts hit contiguous groups, so some failed node's
    ring neighbor (node+1) is down in the same two-minute window — the
    replica-loss case the tier-aware restore model charges."""
    scn = sc.calibrated_bursts(n_nodes=64, span_s=3600 * DAY, seed=2)
    assert scn.failures
    by_node = {}
    pairs = 0
    for e in scn.failures:
        by_node.setdefault(e.node, []).append(e)
        assert e.repair_s is not None and e.repair_s >= 60.0
    for e in scn.failures:
        for nb in by_node.get((e.node + 1) % 64, ()):
            if abs(nb.time - e.time) <= 120.0:
                pairs += 1
    assert pairs > 0


def test_preemption_waves_reclaim_calibrated_fraction():
    scn = sc.calibrated_preemption(n_nodes=64, span_s=3600 * DAY, seed=4)
    assert scn.failures
    # cluster waves by onset (30 s reclaim skew)
    times = np.array([e.time for e in scn.failures])
    order = np.argsort(times)
    waves = []
    cur = [order[0]]
    for i in order[1:]:
        if times[i] - times[cur[-1]] <= 30.0:
            cur.append(i)
        else:
            waves.append(cur)
            cur = [i]
    waves.append(cur)
    lo, hi = CAL.preempt_fraction_range
    for w in waves:
        assert lo * 64 - 1 <= len(w) <= hi * 64 + 1
    expected = CAL.preempt_wave_rate_s * 3600 * DAY
    assert abs(len(waves) - expected) < 5 * math.sqrt(expected)


def test_fleet_intensity_scales_counts():
    base = sc.calibrated_fleet(n_nodes=64, span_s=90 * DAY, seed=6)
    hot = sc.calibrated_fleet(n_nodes=64, span_s=90 * DAY, seed=6,
                              intensity=8.0)
    nb, nh = len(base.failures), len(hot.failures)
    assert nh > 4 * nb          # ~8x in expectation
    # custom calibration flows through end to end
    slow = FleetCalibration(node_mtbf_s=1.0 * DAY)
    fast = sc.calibrated_failures(n_nodes=64, span_s=90 * DAY, seed=6,
                                  calib=slow)
    expected = slow.failure_rate_s(64) * 90 * DAY
    assert abs(len(fast.failures) - expected) < 5 * math.sqrt(expected)
