"""Trace-simulator invariants (the Fig. 11 machinery)."""
import pytest

from benchmarks.common import case5_tasks
from repro.core.simulator import TraceSimulator, run_policies
from repro.core.traces import FailureEvent, trace_a, trace_b, trace_span
from repro.core.detection import ErrorKind


def test_trace_shapes():
    a, b = trace_a(), trace_b()
    assert sum(1 for e in a if e.repair_s is not None) == 10
    assert len(a) == 43
    assert sum(1 for e in b if e.repair_s is not None) == 26
    assert len(b) == 106
    assert trace_span(a) == 8 * 7 * 86400.0
    assert trace_span(b) == 7 * 86400.0
    assert all(x.time <= y.time for x, y in zip(a, a[1:]))


def test_no_failures_equals_ideal():
    tasks, assignment = case5_tasks()
    sim = TraceSimulator(tasks, list(assignment), "unicron")
    res = sim.run([], span_s=1000.0)
    ideal = sim.cluster_waf(0.0) * 1000.0
    assert res.accumulated_waf == pytest.approx(ideal, rel=1e-9)


def test_unicron_dominates_all_policies():
    tasks, assignment = case5_tasks()
    res = run_policies(tasks, assignment, trace_b())
    uni = res["unicron"].accumulated_waf
    for p, r in res.items():
        assert uni >= r.accumulated_waf, p
    # efficiency ordering holds among resilient systems
    assert res["oobleck"].accumulated_waf > res["bamboo"].accumulated_waf
    assert res["bamboo"].accumulated_waf > res["varuna"].accumulated_waf


def test_sev2_blocks_without_capacity_loss():
    tasks, assignment = case5_tasks()
    sim = TraceSimulator(tasks, list(assignment), "unicron")
    ev = FailureEvent(time=100.0, node=0, kind=ErrorKind.CUDA_ERROR,
                      repair_s=None)
    res = sim.run([ev], span_s=10_000.0)
    # capacity unchanged at the end
    assert sum(t.workers for t in sim.tasks) == sum(assignment)
    assert res.downtime_s > 0


def test_sev1_shrinks_then_repairs():
    tasks, assignment = case5_tasks()
    sim = TraceSimulator(tasks, list(assignment), "unicron")
    ev = FailureEvent(time=100.0, node=3,
                      kind=ErrorKind.LOST_CONNECTION, repair_s=5000.0)
    sim.run([ev], span_s=100_000.0)
    # node repaired and capacity replanned back to the full pool
    assert sim.cluster.healthy_workers() == 128


def test_megatron_hot_spare_preserves_capacity():
    tasks, assignment = case5_tasks()
    sim = TraceSimulator(tasks, list(assignment), "megatron")
    assert sim.spares == 1
    ev = FailureEvent(time=100.0, node=3,
                      kind=ErrorKind.LOST_CONNECTION, repair_s=1e9)
    sim.run([ev], span_s=10_000.0)
    # spare consumed, workers unchanged
    assert sim.spares == 0
    assert sum(t.workers for t in sim.tasks) == sum(assignment)


def test_vector_simulator_matches_reference_on_traces():
    """Pure failure traces (the original Fig. 11 inputs) through the
    vectorized engine reproduce the scalar loop's WAF integral."""
    from repro.core.simulator import VectorSimulator
    tasks, assignment = case5_tasks()
    trace = trace_b()
    for policy in ("unicron", "megatron", "bamboo"):
        ref = TraceSimulator(tasks, list(assignment), policy).run(trace)
        got = VectorSimulator(tasks, list(assignment), policy).run(trace)
        assert got.accumulated_waf == pytest.approx(ref.accumulated_waf,
                                                    rel=1e-9), policy
        assert got.n_reconfigs == ref.n_reconfigs
        assert got.downtime_s == pytest.approx(ref.downtime_s)


def test_ablation_ordering_and_consistency():
    """Each ablated mechanism costs WAF; the triple ablation reproduces
    the megatron policy exactly (same detection+transition+replanning)."""
    from repro.core.traces import trace_b
    tasks, assignment = case5_tasks()
    trace = trace_b()
    full = TraceSimulator(tasks, list(assignment), "unicron").run(trace)
    triple = TraceSimulator(
        tasks, list(assignment), "unicron", ablate_detection=True,
        ablate_transition=True, ablate_replan=True).run(trace)
    meg = TraceSimulator(tasks, list(assignment), "megatron").run(trace)
    assert triple.accumulated_waf < full.accumulated_waf
    # triple-ablated unicron == megatron minus the hot spare (<1% apart)
    assert triple.accumulated_waf == pytest.approx(meg.accumulated_waf,
                                                   rel=1e-2)
    assert triple.accumulated_waf <= meg.accumulated_waf
