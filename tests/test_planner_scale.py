"""Vectorized planner-engine tests: the batched cost-model sweep, the
max-plus DP solver, and the incremental PlanTable must agree with the
scalar reference paths they replaced."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import costmodel, waf
from repro.core.costmodel import A800, TPU_V5E, TaskModel
from repro.core.planner import (PlanInput, PlannerCache, PlanTable,
                                _maxplus, _maxplus_vals,
                                _maxplus_vals_fused, brute_force, solve,
                                solve_reference)
from repro.core.waf import Task

SIZES = ["gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b"]


def _task(size="gpt3-1.3b", weight=1.0, gb=256, cap=None):
    return Task(model=TaskModel.from_arch(get_arch(size), global_batch=gb),
                weight=weight, max_workers=cap)


def _tasks(m, caps=None):
    return [_task(SIZES[i % len(SIZES)], weight=0.5 + 0.1 * i,
                  gb=128 if i % 2 else 256,
                  cap=caps[i] if caps else None) for i in range(m)]


def _inp(tasks, assignment, n, d_run=3600.0, d_tr=120.0, faulted=None):
    faulted = faulted or (False,) * len(tasks)
    return PlanInput(tuple(tasks), tuple(assignment), n, d_run, d_tr,
                     tuple(faulted))


# ---- (a) throughput_curve vs per-x scalar reference -----------------------


@pytest.mark.parametrize("hw", [A800, TPU_V5E], ids=lambda h: h.name)
@pytest.mark.parametrize("size", SIZES + ["gpt3-175b"])
def test_throughput_curve_matches_scalar(hw, size):
    t = TaskModel.from_arch(get_arch(size), seq_len=2048, global_batch=256)
    n = 192
    curve = costmodel.throughput_curve(t, n, hw)
    assert curve.flops.shape == (n + 1,)
    assert curve.flops[0] == 0.0
    for x in range(n + 1):
        ref = costmodel.achieved_flops(t, x, hw)
        assert curve.flops[x] == pytest.approx(ref, rel=1e-12, abs=0.0), x
        p = curve.plan(x)
        if ref == 0.0:
            assert p is None
        else:
            assert p is not None
            assert p.agg_flops == pytest.approx(ref, rel=1e-12)
            assert p.dp * p.tp * p.pp <= max(x, 0)
            assert p.mem_per_worker <= hw.hbm_bytes


@pytest.mark.parametrize("hw", [A800, TPU_V5E], ids=lambda h: h.name)
@pytest.mark.parametrize("size", ["gpt3-7b", "gpt3-175b"])
def test_min_feasible_matches_linear_scan(hw, size):
    t = TaskModel.from_arch(get_arch(size), global_batch=256)
    assert (costmodel.min_feasible_workers(t, hw)
            == costmodel.min_feasible_workers_reference(t, hw))


def test_curve_memoized_and_growable():
    t = TaskModel.from_arch(get_arch("gpt3-1.3b"), global_batch=256)
    small = costmodel.throughput_curve(t, 16, A800)
    big = costmodel.throughput_curve(t, 64, A800)
    assert np.array_equal(big.flops[:17], small.flops)
    again = costmodel.throughput_curve(t, 64, A800)
    assert again.flops is big.flops or np.shares_memory(again.flops,
                                                        big.flops)


def test_waf_curve_matches_scalar():
    t = _task("gpt3-7b", weight=1.3)
    n = 64
    F = waf.waf_curve(t, n, A800)
    for x in range(n + 1):
        assert F[x] == pytest.approx(waf.waf(t, x, A800), rel=1e-12, abs=0.0)


def test_reward_curve_matches_scalar():
    t = _task("gpt3-1.3b", weight=0.8)
    n = 48
    for faulted in (False, True):
        g = waf.reward_curve(t, 16, n, d_running=3600.0, d_transition=120.0,
                             worker_faulted=faulted, hw=A800)
        for k in range(n + 1):
            ref = waf.reward(t, 16, k, d_running=3600.0, d_transition=120.0,
                             worker_faulted=faulted, hw=A800)
            assert g[k] == pytest.approx(ref, rel=1e-12, abs=1e-9), (faulted, k)


# ---- (b) vectorized solve vs brute force / scalar DP ----------------------


def test_maxplus_matches_naive():
    rng = np.random.RandomState(0)
    for _ in range(50):
        n = rng.randint(0, 24)
        prev = rng.uniform(-5, 5, n + 1)
        g = rng.uniform(-5, 5, n + 1)
        out, ch = _maxplus(prev, g)
        for j in range(n + 1):
            vals = [prev[j - k] + g[k] for k in range(j + 1)]
            assert out[j] == max(vals)
            assert ch[j] == int(np.argmax(vals))


def test_solve_matches_brute_force_small():
    tasks = _tasks(3)
    for n, faulted in [(10, (False,) * 3), (12, (True, False, False))]:
        inp = _inp(tasks, [4, 4, 4], n, faulted=faulted)
        got = solve(inp, A800)
        want = brute_force(inp, A800)
        assert got.total_reward == pytest.approx(want.total_reward, rel=1e-9)
        assert sum(got.assignment) <= n


@pytest.mark.parametrize("m,n", [(4, 48), (8, 96)])
def test_solve_matches_scalar_dp_medium(m, n):
    tasks = _tasks(m)
    per = n // m
    for fi in (None, 0, m - 1):
        faulted = tuple(i == fi for i in range(m))
        inp = _inp(tasks, [per] * m, n - 8 if fi is not None else n,
                   faulted=faulted)
        got = solve(inp, A800)
        want = solve_reference(inp, A800)
        assert got.total_reward == pytest.approx(want.total_reward, rel=1e-9)
        assert got.assignment == want.assignment
        assert got.waf == pytest.approx(want.waf, rel=1e-9)


def test_solve_equals_reference_on_random_tables():
    """Hypothesis-free randomized sweep: the vectorized DP and the scalar
    DP are the same function on arbitrary (non-monotone) reward rows."""
    rng = np.random.RandomState(42)

    class _Row:
        max_workers = None              # Task contract: uncapped

        def __init__(self, row):
            self.row = row

        def necessary(self, hw):        # waf() sees an unmeetable floor
            return 10 ** 9              # -> cluster WAF contribution 0

    import repro.core.planner as planner_mod
    for trial in range(60):
        m = rng.randint(1, 5)
        n = rng.randint(0, 12)
        rows = rng.uniform(0, 100, (m, n + 1))
        inp = _inp([_Row(r) for r in rows], [0] * m, n)
        def table_row(i_, idx, hw):
            return list(rows[idx])

        orig = planner_mod._reward_row
        try:
            planner_mod._reward_row = table_row
            got = solve(inp, A800)
            want = solve_reference(inp, A800)
        finally:
            planner_mod._reward_row = orig
        assert got.total_reward == pytest.approx(want.total_reward,
                                                 rel=1e-12), trial
        assert got.assignment == want.assignment, trial


def test_fused_kernel_bitwise_identical_to_plain():
    """The tiled fused add+max kernel (both orientations) reduces exactly
    the candidate set of ``_maxplus_vals`` — outputs are bitwise equal."""
    rng = np.random.RandomState(3)
    for _ in range(120):
        n = rng.randint(0, 70)
        prev = rng.uniform(-5, 5, n + 1)
        g = rng.uniform(-5, 5, n + 1)
        want = _maxplus_vals(prev, g)
        assert np.array_equal(want, _maxplus_vals_fused(prev, g))
        assert np.array_equal(want, _maxplus_vals_fused(prev, g, block=4))


def test_banded_kernel_bitwise_identical_under_contract():
    """With monotone prev and g flat past the band — the invariants the
    planner guarantees — the banded kernel equals the dense one bitwise,
    at every band including 0 and n."""
    rng = np.random.RandomState(4)
    for _ in range(120):
        n = rng.randint(1, 70)
        cap = rng.randint(0, n + 1)
        prev = np.maximum.accumulate(rng.uniform(-5, 5, n + 1))
        g = rng.uniform(-5, 5, n + 1)
        g[cap:] = g[cap]
        want = _maxplus_vals(prev, g)
        assert np.array_equal(want, _maxplus_vals_fused(prev, g, band=cap))


def test_waf_flat_past_cap_matches_scalar():
    """Capped tasks: the vector F(t, ·) is flat past the cap and equal to
    the scalar ``waf`` (which clamps x) at every x — including a cap
    below the requirement floor (the task can then never run)."""
    for cap in (0, 4, 12, 64, None):
        t = _task("gpt3-7b", weight=1.1, cap=cap)
        F = waf.waf_curve(t, 96, A800)
        for x in range(97):
            assert F[x] == pytest.approx(waf.waf(t, x, A800),
                                         rel=1e-12, abs=0.0), (cap, x)
        if cap is not None and cap < 96:
            assert np.all(F[cap:] == F[min(cap, 96)])
    M = waf.waf_matrix([_task(cap=8), _task("gpt3-7b", cap=2)], 64, A800)
    for i, t in enumerate([_task(cap=8), _task("gpt3-7b", cap=2)]):
        for x in range(65):
            assert M[i, x] == pytest.approx(waf.waf(t, x, A800),
                                            rel=1e-12, abs=0.0)


# ---- (c) incremental PlanTable vs scenario-by-scenario solves -------------


@pytest.mark.parametrize("m,n", [(1, 8), (3, 36), (6, 96)])
def test_incremental_table_matches_full_solves(m, n):
    tasks = _tasks(m)
    assignment = [n // m] * m
    inc = PlanTable(tasks, assignment, A800, 3600.0, 120.0)
    ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    incremental=False, solver=solve_reference)
    assert set(inc.table) == set(ref.table)
    n_now = sum(assignment)
    for key in ref.table:
        a, b = inc.table[key], ref.table[key]
        assert a.total_reward == pytest.approx(b.total_reward,
                                               rel=1e-9), key
        budget = {"join:1": n_now + inc.workers_per_fault}.get(
            key, n_now if key.startswith("finish") else
            max(n_now - inc.workers_per_fault, 0))
        assert sum(a.assignment) <= budget, (key, a)
        expect_len = m - 1 if key.startswith("finish") else m
        assert len(a.assignment) == expect_len


def test_empty_task_set_table():
    table = PlanTable([], [], A800, 3600.0, 120.0)
    ref = PlanTable([], [], A800, 3600.0, 120.0, incremental=False)
    assert set(table.table) == set(ref.table) == {"join:1"}
    assert table.table["join:1"].assignment == ()
    assert table.table["join:1"].total_reward == 0.0


def test_incremental_table_dispatch_is_constant_time():
    tasks = _tasks(4)
    table = PlanTable(tasks, [8, 8, 8, 8], A800, 3600.0, 120.0)
    assert table.lookup("fault:0") is not None
    assert table.lookup("join:1") is not None
    assert table.lookup("finish:3") is not None
    assert table.lookup("nonsense") is None


def test_solve_fast_identical_to_solve():
    """The cached engine's fresh-dispatch solver is the same function as
    ``solve`` — identical assignments AND rewards, bit for bit."""
    from repro.core.planner import solve_fast
    for m, n in [(1, 8), (4, 48), (8, 96)]:
        tasks = _tasks(m)
        for fi in (None, 0, m - 1):
            faulted = tuple(i == fi for i in range(m))
            inp = _inp(tasks, [n // m] * m, n, faulted=faulted)
            a, b = solve(inp, A800), solve_fast(inp, A800)
            assert a.assignment == b.assignment
            assert a.total_reward == b.total_reward


# ---- (d) lazy / cross-rebuild-cached PlanTable ----------------------------


def test_lazy_cached_table_identical_to_eager():
    """Every scenario assembled lazily through a shared PlannerCache is
    bit-identical (assignment AND reward) to the eager uncached build."""
    tasks = _tasks(6)
    cache = PlannerCache()
    assignment = [16, 16, 16, 24, 24, 32]
    for budget in (None, 160):
        eager = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                          n_budget=budget)
        lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                           n_budget=budget)
        assert not lazy.table                 # nothing assembled yet
        for key in eager.table:
            a, b = eager.table[key], lazy.lookup(key)
            assert a.assignment == b.assignment, key
            assert a.total_reward == b.total_reward, key
    # recurring state: the cache returns the same (now warm) table object
    again = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                        n_budget=160)
    assert again.lookup("fault:0") is lazy.lookup("fault:0")
    assert cache.stats()["hits"]["tables"] >= 1


def test_cached_table_matches_reference_under_random_churn():
    """Deterministic churn walk: one task's assignment changes per step
    (the cross-rebuild chain-reuse case), and every scenario of every
    intermediate state must match the all-scalar reference table."""
    import random

    rng = random.Random(0)
    m, n_budget = 3, 28
    tasks = _tasks(m)
    cache = PlannerCache()
    assignment = [8, 8, 8]
    for step in range(6):
        lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                           workers_per_fault=4, n_budget=n_budget)
        ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                        workers_per_fault=4, incremental=False,
                        solver=solve_reference)
        for key in ref.table:
            got = lazy.lookup(key)
            want = ref.table[key]
            assert got.total_reward == pytest.approx(
                want.total_reward, rel=1e-9), (step, key, assignment)
        i = rng.randrange(m)
        assignment[i] = rng.choice([4, 8, 12, 16])
    stats = cache.stats()
    assert stats["hits"]["arrays"] > 0        # chains were reused


# ---- (e) segment-tree engine ----------------------------------------------


@pytest.mark.parametrize("engine", ["segtree", "batched"])
@pytest.mark.parametrize("m,n,caps", [
    (1, 8, [None]), (2, 16, [6, None]), (3, 36, [10, None, 8]),
    (5, 60, [12, 12, None, 4, 50]), (6, 96, [None] * 6)])
def test_segtree_table_matches_reference(m, n, caps, engine):
    """Tree-based tables (per-node segtree and the default
    level-synchronous batched engine) match the all-scalar reference on
    capped and uncapped fleets, with feasible tracebacks: the traced
    assignment's scalar reward re-sums to the DP total."""
    tasks = _tasks(m, caps=caps)
    assignment = [n // m] * m
    seg = PlanTable(tasks, assignment, A800, 3600.0, 120.0, engine=engine)
    assert seg.engine == engine
    ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    incremental=False, solver=solve_reference)
    assert set(seg.table) == set(ref.table)
    n_now = sum(assignment)
    w = seg.workers_per_fault
    for key in ref.table:
        a, b = seg.table[key], ref.table[key]
        assert a.total_reward == pytest.approx(b.total_reward,
                                               rel=1e-9), key
        budget = {"join:1": n_now + w}.get(
            key, n_now if key.startswith("finish")
            else max(n_now - w, 0))
        assert sum(a.assignment) <= budget, (key, a)
        # traceback consistency: re-score the plan with the scalar reward
        kind, _, idx = key.partition(":")
        if kind == "finish":
            rem = [(t, assignment[i]) for i, t in enumerate(tasks)
                   if i != int(idx)]
        else:
            rem = list(zip(tasks, assignment))
        total = sum(waf.reward(
            t, x_old, x_new, d_running=3600.0, d_transition=120.0,
            worker_faulted=(kind == "fault" and i == int(idx)), hw=A800)
            for i, ((t, x_old), x_new) in enumerate(zip(rem, a.assignment)))
        assert total == pytest.approx(a.total_reward, rel=1e-9), key


def test_segtree_lazy_cached_identical_to_eager():
    """Lazy cache-assembled segment-tree scenarios are bit-identical to
    the eager uncached build (same node merges, same kernel)."""
    tasks = _tasks(5, caps=[8, None, 12, None, 6])
    cache = PlannerCache()
    assignment = [12, 12, 12, 12, 12]
    eager = PlanTable(tasks, assignment, A800, 3600.0, 120.0)
    lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0)
    for key in eager.table:
        got = lazy.lookup(key)
        assert got.assignment == eager.table[key].assignment, key
        assert got.total_reward == eager.table[key].total_reward, key


def test_segtree_and_chain_engines_agree():
    """Both incremental engines implement the same optimum: totals agree
    to float-reassociation tolerance on every scenario."""
    tasks = _tasks(7, caps=[16, None, 8, 24, None, 12, 16])
    assignment = [12] * 7
    seg = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    engine="segtree")
    chain = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                      engine="chain")
    assert set(seg.table) == set(chain.table)
    for key in seg.table:
        assert seg.table[key].total_reward == pytest.approx(
            chain.table[key].total_reward, rel=1e-9), key


@pytest.mark.parametrize("engine", ["segtree", "batched"])
def test_segtree_cached_churn_reuses_log_m_nodes(engine):
    """A one-task churn step through a shared cache recomputes only the
    O(log m) tree nodes whose span contains the change (plus the
    complements crossing them) — most array lookups are hits.  Holds for
    the per-node segtree engine and the level-synchronous batched one
    (same content-keyed node/complement cache entries)."""
    m = 8
    tasks = _tasks(m, caps=[12] * m)
    cache = PlannerCache()
    assignment = [8] * m
    t1 = cache.table(tasks, assignment, A800, 3600.0, 120.0, n_budget=80,
                     engine=engine)
    for key in t1.scenario_keys():
        t1.lookup(key)
    before = dict(cache.misses)
    assignment[3] = 12
    t2 = cache.table(tasks, assignment, A800, 3600.0, 120.0, n_budget=80,
                     engine=engine)
    for key in t2.scenario_keys():
        t2.lookup(key)
    new_arrays = cache.misses["arrays"] - before["arrays"]
    # full from-scratch assembly costs > 3 arrays per scenario; the
    # cached rebuild must reuse far more than it recomputes
    assert new_arrays < 2 * len(t2.scenario_keys()), new_arrays
    ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    incremental=False, solver=solve_reference)
    for key in ref.table:
        assert t2.lookup(key).total_reward == pytest.approx(
            ref.table[key].total_reward, rel=1e-9), key


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        PlanTable(_tasks(1), [4], A800, 3600.0, 120.0, engine="btree")


# ---- (f) level-synchronous batched engine ---------------------------------


def test_batched_kernel_bitwise_identical_per_slice():
    """The stacked kernel with per-row bands equals per-slice 2-D fused
    calls bitwise, across mixed dense/banded rows and every strategy
    bucket (shift-slab stacks and per-row tile fallthrough)."""
    from repro.core.planner import _maxplus_vals_fused_batched
    rng = np.random.RandomState(7)
    for _ in range(120):
        B = rng.randint(1, 10)
        n = rng.randint(0, 70)
        prev = np.maximum.accumulate(rng.uniform(-5, 5, (B, n + 1)),
                                     axis=1)
        g = rng.uniform(-5, 5, (B, n + 1))
        bands = []
        for r in range(B):
            b = rng.choice([None, rng.randint(0, n + 1)])
            if b is not None:
                b = int(b)
                g[r, b:] = g[r, min(b, n)]
            bands.append(b)
        out = _maxplus_vals_fused_batched(prev, g, bands)
        for r in range(B):
            want = _maxplus_vals_fused(prev[r], g[r], band=bands[r])
            assert np.array_equal(out[r], want), (B, n, bands, r)


@pytest.mark.parametrize("m,n,caps", [
    (1, 8, [None]), (3, 36, [10, None, 8]), (6, 96, [12] * 6),
    (7, 96, [16, None, 8, 24, None, 12, 16])])
def test_batched_engine_bitwise_identical_to_segtree(m, n, caps):
    """The level-synchronous engine stacks exactly the segtree's node
    merges (same operands, orders and bands), so eager tables agree
    bit for bit — totals AND assignments."""
    tasks = _tasks(m, caps=caps)
    assignment = [n // m] * m
    bat = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    engine="batched")
    seg = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    engine="segtree")
    assert set(bat.table) == set(seg.table)
    for key in seg.table:
        assert bat.table[key].total_reward == seg.table[key].total_reward
        assert bat.table[key].assignment == seg.table[key].assignment
        assert bat.table[key].waf == seg.table[key].waf


def test_batched_value_only_rebuild_with_lazy_traceback():
    """``rebuild_values`` materializes every scenario's total with ZERO
    tracebacks; a subsequent ``lookup`` runs exactly one traceback for
    the dispatched key and its plan matches the eager build bitwise."""
    tasks = _tasks(5, caps=[8, None, 12, None, 6])
    assignment = [12] * 5
    cache = PlannerCache()
    eager = PlanTable(tasks, assignment, A800, 3600.0, 120.0)
    lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0)
    totals = lazy.rebuild_values()
    assert lazy.batch_stats["tracebacks"] == 0
    assert not lazy.table                    # values only, no Plans yet
    assert set(totals) == set(eager.table)
    for key, total in totals.items():
        assert total == eager.table[key].total_reward, key
        assert lazy.scenario_total(key) == total, key
    plan = lazy.lookup("fault:2")
    assert lazy.batch_stats["tracebacks"] == 1
    assert plan.assignment == eager.table["fault:2"].assignment
    assert plan.total_reward == eager.table["fault:2"].total_reward
    # memoized Plan: a second lookup is a dict hit, not a new traceback
    assert lazy.lookup("fault:2") is plan
    assert lazy.batch_stats["tracebacks"] == 1


@pytest.mark.parametrize("m", [1, 2, 5, 8, 16])
def test_batched_rebuild_is_constant_launches_per_level(m):
    """A whole-table rebuild issues O(log m) stacked launches (leaf pass
    + one per tree level up, one per complement level down, one fault
    stack), NOT O(m log m) per-merge kernel calls."""
    import math
    tasks = _tasks(m, caps=[12] * m)
    table = PlanTable(tasks, [8] * m, A800, 3600.0, 120.0,
                      engine="batched")
    depth = max(1, math.ceil(math.log2(m))) if m > 1 else 0
    assert table.batch_stats["launches"] <= 2 * depth + 1
    # eager build materializes every scenario plan via lazy traceback
    assert table.batch_stats["tracebacks"] == len(table.scenario_keys())
    if m > 1:
        assert table.batch_stats["levels"] >= 2


def test_planner_cache_prebuild_runs_value_rebuild():
    """``PlannerCache.table(prebuild=True)`` returns a table whose whole
    -table value sweep already ran (totals memoized, no tracebacks), and
    the memoized table comes back warm on a recurring state."""
    tasks = _tasks(4, caps=[10, None, 8, 12])
    assignment = [10, 10, 10, 10]
    cache = PlannerCache()
    table = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                        prebuild=True)
    assert table.batch_stats["launches"] >= 1
    assert table.batch_stats["tracebacks"] == 0
    launches = table.batch_stats["launches"]
    eager = PlanTable(tasks, assignment, A800, 3600.0, 120.0)
    for key in table.scenario_keys():
        assert table.scenario_total(key) == eager.table[key].total_reward
    assert table.batch_stats["launches"] == launches   # sweep was done
    again = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                        prebuild=True)                 # idempotent on hit
    assert again is table
    assert again.batch_stats["launches"] == launches


# ---- (g) fused one-program engine -----------------------------------------


@pytest.mark.parametrize("m,n,caps", [
    (1, 8, [None]), (3, 36, [10, None, 8]), (6, 96, [12] * 6),
    (7, 96, [16, None, 8, 24, None, 12, 16])])
def test_fused_engine_bitwise_identical_to_batched(m, n, caps):
    """The fused one-program engine reduces exactly the batched engine's
    candidate sets (chunked, scatter-max merged, f64 on device), so eager
    tables agree bit for bit — totals, assignments AND WAF."""
    tasks = _tasks(m, caps=caps)
    assignment = [n // m] * m
    fus = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    engine="fused")
    bat = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    engine="batched")
    assert set(fus.table) == set(bat.table)
    for key in bat.table:
        assert fus.table[key].total_reward == bat.table[key].total_reward
        assert fus.table[key].assignment == bat.table[key].assignment
        assert fus.table[key].waf == bat.table[key].waf


def test_fused_table_matches_reference():
    """Fused-engine scenario totals against the all-scalar
    ``solve_reference`` table on a capped fleet (f32 tolerance when the
    pallas backend is active — the CI leg's configuration)."""
    from repro.core.planner import get_maxplus_backend
    tol = 1e-5 if get_maxplus_backend() == "pallas" else 1e-9
    tasks = _tasks(3, caps=[10, None, 8])
    assignment = [12, 12, 12]
    fus = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    engine="fused")
    ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    incremental=False, solver=solve_reference)
    assert set(fus.table) == set(ref.table)
    for key in ref.table:
        assert fus.table[key].total_reward == pytest.approx(
            ref.table[key].total_reward, rel=tol), key


def test_fused_whole_table_single_dispatch():
    """A whole-table rebuild on the fused engine is exactly ONE device
    dispatch — every scenario total materialized, zero tracebacks, zero
    stacked launches — and repeating it on the warm table dispatches
    nothing new.  Lookups afterwards stay host-side."""
    tasks = _tasks(5, caps=[8, None, 12, None, 6])
    assignment = [12] * 5
    cache = PlannerCache()
    lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                       engine="fused")
    assert lazy.batch_stats["device_dispatches"] == 0
    totals = lazy.rebuild_values()
    assert lazy.batch_stats["device_dispatches"] == 1
    assert lazy.batch_stats["launches"] == 0
    assert lazy.batch_stats["tracebacks"] == 0
    assert not lazy.table                    # values only, no Plans yet
    eager = PlanTable(tasks, assignment, A800, 3600.0, 120.0)
    assert set(totals) == set(eager.table)
    for key, total in totals.items():
        assert total == eager.table[key].total_reward, key
    lazy.rebuild_values()                    # idempotent on a warm table
    assert lazy.batch_stats["device_dispatches"] == 1
    plan = lazy.lookup("fault:2")            # traceback is host-side
    assert lazy.batch_stats["device_dispatches"] == 1
    assert lazy.batch_stats["tracebacks"] == 1
    assert plan.assignment == eager.table["fault:2"].assignment
    assert plan.total_reward == eager.table["fault:2"].total_reward


def test_fused_same_signature_churn_no_retrace():
    """Cap-constrained churn keeps the schedule signature fixed, so the
    whole walk runs ONE cached program — a single trace, one execution
    per distinct state, no program-cache growth past the first build."""
    import repro.core.planner as planner_mod
    m = 6
    tasks = _tasks(m, caps=[12] * m)
    cache = PlannerCache()
    states = [[8] * m, [8, 12, 8, 4, 8, 8], [4, 12, 8, 4, 12, 8],
              [12] * m, [4, 4, 8, 12, 8, 4]]
    sig = None
    prog = None
    dispatches = 0
    for a in states:
        table = cache.table(tasks, a, A800, 3600.0, 120.0, n_budget=80,
                            engine="fused")
        before = table.batch_stats["device_dispatches"]
        table.rebuild_values()
        dispatches += table.batch_stats["device_dispatches"] - before
        if sig is None:
            sig = table._fused_signature()
            prog = planner_mod._FUSED_PROGRAMS[sig]
        else:
            # caps bound every draw, so bands — hence the signature, and
            # with it the compiled program — never change across the walk
            assert table._fused_signature() == sig
            assert planner_mod._FUSED_PROGRAMS[sig] is prog
    assert dispatches == len(states)
    assert prog.calls >= len(states)
    # ONE trace for the whole walk (-1 only if this jax cannot report it)
    assert prog.traces() in (-1, 1)


def test_fused_engine_pallas_backend_matches_reference():
    """engine="fused" under REPRO_PLANNER_BACKEND=pallas (via the
    setter): the f32 scan-chunk kernel becomes the inner step and the
    table must match the all-scalar reference to f32 tolerance — the
    combination CI pins under REPRO_PALLAS_INTERPRET=1."""
    from repro.core.planner import set_maxplus_backend
    tasks = _tasks(2, caps=[8, None])
    ref = PlanTable(tasks, [8, 16], A800, 3600.0, 120.0,
                    incremental=False, solver=solve_reference)
    set_maxplus_backend("pallas")
    try:
        fus = PlanTable(tasks, [8, 16], A800, 3600.0, 120.0,
                        engine="fused")
    finally:
        set_maxplus_backend(None)
    assert set(fus.table) == set(ref.table)
    for key in ref.table:
        a, b = fus.table[key], ref.table[key]
        rel = abs(a.total_reward - b.total_reward) / max(
            1.0, abs(b.total_reward))
        assert rel < 1e-5, (key, rel)
    assert fus.batch_stats["device_dispatches"] == 1


def test_batched_scenario_total_value_only():
    """``scenario_total`` never materializes assignments and agrees with
    the reference solver's totals; unknown keys return None."""
    tasks = _tasks(3, caps=[10, None, 8])
    assignment = [12, 12, 12]
    cache = PlannerCache()
    lazy = cache.table(tasks, assignment, A800, 3600.0, 120.0)
    ref = PlanTable(tasks, assignment, A800, 3600.0, 120.0,
                    incremental=False, solver=solve_reference)
    for key in ref.table:
        got = lazy.scenario_total(key)
        assert got == pytest.approx(ref.table[key].total_reward,
                                    rel=1e-9), key
    assert lazy.scenario_total("nonsense") is None
    assert lazy.scenario_total("fault:99") is None
    assert lazy.batch_stats["tracebacks"] == 0
    assert not lazy.table
