"""Hierarchical checkpointing + nearest-principle state migration (§6.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.inmemory import InMemoryStore
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint import persistent
from repro.core.transition import (estimate_baseline, estimate_unicron,
                                   migrate_seconds, migration_source)


@pytest.fixture
def state():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (8, 8)),
            "b": jnp.arange(8, dtype=jnp.float32)}


def _close(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y))


def test_persistent_roundtrip(tmp_path, state):
    persistent.save(str(tmp_path), 7, state)
    assert persistent.latest_step(str(tmp_path)) == 7
    got = persistent.restore(str(tmp_path), state)
    _close(got, state)


def test_latest_step_survives_torn_marker_and_tmp_leftovers(tmp_path,
                                                           state):
    """A crash between archive write and marker write (or mid-marker)
    must not lose the newest complete checkpoint (ISSUE 10 satellite)."""
    d = str(tmp_path)
    assert persistent.latest_step(d) is None          # empty directory
    persistent.save(d, 3, state)
    persistent.save(d, 12, state)

    # torn marker: empty file
    (tmp_path / "latest").write_text("")
    assert persistent.latest_step(d) == 12
    # torn marker: garbage bytes
    (tmp_path / "latest").write_text("12\x0034garbage")
    assert persistent.latest_step(d) == 12
    # marker points at a step whose archive never landed
    (tmp_path / "latest").write_text("99")
    assert persistent.latest_step(d) == 12
    # marker deleted entirely
    (tmp_path / "latest").unlink()
    assert persistent.latest_step(d) == 12

    # stray in-flight tmp archive from a dead writer is not a candidate
    (tmp_path / "ckpt_00000050.npz.tmp.npz").write_bytes(b"partial")
    (tmp_path / "ckpt_garbage.npz").write_bytes(b"junk")
    assert persistent.latest_step(d) == 12
    got = persistent.restore(d, state)
    _close(got, state)


def test_inmemory_ring_replication(state):
    store = InMemoryStore(n_ranks=4)
    store.put("t", 1, step=5, tree=state)
    step, snap, src = store.get("t", 1)
    assert (step, src) == (5, "inmemory_local")
    # rank 1's snapshot is replicated on neighbor rank 2
    store.drop_rank("t", 1)
    hit = store.get("t", 1)
    assert hit is not None and hit[2] == "inmemory_replica"
    _close(hit[1], state)


def test_nearest_principle_ordering(tmp_path, state):
    """DP replica beats in-memory beats persistent."""
    mgr = CheckpointManager(str(tmp_path), n_ranks=4, persist_every=1,
                            task="gpt-7b")
    mgr.save(rank=0, step=3, state=state)
    # keyed by the real task id, not a hardcoded constant
    assert mgr.store.get("gpt-7b", 0) is not None
    assert mgr.store.get("task", 0) is None

    peer = jax.tree.map(lambda x: x + 1, state)
    got, step, src = mgr.restore(0, state, dp_peer_state=peer, peer_step=4)
    assert src == "dp_replica" and step == 4
    _close(got, peer)

    got, step, src = mgr.restore(0, state)
    assert src == "inmemory_local" and step == 3

    mgr.store.drop_rank(mgr.task, 0)
    mgr.store.drop_rank(mgr.task, mgr.store.neighbor(0))
    got, step, src = mgr.restore(0, state)
    assert src == "persistent" and step == 3
    _close(got, state)


def test_restore_without_any_source(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), n_ranks=2, task="empty")
    with pytest.raises(FileNotFoundError):
        mgr.restore(0, state)


def test_migration_source_selection():
    assert migration_source(dp_degree=4, inmemory_available=False) == \
        "dp_replica"
    assert migration_source(dp_degree=1, inmemory_available=True) == \
        "inmemory"
    assert migration_source(dp_degree=1, inmemory_available=False) == \
        "persistent"


def test_migrate_seconds_tier_ordering():
    b = 100e9
    assert migrate_seconds(b, "dp_replica") < migrate_seconds(b, "inmemory")
    assert migrate_seconds(b, "inmemory") <= migrate_seconds(b, "persistent")


def test_transition_cost_figure9_ordering():
    """Unicron < Oobleck/Bamboo (dynamic reconfig) < Megatron/Varuna
    (checkpoint restart) — Fig. 9's qualitative result."""
    state_bytes = 16.0 * 7e9            # GPT-3 7B
    uni = estimate_unicron(state_bytes, avg_iter_s=30.0, dp_degree=4,
                           detect_s=1.8)
    dyn = estimate_baseline(state_bytes, detect_s=1800.0,
                            dynamic_reconfig=True, ckpt_restart=False)
    ckpt = estimate_baseline(state_bytes, detect_s=1800.0,
                             dynamic_reconfig=False, ckpt_restart=True)
    assert uni.total < dyn.total < ckpt.total
    # paper figure-2 magnitude: baseline restart ~ an hour
    assert ckpt.total > 45 * 60


def test_unicron_partial_result_recompute_bounded():
    """Partial-result reuse keeps recompute below one iteration."""
    c = estimate_unicron(1e9, avg_iter_s=60.0, dp_degree=8, detect_s=0.3)
    assert c.recompute_s <= 60.0


# ---- GEMINI preference order through the agent recovery path (§6.3) -------


def test_agent_recovers_local_first(state):
    from repro.core.agent import UnicronAgent
    store = InMemoryStore(n_ranks=4)
    store.put("t", 1, step=9, tree=state)
    agent = UnicronAgent(1, None, n_gpus=4)     # kv unused on this path
    got, step, src = agent.recover_checkpoint(store, "t", 1)
    assert (step, src) == (9, "inmemory_local")
    _close(got, state)


def test_agent_recovers_neighbor_replica_then_persistent(tmp_path, state):
    from repro.checkpoint import persistent as pt
    from repro.core.agent import UnicronAgent
    store = InMemoryStore(n_ranks=4)
    store.put("t", 1, step=9, tree=state)
    pt.save(str(tmp_path), 7, state)
    agent = UnicronAgent(1, None, n_gpus=4)
    # host 1 dies: its local copy is gone, neighbor (rank 2) holds it
    store.drop_rank("t", 1)
    got, step, src = agent.recover_checkpoint(store, "t", 1,
                                              persist_dir=str(tmp_path))
    assert (step, src) == (9, "inmemory_replica")
    _close(got, state)
    # neighbor also lost: only the persistent tier remains (older step)
    store.drop_rank("t", store.neighbor(1))
    got, step, src = agent.recover_checkpoint(store, "t", 1,
                                              persist_dir=str(tmp_path),
                                              template=state)
    assert (step, src) == (7, "persistent")
    _close(got, state)


def test_agent_recover_no_tier_raises(state):
    from repro.core.agent import UnicronAgent
    agent = UnicronAgent(0, None, n_gpus=4)
    with pytest.raises(FileNotFoundError):
        agent.recover_checkpoint(InMemoryStore(n_ranks=2), "t", 0)


def test_drop_rank_hosting_anothers_replica(state):
    """Losing host 2 also loses rank *1*'s replica (held ON host 2), but
    rank 1 still recovers from its own local copy; rank 2 recovers from
    its replica on host 3."""
    store = InMemoryStore(n_ranks=4)
    store.put("t", 1, step=5, tree=state)       # replica lands on host 2
    store.put("t", 2, step=6, tree=state)       # replica lands on host 3
    store.drop_rank("t", 2)
    hit1 = store.get("t", 1)
    assert hit1 is not None and hit1[2] == "inmemory_local"
    hit2 = store.get("t", 2)
    assert hit2 is not None and hit2[2] == "inmemory_replica"
    # now rank 1's host dies too: local gone AND its replica died with
    # host 2 earlier -> nothing left for rank 1
    store.drop_rank("t", 1)
    assert store.get("t", 1) is None
