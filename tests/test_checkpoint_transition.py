"""Hierarchical checkpointing + nearest-principle state migration (§6.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.inmemory import InMemoryStore
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint import persistent
from repro.core.transition import (estimate_baseline, estimate_unicron,
                                   migrate_seconds, migration_source)


@pytest.fixture
def state():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (8, 8)),
            "b": jnp.arange(8, dtype=jnp.float32)}


def _close(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y))


def test_persistent_roundtrip(tmp_path, state):
    persistent.save(str(tmp_path), 7, state)
    assert persistent.latest_step(str(tmp_path)) == 7
    got = persistent.restore(str(tmp_path), state)
    _close(got, state)


def test_inmemory_ring_replication(state):
    store = InMemoryStore(n_ranks=4)
    store.put("t", 1, step=5, tree=state)
    step, snap, src = store.get("t", 1)
    assert (step, src) == (5, "inmemory_local")
    # rank 1's snapshot is replicated on neighbor rank 2
    store.drop_rank("t", 1)
    hit = store.get("t", 1)
    assert hit is not None and hit[2] == "inmemory_replica"
    _close(hit[1], state)


def test_nearest_principle_ordering(tmp_path, state):
    """DP replica beats in-memory beats persistent."""
    mgr = CheckpointManager(str(tmp_path), n_ranks=4, persist_every=1)
    mgr.save(rank=0, step=3, state=state)

    peer = jax.tree.map(lambda x: x + 1, state)
    got, step, src = mgr.restore(0, state, dp_peer_state=peer, peer_step=4)
    assert src == "dp_replica" and step == 4
    _close(got, peer)

    got, step, src = mgr.restore(0, state)
    assert src == "inmemory_local" and step == 3

    mgr.store.drop_rank("task", 0)
    mgr.store.drop_rank("task", mgr.store.neighbor(0))
    got, step, src = mgr.restore(0, state)
    assert src == "persistent" and step == 3
    _close(got, state)


def test_restore_without_any_source(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), n_ranks=2)
    with pytest.raises(FileNotFoundError):
        mgr.restore(0, state)


def test_migration_source_selection():
    assert migration_source(dp_degree=4, inmemory_available=False) == \
        "dp_replica"
    assert migration_source(dp_degree=1, inmemory_available=True) == \
        "inmemory"
    assert migration_source(dp_degree=1, inmemory_available=False) == \
        "persistent"


def test_migrate_seconds_tier_ordering():
    b = 100e9
    assert migrate_seconds(b, "dp_replica") < migrate_seconds(b, "inmemory")
    assert migrate_seconds(b, "inmemory") <= migrate_seconds(b, "persistent")


def test_transition_cost_figure9_ordering():
    """Unicron < Oobleck/Bamboo (dynamic reconfig) < Megatron/Varuna
    (checkpoint restart) — Fig. 9's qualitative result."""
    state_bytes = 16.0 * 7e9            # GPT-3 7B
    uni = estimate_unicron(state_bytes, avg_iter_s=30.0, dp_degree=4,
                           detect_s=1.8)
    dyn = estimate_baseline(state_bytes, detect_s=1800.0,
                            dynamic_reconfig=True, ckpt_restart=False)
    ckpt = estimate_baseline(state_bytes, detect_s=1800.0,
                             dynamic_reconfig=False, ckpt_restart=True)
    assert uni.total < dyn.total < ckpt.total
    # paper figure-2 magnitude: baseline restart ~ an hour
    assert ckpt.total > 45 * 60


def test_unicron_partial_result_recompute_bounded():
    """Partial-result reuse keeps recompute below one iteration."""
    c = estimate_unicron(1e9, avg_iter_s=60.0, dp_degree=8, detect_s=0.3)
    assert c.recompute_s <= 60.0
