"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd
from repro.kernels.ssd_scan import ssd_scan_fwd

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # B, Sq, Sk, H, KV, D, causal, window, softcap
    (2, 128, 128, 4, 2, 64, True, 0, 0.0),      # GQA causal
    (1, 100, 100, 4, 1, 32, True, 0, 0.0),      # MQA, ragged seq
    (2, 64, 64, 8, 8, 16, True, 16, 0.0),       # sliding window
    (1, 256, 256, 2, 2, 64, False, 0, 0.0),     # bidirectional (hubert)
    (1, 96, 96, 4, 2, 64, True, 0, 30.0),       # logit softcap (gemma)
    (1, 64, 192, 2, 2, 32, True, 0, 0.0),       # cross-length (q_offset)
]


@pytest.mark.parametrize(
    "B,Sq,Sk,H,KV,D,causal,window,softcap", ATTN_CASES)
def test_flash_attention_matches_oracle(B, Sq, Sk, H, KV, D, causal,
                                        window, softcap):
    ks = jax.random.split(jax.random.fold_in(KEY, Sq * Sk + H), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, KV, D))
    v = jax.random.normal(ks[2], (B, Sk, KV, D))
    off = Sk - Sq
    got = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              softcap=softcap, q_offset=off,
                              block_q=32, block_k=32)
    want = ref.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=off)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 32)).astype(dtype)
    got = flash_attention_fwd(q, k, v, block_q=32, block_k=32)
    want = ref.flash_attention(q, k, v)
    assert got.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 64), (128, 128)])
def test_flash_attention_block_shape_invariance(block_q, block_k):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 80, 2, 32))
    k = jax.random.normal(ks[1], (1, 80, 2, 32))
    v = jax.random.normal(ks[2], (1, 80, 2, 32))
    got = flash_attention_fwd(q, k, v, block_q=block_q, block_k=block_k)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_grad_matches_oracle_grad():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 48, 2, 16))
    k = jax.random.normal(ks[1], (1, 48, 2, 16))
    v = jax.random.normal(ks[2], (1, 48, 2, 16))
    g1 = jax.grad(lambda q: jnp.sum(ops.flash_attention(q, k, v) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(ref.flash_attention(q, k, v) ** 2))(q)
    np.testing.assert_allclose(g1, g2, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# SSD chunk scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # B, S, H, P, G, N, chunk
    (2, 64, 4, 16, 1, 8, 16),
    (1, 100, 2, 32, 1, 16, 32),      # ragged
    (1, 128, 4, 8, 2, 8, 128),       # multi-group, single chunk
    (2, 37, 2, 8, 1, 4, 16),         # S < 2 chunks, ragged
]


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", SSD_CASES)
def test_ssd_scan_matches_oracle(B, S, H, P, G, N, chunk):
    ks = jax.random.split(jax.random.fold_in(KEY, S * H + P), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y, fin = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk)
    yr, finr = ref.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(fin, finr, atol=1e-4, rtol=1e-4)


def test_ssd_scan_matches_serial_recurrence():
    """Second-level oracle: token-serial SSM recurrence."""
    from repro.models.ssm import ssd_decode_step
    B, S, H, P, N = 1, 24, 2, 4, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[4], (B, S, 1, N))
    y, fin = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=8)
    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        yt, state = ssd_decode_step(state, x[:, t], dt[:, t], A,
                                    Bm[:, t], Cm[:, t])
        ys.append(yt)
    y_serial = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y, y_serial, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(fin, state, atol=1e-4, rtol=1e-4)


def test_ssd_scan_chunk_invariance():
    B, S, H, P, N = 1, 96, 2, 8, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[4], (B, S, 1, N))
    y16, _ = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=16)
    y48, _ = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=48)
    np.testing.assert_allclose(y16, y48, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused RMSNorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 32), (2, 17, 96), (1, 5, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_oracle(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape).astype(dtype)
    s = jax.random.normal(ks[1], (shape[-1],)).astype(dtype)
    got = rmsnorm_fwd(x, s, block_rows=8)
    want = ref.rmsnorm(x, s)
    assert got.dtype == dtype
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=2e-2
                               if dtype == jnp.bfloat16 else 1e-5)


def test_rmsnorm_grad():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (6, 32))
    s = jax.random.normal(ks[1], (32,))
    g1 = jax.grad(lambda x, s: jnp.sum(ops.rmsnorm(x, s) ** 2), (0, 1))(x, s)
    g2 = jax.grad(lambda x, s: jnp.sum(ref.rmsnorm(x, s) ** 2), (0, 1))(x, s)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# max-plus convolution (planner DP kernel)
# ---------------------------------------------------------------------------


def _maxplus_case(seed, monotone=False, cap=None):
    rng = np.random.RandomState(seed)
    n = rng.randint(0, 200)
    prev = rng.uniform(-50.0, 50.0, n + 1)
    if monotone:
        prev = np.maximum.accumulate(prev)
    g = rng.uniform(-50.0, 50.0, n + 1)
    band = None
    if cap is not None:
        band = min(cap, n)
        g[band:] = g[band]
    return prev, g, band


@pytest.mark.parametrize("seed", range(8))
def test_maxplus_dense_matches_numpy_oracle(seed):
    """Pallas maxplus (interpret off-TPU) == the f32 numpy oracle with the
    kernel's candidate arithmetic, dense band."""
    from repro.kernels.maxplus import maxplus_conv, maxplus_conv_np
    prev, g, _ = _maxplus_case(seed)
    got = np.asarray(maxplus_conv(prev, g))
    want = maxplus_conv_np(prev, g)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("seed,cap", [(0, 0), (1, 1), (2, 7), (3, 32),
                                      (4, 100)])
def test_maxplus_banded_matches_dense(seed, cap):
    """Under the band contract (monotone prev, g flat past the band) the
    banded kernel equals the dense convolution."""
    from repro.kernels.maxplus import maxplus_conv, maxplus_conv_np
    prev, g, band = _maxplus_case(seed, monotone=True, cap=cap)
    got = np.asarray(maxplus_conv(prev, g, band=band))
    dense = maxplus_conv_np(prev, g)           # f32 oracle, full band
    np.testing.assert_allclose(got, dense, rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("seed", range(6))
def test_maxplus_batched_matches_2d_kernel(seed):
    """The grid-batched Pallas kernel equals per-slice 2-D ``maxplus_conv``
    calls (and the f32 numpy oracle) on stacks with mixed per-row bands —
    the equivalence CI pins under REPRO_PALLAS_INTERPRET=1."""
    from repro.kernels.maxplus import (maxplus_conv, maxplus_conv_batched,
                                       maxplus_conv_np)
    rng = np.random.RandomState(seed)
    B = rng.randint(1, 5)
    n = rng.randint(0, 120)
    prev = np.maximum.accumulate(
        rng.uniform(-50.0, 50.0, (B, n + 1)).astype(np.float32), axis=1)
    g = rng.uniform(-50.0, 50.0, (B, n + 1)).astype(np.float32)
    bands = []
    for r in range(B):
        band = rng.choice([None, rng.randint(0, n + 1)])
        if band is not None:
            band = int(band)
            g[r, band:] = g[r, min(band, n)]
        bands.append(band)
    got = np.asarray(maxplus_conv_batched(prev, g, bands))
    assert got.shape == (B, n + 1)
    for r in range(B):
        want = np.asarray(maxplus_conv(prev[r], g[r], band=bands[r]))
        np.testing.assert_allclose(got[r], want, rtol=1e-6, atol=1e-5)
        oracle = maxplus_conv_np(prev[r], g[r], band=bands[r])
        np.testing.assert_allclose(got[r], oracle, rtol=1e-6, atol=1e-5)


def test_maxplus_batched_scalar_band_and_shape_checks():
    """Scalar band broadcast, band-count validation and 1-D rejection."""
    from repro.kernels.maxplus import maxplus_conv, maxplus_conv_batched
    rng = np.random.RandomState(11)
    prev = np.maximum.accumulate(
        rng.uniform(0, 10, (3, 33)).astype(np.float32), axis=1)
    g = rng.uniform(0, 10, (3, 33)).astype(np.float32)
    g[:, 8:] = g[:, 8:9]
    got = np.asarray(maxplus_conv_batched(prev, g, 8))
    for r in range(3):
        want = np.asarray(maxplus_conv(prev[r], g[r], band=8))
        np.testing.assert_allclose(got[r], want, rtol=1e-6, atol=1e-5)
    with pytest.raises(ValueError):
        maxplus_conv_batched(prev[0], g[0])
    with pytest.raises(ValueError):
        maxplus_conv_batched(prev, g, [8, 8])


def test_maxplus_matches_planner_float64_kernel():
    """The float32 kernel tracks the planner's float64 value kernel to f32
    precision on O(100) data — the interpret-mode equivalence the CI step
    pins (``_maxplus_vals`` is the PR-1 ground-truth kernel)."""
    from repro.core.planner import _maxplus_vals
    from repro.kernels.maxplus import maxplus_conv
    for seed in range(6):
        prev, g, _ = _maxplus_case(seed, monotone=True)
        got = np.asarray(maxplus_conv(prev, g))
        want = _maxplus_vals(prev, g)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_maxplus_planner_backend_end_to_end():
    """A PlanTable built with REPRO_PLANNER_BACKEND=pallas (via the
    setter) matches the all-scalar reference table to f32 tolerance."""
    from repro.configs import get_arch
    from repro.core.costmodel import A800, TaskModel
    from repro.core.planner import (PlanTable, set_maxplus_backend,
                                    solve_reference)
    from repro.core.waf import Task
    tasks = [Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                            global_batch=256),
                  weight=1.0, max_workers=8),
             Task(model=TaskModel.from_arch(get_arch("gpt3-7b"),
                                            global_batch=256),
                  weight=1.3)]
    ref = PlanTable(tasks, [8, 16], A800, 3600.0, 120.0,
                    incremental=False, solver=solve_reference)
    set_maxplus_backend("pallas")
    try:
        seg = PlanTable(tasks, [8, 16], A800, 3600.0, 120.0)
    finally:
        set_maxplus_backend(None)
    assert set(seg.table) == set(ref.table)
    for key in ref.table:
        a, b = seg.table[key], ref.table[key]
        rel = abs(a.total_reward - b.total_reward) / max(
            1.0, abs(b.total_reward))
        assert rel < 1e-5, (key, rel)


@pytest.mark.parametrize("seed", range(6))
def test_maxplus_scan_chunk_matches_oracle(seed):
    """The scan-compatible chunk kernel (the fused planner engine's inner
    step) against its numpy oracle:
    ``out[r, j] = max_k wins[r, j + K - 1 - k] + gs[r, k]``."""
    from repro.kernels.maxplus import NEG, maxplus_scan_chunk
    rng = np.random.RandomState(seed)
    B = rng.randint(1, 6)
    K = rng.randint(1, 33)
    n1 = rng.randint(1, 200)
    wins = rng.uniform(-50.0, 50.0, (B, n1 + K - 1)).astype(np.float32)
    gs = rng.uniform(-50.0, 50.0, (B, K)).astype(np.float32)
    # -inf masking (how the fused program disables off-band candidates
    # and dummy rows) must stay a no-op candidate, not a NaN source
    gs[rng.uniform(size=gs.shape) < 0.2] = NEG
    got = np.asarray(maxplus_scan_chunk(wins, gs))
    assert got.shape == (B, n1)
    want = np.full((B, n1), NEG, dtype=np.float32)
    for k in range(K):
        want = np.maximum(want, wins[:, K - 1 - k:K - 1 - k + n1]
                          + gs[:, k:k + 1])
    np.testing.assert_array_equal(got, want)


def test_maxplus_f32_error_budget_paper_scale():
    """f32 error budget for the Pallas kernels on PAPER-SCALE reward
    rows — real cost-model reward curves (O(1e2..1e4) values, O(1e-3)
    increments), chained through an m-task DP exactly as the planner
    composes them — against the f64 numpy kernel (``_maxplus_vals``).

    Documented budget: **1e-6 relative** on every DP cell, per
    convolution AND accumulated over the full chain.  Observed error
    (f32 input rounding, one add per candidate, order-free max) is
    ~2e-7 chained and ~6e-7 on the raw-row stack, so the budget binds —
    any extra f32 rounding stage in the kernels would trip it.  This is
    the gate the ROADMAP requires before the pallas backend can ever
    become the default."""
    from repro.configs import get_arch
    from repro.core.costmodel import A800, TaskModel
    from repro.core.planner import PlanTable, _maxplus_vals
    from repro.core.waf import Task
    from repro.kernels.maxplus import maxplus_conv, maxplus_conv_batched
    tasks = [Task(model=TaskModel.from_arch(get_arch(size),
                                            global_batch=256),
                  weight=w, max_workers=64)
             for size, w in (("gpt3-1.3b", 1.0), ("gpt3-7b", 1.3),
                             ("gpt3-13b", 0.7), ("gpt3-1.3b", 2.0))]
    table = PlanTable(tasks, [32] * len(tasks), A800, 3600.0, 120.0,
                      lazy=True, n_budget=512)
    rows = [np.asarray(table._row(i), dtype=np.float64)
            for i in range(len(tasks))]

    def rel(a, b):
        return np.max(np.abs(np.asarray(a, dtype=np.float64) - b)
                      / np.maximum(np.abs(b), 1.0))

    # chained DP: f32 kernel output feeds the next f32 convolution, so
    # rounding accumulates exactly as it would in a pallas-backed build
    # (leaf = running max over budgets, like the engines' DP leaves)
    prev64 = np.maximum.accumulate(rows[0])
    prev32 = prev64.astype(np.float32)
    worst = 0.0
    for g in rows[1:]:
        prev64 = _maxplus_vals(prev64, g)
        prev32 = np.asarray(maxplus_conv(prev32, g.astype(np.float32)))
        worst = max(worst, rel(prev32, prev64))
    assert worst < 1e-6, f"chained f32 DP error {worst:.2e} over budget"

    # grid-batched kernel on the raw reward stack, same budget
    stack32 = np.stack(rows).astype(np.float32)
    prev_stack = np.stack([np.maximum.accumulate(r) for r in rows])
    got = np.asarray(maxplus_conv_batched(
        prev_stack.astype(np.float32), stack32))
    worst_b = max(rel(got[r], _maxplus_vals(prev_stack[r], rows[r]))
                  for r in range(len(rows)))
    assert worst_b < 1e-6, f"batched f32 error {worst_b:.2e} over budget"
    print(f"[f32 budget] chained {worst:.2e}, batched {worst_b:.2e} "
          f"(budget 1e-6)")


# ---------------------------------------------------------------------------
# end-to-end kernel path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-780m", "gemma3-12b"])
def test_pallas_path_matches_jnp_path(arch):
    from repro.configs import get_arch
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import build_model
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticLM(cfg, seq_len=64, global_batch=2).batch(0)
    l1, _ = model.loss(params, batch, kernel="jnp")
    l2, _ = model.loss(params, batch, kernel="pallas")
    assert abs(float(l1) - float(l2)) < 1e-4


# ---------------------------------------------------------------------------
# flash custom-VJP blocked attention (perf variant "flash")
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,S,H,KV,D,causal,window,softcap",
    [(2, 128, 4, 2, 64, True, 0, 0.0),
     (1, 100, 4, 1, 32, True, 0, 0.0),
     (2, 64, 8, 8, 16, True, 16, 0.0),
     (1, 96, 4, 2, 64, True, 0, 30.0)])
def test_flash_vjp_matches_oracle(B, S, H, KV, D, causal, window, softcap):
    from repro.models.flash_vjp import flash_attention_jnp
    ks = jax.random.split(jax.random.fold_in(KEY, S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))

    def f(q, k, v):
        return flash_attention_jnp(q, k, v, causal, window, softcap, 0,
                                   32, 32)

    def r(q, k, v):
        return ref.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, q_offset=0)

    np.testing.assert_allclose(f(q, k, v), r(q, k, v), atol=2e-5, rtol=2e-5)
    g1 = jax.grad(lambda q, k, v: jnp.sum(f(q, k, v) ** 2), (0, 1, 2))(
        q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(r(q, k, v) ** 2), (0, 1, 2))(
        q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)


def test_flash_kernel_path_end_to_end():
    from repro.configs import get_arch
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import build_model
    for arch in ("qwen3-4b", "deepseek-v3-671b"):
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = SyntheticLM(cfg, seq_len=64, global_batch=2).batch(0)
        l1, _ = model.loss(params, batch, kernel="jnp")
        l2, _ = model.loss(params, batch, kernel="flash")
        assert abs(float(l1) - float(l2)) < 1e-4, arch


def test_moe_shardmap_matches_reference():
    from repro.configs import get_arch
    from repro.data.pipeline import SyntheticLM
    from repro.models import moe
    from repro.models.model import build_model
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticLM(cfg, seq_len=32, global_batch=2).batch(0)
    l_ref, _ = model.loss(params, batch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    moe.SHARD_MAP = (mesh, ("data",))
    try:
        l_sm, _ = model.loss(params, batch)
        g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    finally:
        moe.SHARD_MAP = None
    assert abs(float(l_ref) - float(l_sm)) < 1e-5
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_moe_dispatch_3d_matches_flat():
    from repro.configs import get_arch
    from repro.data.pipeline import SyntheticLM
    from repro.models import moe
    from repro.models.model import build_model
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticLM(cfg, seq_len=32, global_batch=2).batch(0)
    l_flat, _ = model.loss(params, batch)
    moe.DISPATCH_3D = True
    try:
        l_3d, _ = model.loss(params, batch)
    finally:
        moe.DISPATCH_3D = False
    assert abs(float(l_flat) - float(l_3d)) < 1e-6
