"""Error detection (§4.1, Table 1/2) and handling workflow (§4.2, Fig. 7)."""
import pytest

from repro.core.agent import UnicronAgent
from repro.core.detection import (BASELINE_TIMEOUT_S, ERROR_TABLE, ErrorKind,
                                  Method, OnlineStatMonitor, Severity,
                                  classify, detection_time)
from repro.core.handling import Action, FailureCase, action_for, decide
from repro.core.kvstore import KVStore


def test_table1_complete():
    """Every error status has a detection method + severity (Table 1)."""
    assert len(ERROR_TABLE) == len(ErrorKind)
    m, s = classify(ErrorKind.LOST_CONNECTION)
    assert m is Method.NODE_HEALTH and s is Severity.SEV1
    m, s = classify(ErrorKind.NCCL_TIMEOUT)
    assert m is Method.STATISTICAL and s is Severity.SEV3
    m, s = classify(ErrorKind.CUDA_ERROR)
    assert m is Method.EXCEPTION and s is Severity.SEV2


def test_detection_times_table2():
    """Unicron detects in seconds; the baseline waits for the 30-minute
    NCCL watchdog for everything but node loss (Table 2)."""
    avg_iter = 30.0
    assert detection_time(ErrorKind.LOST_CONNECTION, avg_iter) == \
        pytest.approx(5.6)
    assert detection_time(ErrorKind.EXITED_ABNORMALLY, avg_iter) == \
        pytest.approx(1.8)
    assert detection_time(ErrorKind.CUDA_ERROR, avg_iter) == pytest.approx(0.3)
    assert detection_time(ErrorKind.TASK_HANG, avg_iter) == \
        pytest.approx(3 * avg_iter)
    for kind in (ErrorKind.EXITED_ABNORMALLY, ErrorKind.CUDA_ERROR,
                 ErrorKind.TASK_HANG):
        assert detection_time(kind, avg_iter, unicron=False) == \
            BASELINE_TIMEOUT_S
    assert detection_time(ErrorKind.LOST_CONNECTION, avg_iter,
                          unicron=False) == pytest.approx(5.7)


def test_online_stat_monitor_thresholds():
    """Fig. 6: degraded above 1.1x average, failed above 3x."""
    mon = OnlineStatMonitor()
    assert mon.status(100.0) == "ok"          # no history yet
    for _ in range(10):
        mon.observe(10.0)
    assert mon.status(10.5) == "ok"
    assert mon.status(12.0) == "degraded"
    assert mon.status(29.9) == "degraded"
    assert mon.status(30.1) == "failed"


def test_severity_to_action_mapping():
    assert action_for(Severity.SEV3) is Action.REATTEMPT
    assert action_for(Severity.SEV2) is Action.RESTART
    assert action_for(Severity.SEV1) is Action.RECONFIGURE


def test_escalation_chain():
    """Fig. 7: SEV3 -> SEV2 -> SEV1 on repeated action failure."""
    case = FailureCase.from_kind(ErrorKind.CONNECTION_REFUSED)
    assert case.severity is Severity.SEV3
    assert case.next_action() is Action.REATTEMPT
    case.record_failure()
    assert case.severity is Severity.SEV2
    assert case.next_action() is Action.RESTART
    case.record_failure()
    assert case.severity is Severity.SEV1
    d = decide(case)
    assert d.action is Action.RECONFIGURE
    assert d.isolate_node and d.replan_all_tasks
    case.record_failure()                     # SEV1 stays SEV1
    assert case.severity is Severity.SEV1


def test_agent_heartbeat_lease_expiry():
    """Node loss = heartbeat lease expiry in the status monitor -> SEV1."""
    kv = KVStore()
    agent = UnicronAgent(node_id=3, kv=kv)
    agent.heartbeat(now=0.0)
    assert kv.get("/nodes/3/alive") == 0.0
    assert kv.expire(now=3.0) == []           # TTL 6s: still alive
    agent.kill()
    dead = kv.expire(now=7.0)
    assert "/nodes/3/alive" in dead


def test_agent_inband_report_latency():
    kv = KVStore()
    agent = UnicronAgent(node_id=0, kv=kv)
    rec = agent.report(ErrorKind.CUDA_ERROR, now=100.0)
    assert rec["visible_at"] == pytest.approx(100.3)
    assert rec["severity"] == int(Severity.SEV2)
    assert kv.prefix("/errors/0/")


def test_kvstore_watch_and_cas():
    kv = KVStore()
    seen = []
    kv.watch("/a/", lambda op, k, v: seen.append((op, k)))
    kv.put("/a/x", 1)
    kv.put("/b/y", 2)
    kv.delete("/a/x")
    assert seen == [("put", "/a/x"), ("delete", "/a/x")]
    kv.put("/c", "old")
    assert kv.cas("/c", "old", "new")
    assert not kv.cas("/c", "old", "newer")
    assert kv.get("/c") == "new"
