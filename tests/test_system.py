"""System-level integration tests: training convergence, decode/forward
consistency, fused vs resumable path equivalence, coordinator flow."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.coordinator import UnicronCoordinator
from repro.core.costmodel import A800, TaskModel
from repro.core.detection import ErrorKind
from repro.core.handling import Action, Trigger
from repro.core.waf import Task
from repro.data.pipeline import SyntheticLM, stack_microbatches
from repro.models.model import build_model
from repro.optim import AdamW, constant
from repro.serve.decode import generate
from repro.train.state import init_train_state
from repro.train.step import (accumulate, finalize_step, make_grad_fn, make_train_step)


def test_training_loss_decreases():
    cfg = get_arch("gemma-2b").reduced()
    model = build_model(cfg)
    opt = AdamW(lr=constant(3e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, seq_len=64, global_batch=8)
    step = jax.jit(make_train_step(model, opt, 2))
    losses = []
    for i in range(12):
        state, m = step(state, stack_microbatches(data.batch(i), 2))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_fused_equals_resumable_path():
    """The fused scan step and the per-micro-batch accumulate/finalize
    path produce identical parameters (strict semantics)."""
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    opt = AdamW(lr=constant(1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(1))
    data = SyntheticLM(cfg, seq_len=32, global_batch=4)
    batch = data.batch(0)

    fused = jax.jit(make_train_step(model, opt, 2))
    s_fused, _ = fused(state, stack_microbatches(batch, 2))

    grad_fn = make_grad_fn(model)
    acc = None
    for i in range(2):
        mb = jax.tree.map(lambda a: a[i * 2:(i + 1) * 2], batch)
        g, _ = grad_fn(state.params, mb)
        acc = accumulate(acc, g)
    s_resum, _ = finalize_step(opt, state, acc, 2)

    for a, b in zip(jax.tree.leaves(s_fused.params),
                    jax.tree.leaves(s_resum.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_decode_matches_forward_teacher_forcing():
    """Token-by-token decode reproduces the full-sequence forward logits
    (KV caches, ring buffers, SSM states are all exact)."""
    for arch in ("qwen3-4b", "gemma3-12b", "mamba2-780m", "zamba2-1.2b",
                 "deepseek-v3-671b"):
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        S = 24
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                                  cfg.vocab)
        batch = {"tokens": toks}
        full_logits, _ = model.forward(params, batch)

        caches = model.init_cache(2, capacity=S)
        logits_seq = []
        for t in range(S):
            lg, caches = model.decode_step(params, caches, toks[:, t],
                                           jnp.int32(t))
            logits_seq.append(lg)
        dec = jnp.stack(logits_seq, axis=1)
        np.testing.assert_allclose(np.asarray(dec),
                                   np.asarray(full_logits),
                                   atol=2e-3, rtol=2e-3)


def test_generate_deterministic_greedy():
    cfg = get_arch("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    out1 = generate(model, params, prompt, n_new=6)
    out2 = generate(model, params, prompt, n_new=6)
    assert out1.shape == (2, 6)
    assert jnp.array_equal(out1, out2)


def test_coordinator_full_failure_flow():
    """SEV2 error -> restart decision; failed restart escalates to SEV1
    -> reconfigure; the plan respects the shrunken cluster."""
    tasks = [Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                            global_batch=64)),
             Task(model=TaskModel.from_arch(get_arch("gpt3-7b"),
                                            global_batch=64))]
    coord = UnicronCoordinator(tasks, [32, 96], A800)
    d = coord.on_error("case1", ErrorKind.CUDA_ERROR)
    assert d.action is Action.RESTART
    d = coord.on_action_failed("case1")
    assert d.action is Action.RECONFIGURE and d.isolate_node

    plan = coord.reconfigure(n_workers_now=120, faulted_task=1,
                             trigger=Trigger.ERROR)
    assert sum(plan.assignment) <= 120
    assert coord.cluster_waf() > 0

    # node joins back: reconfiguration can use the extra capacity
    plan2 = coord.reconfigure(n_workers_now=128, trigger=Trigger.NODE_JOIN)
    assert sum(plan2.assignment) <= 128


def test_coordinator_multitask_beats_naive_split():
    """The WAF-optimal assignment is at least as good as equal split."""
    from repro.core import waf as waf_mod
    small = Task(model=TaskModel.from_arch(get_arch("gpt3-1.3b"),
                                           global_batch=64), weight=2.0)
    big = Task(model=TaskModel.from_arch(get_arch("gpt3-13b"),
                                         global_batch=64), weight=0.5)
    coord = UnicronCoordinator([small, big], [64, 64], A800)
    plan = coord.reconfigure(n_workers_now=128, trigger=Trigger.TASK_LAUNCHED)
    equal = sum(waf_mod.waf(t, 64, A800) for t in (small, big))
    assert plan.waf >= equal - 1e-6
