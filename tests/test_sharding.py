"""Sharding-rule tests: every arch's parameter tree gets divisibility-
valid specs; real (laptop-mesh) execution agrees with single-device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_arch
from repro.models.model import build_model
from repro.optim import AdamW, constant
from repro.sharding import (batch_specs, cache_specs, param_specs,
                            train_state_specs, zero1_spec)
from repro.train.state import abstract_train_state

MODEL_SIZE = 16


def _flat_axes(spec):
    out = []
    for p in spec:
        if p is None:
            continue
        if isinstance(p, tuple):
            out.extend(p)
        else:
            out.append(p)
    return out


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_divisible(arch):
    """Every sharded dim divides the mesh axis size — for the FULL config
    (eval_shape: no allocation)."""
    cfg = get_arch(arch)
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(params_sds, MODEL_SIZE)

    n_sharded = 0
    for leaf, spec in zip(jax.tree.leaves(params_sds),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x:
                                          isinstance(x, P))):
        assert len(spec) <= len(leaf.shape)
        for dim, part in enumerate(spec):
            if part is None:
                continue
            assert leaf.shape[dim] % MODEL_SIZE == 0, (leaf.shape, spec)
            n_sharded += 1
    # the bulk of parameters must actually be sharded
    assert n_sharded > 0


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v3-671b",
                                  "mamba2-780m"])
def test_zero1_opt_state_sharded(arch):
    cfg = get_arch(arch)
    model = build_model(cfg)
    opt = AdamW(lr=constant(1e-4))
    state_sds = abstract_train_state(model, opt)
    specs = train_state_specs(state_sds, _FakeMesh())
    # mu for most big matrices must carry a data axis beyond the param
    # spec.  Exceptions exist: e.g. mamba2's (50280, 1536) embedding has
    # d_model on the model axis and a vocab not divisible by 16, so its
    # optimizer state legitimately stays data-unsharded.
    big = [(l, s) for l, s in zip(
        jax.tree.leaves(state_sds.opt.mu),
        jax.tree.leaves(specs.opt.mu, is_leaf=lambda x: isinstance(x, P)))
        if l.ndim >= 2 and l.size > 1e6]
    assert big
    with_data = [s for _, s in big if "data" in _flat_axes(s)]
    assert len(with_data) >= len(big) * 0.6
    for leaf, s in big:
        if "data" not in _flat_axes(s):
            # only legitimately-indivisible leaves may lack the data axis
            assert all(d % 16 != 0 or p is not None
                       for d, p in zip(leaf.shape,
                                       list(s) + [None] * leaf.ndim)
                       if d > 1), (leaf.shape, s)


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_zero1_spec_adds_axis():
    s = zero1_spec(P(None, "model"), (4096, 1024), ("data",), 16)
    assert s == P("data", "model")
    # refuses non-divisible
    s2 = zero1_spec(P(None, "model"), (17, 1024), ("data",), 16)
    assert s2 == P(None, "model")


def test_batch_specs_stacked():
    sds = {"tokens": jax.ShapeDtypeStruct((8, 32, 128), jnp.int32)}
    specs = batch_specs(sds, ("data",), 16, stacked=True)
    assert specs["tokens"] == P(None, "data", None)
    specs2 = batch_specs(sds, ("pod", "data"), 32, stacked=True)
    assert specs2["tokens"] == P(None, ("pod", "data"), None)


def test_cache_specs_long_context():
    sds = {"k": jax.ShapeDtypeStruct((48, 1, 524288, 8, 256), jnp.bfloat16),
           "v": jax.ShapeDtypeStruct((48, 1, 524288, 8, 256), jnp.bfloat16)}
    specs = cache_specs(sds, ("data",), 16, 16, shard_seq=True)
    # batch=1 unshardable -> capacity dim over data (flash-decoding style)
    assert specs["k"][2] == "data"


def test_sharded_execution_matches_single_device():
    """Real multi-device check on the host mesh: a sharded train step
    produces the same loss as the unsharded one."""
    n = len(jax.devices())
    if n < 2:
        # 1-device CI: the mesh is trivial but the pjit path still runs
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    else:
        mesh = jax.make_mesh((n // 2 if n % 2 == 0 else 1, 2)
                             if n >= 2 else (1, 1), ("data", "model"))
    from repro.data.pipeline import SyntheticLM, stack_microbatches
    from repro.sharding import to_named
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step

    cfg = get_arch("gemma-2b").reduced()
    model = build_model(cfg)
    opt = AdamW(lr=constant(1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, seq_len=32, global_batch=4)
    batch = stack_microbatches(data.batch(0), 2)
    step = make_train_step(model, opt, 2)

    _, ref_metrics = jax.jit(step)(state, batch)

    state_sds = jax.eval_shape(lambda s: s, state)
    specs = train_state_specs(state_sds, mesh)
    bspecs = batch_specs(jax.eval_shape(lambda b: b, batch),
                         ("data",), mesh.shape["data"], stacked=True)
    jitted = jax.jit(step, in_shardings=(to_named(mesh, specs),
                                         to_named(mesh, bspecs)))
    _, got_metrics = jitted(state, batch)
    np.testing.assert_allclose(float(got_metrics["loss"]),
                               float(ref_metrics["loss"]), rtol=1e-5)
